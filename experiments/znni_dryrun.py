"""ZNNi-at-pod-scale dry-run: the paper's own workload lowered on the
production mesh.

Volume inference for the paper's nets, sharded BOTH ways the paper
distributes work (§II): the `model` axis carries independent volumes
(the paper's patch-per-worker outer loop) and the `data` axis spatially
shards each volume along x with halo exchange (our beyond-paper variant
of the overlapping patches).  Proves the distribution config of the
paper-faithful pipeline is coherent on 256 chips.

Run:  PYTHONPATH=src python experiments/znni_dryrun.py [--net n537] [--m 4]
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    " --xla_cpu_strict_dot_conv_math=true"
    " --xla_allow_excess_precision=false"
)

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import ZNNI_NETS  # noqa: E402
from repro.core import convnet, planner  # noqa: E402
from repro.core.distributed_inference import halo_sharded_apply  # noqa: E402
from repro.core.hw import TPU_V5E  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.analysis import collective_bytes, roofline  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "dryrun")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="n537")
    ap.add_argument("--m", type=int, default=4, help="fragment size per x-shard")
    args = ap.parse_args()

    net = ZNNI_NETS[args.net]
    plan = planner.plan_single(net, TPU_V5E, max_m=args.m)
    prims = [c.prim for c in plan.choices]
    # Along the SHARDED x axis each shard holds a plain-stride core extent
    # m*P (conv/pool slack arrives via halo exchange); the unsharded y/z
    # axes use the standard MPF-valid patch size.
    x_local = args.m * net.total_pooling()
    n_in = net.valid_input_size(args.m)
    mesh = make_production_mesh()  # (16, 16) = ('data', 'model')
    W = 16  # x-shards over 'data'
    S = 16  # volumes over 'model'

    params = jax.eval_shape(
        lambda k: convnet.init_params(k, net), jax.random.PRNGKey(0)
    )
    # concrete params needed for closure? no — pass as argument.
    x_sds = jax.ShapeDtypeStruct((S, 1, W * x_local, n_in, n_in), jnp.float32)

    def run(params, x):
        f = shard_map(
            lambda p, xl: halo_sharded_apply(p, net, xl, prims, axis_name="data"),
            mesh=mesh,
            in_specs=(P(), P("model", None, "data", None, None)),
            out_specs=P("model", None, "data", None, None),
            check_rep=False,
        )
        return f(params, x)

    jitted = jax.jit(run)
    with mesh:
        lowered = jitted.lower(params, x_sds)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    terms = roofline(
        float(ca.get("flops", 0)), float(ca.get("bytes accessed", 0)),
        coll.get("total", 0.0), hw=TPU_V5E, chips=256,
    )
    print(f"[znni-dryrun] {args.net} x {S} volumes x {W} x-shards (256 chips)")
    print(f"  memory_analysis: {mem}")
    print(f"  plan: S={plan.batch} prims={prims}")
    print(f"  cost: flops={ca.get('flops', 0):.3e} bytes={ca.get('bytes accessed', 0):.3e}")
    print(f"  collectives: { {k: f'{v:.3e}' for k, v in coll.items()} }")
    print(f"  roofline: compute={terms.compute_s:.3e}s memory={terms.memory_s:.3e}s "
          f"collective={terms.collective_s:.3e}s dominant={terms.dominant}")
    rec = {
        "net": args.net, "volumes": S, "x_shards": W, "n_in": n_in,
        "prims": prims,
        "x_local": x_local,
        "mem": {"argument_bytes": mem.argument_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes},
        "cost": {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))},
        "collectives": coll, "roofline": terms.to_dict(),
    }
    with open(os.path.join(OUT, f"znni__{args.net}__single.json"), "w") as f:
        json.dump(rec, f, indent=2, default=str)
    print("OK")


if __name__ == "__main__":
    main()
