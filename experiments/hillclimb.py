"""Hillclimb driver: run a dry-run probe cell with named override sets and
record tagged JSONs for EXPERIMENTS.md §Perf.

Usage (requires the probe env flag):
  REPRO_UNROLL_INNER=1 PYTHONPATH=src python experiments/hillclimb.py \
      --arch qwen2-vl-7b --shape prefill_32k --mesh single \
      --tag h1_padheads --set pad_q_groups=8
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    " --xla_cpu_strict_dot_conv_math=true"
    " --xla_allow_excess_precision=false"
)

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"))

from repro.launch.dryrun import probe_cell, run_cell  # noqa: E402

OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "dryrun")


def parse_overrides(pairs):
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        try:
            out[k] = int(v)
        except ValueError:
            out[k] = v
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--tag", required=True)
    ap.add_argument("--set", action="append", default=[], dest="sets")
    ap.add_argument("--full", action="store_true",
                    help="also run the full-depth compile (memory numbers)")
    args = ap.parse_args()

    overrides = parse_overrides(args.sets)
    rec = probe_cell(args.arch, args.shape, args.mesh, overrides=overrides)
    fname = os.path.join(OUT, f"{args.tag}__{args.arch}__{args.shape}__{args.mesh}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=2, default=str)
    if args.full:
        recf = run_cell(args.arch, args.shape, args.mesh, overrides=overrides)
        with open(fname.replace(".json", "__full.json"), "w") as f:
            json.dump(recf, f, indent=2, default=str)
    print(f"wrote {fname}")


if __name__ == "__main__":
    main()
