"""Render EXPERIMENTS.md tables from dry-run JSON artifacts."""

from __future__ import annotations

import glob
import json
import os
import sys

DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "dryrun")


def load(tag="baseline"):
    recs = []
    for f in sorted(glob.glob(os.path.join(DIR, f"{tag}__*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def roofline_table(recs, mesh="single", probes=None):
    """Roofline per cell.  When `probes` (trip-count-corrected records) are
    given, terms come from the probe and memory columns from the baseline."""
    by_cell = {}
    if probes:
        by_cell = {(p["arch"], p["shape"], p["mesh"]): p for p in probes}
    rows = []
    header = (
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | "
        "args/chip (GiB) | temp/chip (GiB) | useful FLOPs ratio |"
    )
    rows.append(header)
    rows.append("|" + "---|" * 9)
    for r in recs:
        if r["mesh"] != mesh:
            continue
        cell = f"| {r['arch']} | {r['shape']} "
        if "skipped" in r:
            rows.append(cell + "| — | — | — | skipped (full attention @500k) | — | — | — |")
            continue
        if "error" in r:
            rows.append(cell + f"| ERROR {r['error'][:40]} |")
            continue
        p = by_cell.get((r["arch"], r["shape"], r["mesh"]))
        t = (p or r)["roofline"]
        m = r["mem"]
        rows.append(
            cell
            + f"| {t['compute_s']:.2e} | {t['memory_s']:.2e} | {t['collective_s']:.2e} "
            f"| **{t['dominant']}** | {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['temp_bytes'])} | {t['useful_flops_ratio']:.3f} |"
        )
    return "\n".join(rows)


def dryrun_table(recs):
    rows = [
        "| arch | shape | mesh | chips | compiles | fits HBM (resident) | "
        "FLOPs/chip | bytes/chip | coll bytes/chip | compile (s) |",
        "|" + "---|" * 10,
    ]
    for r in recs:
        base = f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('chips', '—')} "
        if "skipped" in r:
            rows.append(base + "| skip | — | — | — | — | — |")
            continue
        if "error" in r:
            rows.append(base + f"| **FAIL** | — | — | — | — | — |")
            continue
        m = r["mem"]
        resident = m["argument_bytes"]
        fits = "yes" if resident < 16 * 2**30 else "NO"
        c = r["cost"]
        rows.append(
            base + f"| yes | {fits} ({fmt_bytes(resident)} GiB) "
            f"| {c.get('flops', 0):.2e} | {c.get('bytes accessed', 0):.2e} "
            f"| {r['collectives'].get('total', 0):.2e} | {r.get('compile_s', 0):.0f} |"
        )
    return "\n".join(rows)


if __name__ == "__main__":
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    recs = load(tag)
    probes = load("probe")
    print("## Roofline (single-pod, 256 chips; trip-count-corrected probes)\n")
    print(roofline_table(recs, "single", probes=probes))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(recs, "multi", probes=probes))
    print("\n## Dry-run (all cells)\n")
    print(dryrun_table(recs))
