"""Sweep-aware planning: predicted reuse counters == executor counters (ISSUE 4).

The acceptance property of threading ``PlanGeometry`` through the
cost/primitive/planner stack: for the deployed mix (overlap_save at layer
0, fft_cached deeper, MPF pools), the planner-side cache simulation
(``tiler.predict_sweep_counts``, surfaced as ``PlanExecutor.predict_counts``
and ``Plan.sweep``) must match the executor's measured ``last_stats``
EXACTLY — segment FFTs, cache hits, MAD segments, and strip/full patch
counts — across interior-rich, shifted-edge, ragged, and degenerate
single-patch tilings, at multiple batch sizes.  Alongside exactness, the
deep-reuse strip path must (a) equal the dense oracle, and (b) strictly
reduce per-interior-patch MAD work versus the PR-3 full path.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, cost_model, planner
from repro.core.hw import TPU_V5E
from repro.volume import PlanExecutor
from repro.volume.tiler import predict_sweep_counts

NET = ConvNetConfig(
    "sweep-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
# the deployed mix: overlap_save where the sweep cache has a cross-patch
# identity to exploit (layer 0), fft_cached deeper
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()  # m = 1


def _dense(params, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, NET, jnp.asarray(vol)[None])[0]
    )


@pytest.fixture(scope="module")
def params():
    return convnet.init_params(jax.random.PRNGKey(0), NET)


# interior-rich, shifted x edge, ragged y, and the degenerate single patch
SHAPES = {
    "interior": (4 * CORE + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1),
    "shifted_x": (3 * CORE + 1 + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1),
    "ragged_yz": (3 * CORE + 2 + FOV - 1, CORE + 3 + FOV - 1, CORE + 1 + FOV - 1),
    "single_patch": (CORE + FOV - 1, CORE + FOV - 1, CORE + FOV - 1),
}


@pytest.mark.parametrize("shape", SHAPES.values(), ids=SHAPES.keys())
@pytest.mark.parametrize("batch", [1, 3])
def test_predicted_counters_match_executor_exactly(params, rng, shape, batch):
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ex = PlanExecutor(params, NET, prims=MIX, m=1, batch=batch)
    got = ex.run(vol)
    np.testing.assert_allclose(got, _dense(params, vol), atol=1e-3)
    s = ex.last_stats
    pred = ex.predict_counts(shape)
    assert s["os_seg_fft"] == pred.seg_fft
    assert s["os_seg_hits"] == pred.seg_hits
    assert s["os_mad_segments"] == pred.mad_segments
    assert s["deep_strip_patches"] == pred.strip_patches
    assert s["deep_full_patches"] == pred.full_patches
    assert pred.n_patches == s["patches"]
    # a second sweep is a fresh scope: identical counts, no leak
    ex.run(vol)
    assert ex.last_stats["os_seg_fft"] == pred.seg_fft
    assert not ex._sweeps and not ex._halo_caches


def test_planner_sweep_counts_equal_executor(params, rng):
    """``plan_fixed(volume_shape=...)`` records on the Plan exactly what
    the executor measures — the planner and the runtime agree on the whole
    sweep, not just per-patch shapes."""
    shape = SHAPES["shifted_x"]
    plan = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=2, volume_shape=shape
    )
    assert plan.sweep is not None and plan.geometry is not None
    assert plan.geometry.seg_core == plan.core  # executor's pinned grid
    ex = PlanExecutor(params, NET, plan)
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ex.run(vol)
    s = ex.last_stats
    assert s["os_seg_fft"] == plan.sweep.seg_fft
    assert s["os_seg_hits"] == plan.sweep.seg_hits
    assert s["os_mad_segments"] == plan.sweep.mad_segments
    assert s["deep_strip_patches"] == plan.sweep.strip_patches


def test_deep_reuse_reduces_interior_work(params, rng):
    """Interior patches pay strictly less: fewer MAD segments than the
    PR-3 full path, identical segment-FFT counts (layer-0 input reuse is
    unchanged), and bitwise-equal-to-oracle outputs either way."""
    shape = SHAPES["interior"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    want = _dense(params, vol)
    deep = PlanExecutor(params, NET, prims=MIX, m=1, batch=1)
    flat = PlanExecutor(params, NET, prims=MIX, m=1, batch=1, deep_reuse=False)
    np.testing.assert_allclose(deep.run(vol), want, atol=1e-3)
    np.testing.assert_allclose(flat.run(vol), want, atol=1e-3)
    sd, sf = deep.last_stats, flat.last_stats
    assert sd["deep_strip_patches"] > 0
    assert sd["os_mad_segments"] < sf["os_mad_segments"]
    assert sd["os_seg_fft"] == sf["os_seg_fft"]
    # per-interior-patch MAD at the jit boundary: q trailing segments
    q = deep._q_strip
    spec0 = deep.compiled.layers[0].os_spec
    assert 0 < q < spec0.n_segments
    assert (
        sd["os_mad_segments"]
        == sd["deep_strip_patches"] * q
        + sd["deep_full_patches"] * spec0.n_segments
    )


def test_single_patch_volume_degenerates_to_full_path(params, rng):
    """The degenerate single-patch sweep: nothing to reuse, the strip path
    never fires, and prediction still matches exactly."""
    shape = SHAPES["single_patch"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ex = PlanExecutor(params, NET, prims=MIX, m=1, batch=2)
    np.testing.assert_allclose(ex.run(vol), _dense(params, vol), atol=1e-3)
    s = ex.last_stats
    assert s["deep_strip_patches"] == 0 and s["deep_full_patches"] == 1
    assert s["os_seg_hits"] == 0
    pred = ex.predict_counts(shape)
    assert (s["os_seg_fft"], s["os_mad_segments"]) == (
        pred.seg_fft, pred.mad_segments
    )


def test_predict_counts_requires_reuse_plan(params):
    prims = ["fft_cached" if l.kind == "conv" else "mpf" for l in NET.layers]
    ex = PlanExecutor(params, NET, prims=prims, m=1, batch=1)
    with pytest.raises(ValueError):
        ex.predict_counts(SHAPES["single_patch"])


def test_predict_sweep_counts_rejects_plain_tiling():
    from repro.volume.tiler import tile_volume

    with pytest.raises(ValueError):
        predict_sweep_counts(tile_volume((40, 40, 40), core=4, fov=18))


# -- geometry-aware costing ---------------------------------------------------


def test_sweep_geometry_prices_below_local():
    """Sweep-aware costing strictly undercuts context-free costing for the
    reuse-capable mix (amortized input FFTs + strip-priced deeper layers),
    and the pricing uses the executor's core-pinned layer-0 grid."""
    shape = SHAPES["interior"]
    sweep = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=2, volume_shape=shape
    )
    local = planner.plan_fixed(NET, TPU_V5E, MIX, m=1, batch=2)
    assert sweep.total_time < local.total_time
    assert sweep.throughput > local.throughput
    # deep reuse off still amortizes input FFTs, but strictly less
    no_deep = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=2, volume_shape=shape, deep_reuse=False
    )
    assert sweep.total_time < no_deep.total_time < local.total_time
    assert no_deep.sweep.strip_patches == 0


def test_geometry_local_default_is_self_contained():
    """Context-free costing prices every segment transform (the honest
    price of the one-shot apply); a sweep geometry with exact per-patch
    averages prices less input-FFT work."""
    S, f, fp, n, k = 2, 8, 8, (21, 21, 21), 3
    local = cost_model.conv_overlap_save_cost(S, f, fp, n, k)
    geom = cost_model.PlanGeometry(
        core=4, fov=18, seg_core=4, interior_frac=0.5,
        seg_fft_per_patch=2.0, n_patches=8,
    ).at_layer(0)
    swept = cost_model.conv_overlap_save_cost(S, f, fp, n, k, geom)
    assert swept.flops < local.flops
    assert swept.hbm_bytes < local.hbm_bytes
    # geometry does not relax the memory-budget axis
    assert swept.peak_bytes == local.peak_bytes


def test_plan_single_volume_shape_search(params):
    """The searches accept the geometry: plan_single under a volume shape
    returns a plan whose recorded counters (when the winning mix is
    reuse-capable) come from the same simulation predict_counts runs."""
    shape = SHAPES["interior"]
    plan = planner.plan_single(
        NET, TPU_V5E, max_m=2, batches=(2,),
        conv_prims=("overlap_save",), strategy_name="os",
        volume_shape=shape,
    )
    assert plan is not None
    assert plan.sweep is not None
    assert plan.geometry.n_patches == plan.sweep.n_patches
    strategies = planner.plan_all_strategies(
        NET, TPU_V5E, chips=4, volume_shape=shape
    )
    assert strategies["single"] is not None
