"""Volume runtime: tiler geometry, plan executor, and serving engine all
reproduce the dense sliding-window oracle over volumes larger than a patch
(ISSUE 1 acceptance: non-aligned edges, MPF and plain-pool plans)."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.serving import VolumeEngine, VolumeRequest
from repro.volume import (
    PlanExecutor,
    pad_volume,
    tile_for_net,
    tile_volume,
    tiled_apply,
)

# Toy mirrors of the paper's net shapes (Table III patterns, tiny channels)
TOY_NETS = {
    "toy337": ConvNetConfig(
        "toy337", 1,
        (L("conv", 2, 4), L("pool", 2), L("conv", 3, 5), L("pool", 2), L("conv", 3, 2)),
    ),
    "toy537": ConvNetConfig(
        "toy537", 1,
        (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
    ),
    "toy726": ConvNetConfig(
        "toy726", 1,
        (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("conv", 2, 2)),
    ),
}


def _mpf_prims(net):
    convs = itertools.cycle(["direct", "fft_task", "fft_data"])
    return [next(convs) if l.kind == "conv" else "mpf" for l in net.layers]


def _pool_prims(net):
    return ["direct" if l.kind == "conv" else "pool" for l in net.layers]


def _dense(params, net, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, net, jnp.asarray(vol)[None])[0]
    )


def _volume(net, m, rng, extra=(3, 0, -2)):
    """> 1 core per axis; +3 non-aligned on x, aligned y, undersized z."""
    fov = net.field_of_view()
    core = m * net.total_pooling()
    shape = tuple(
        2 * core + e + fov - 1 if e >= 0 else max(fov, core + e + fov - 1)
        for e in extra
    )
    return rng.normal(size=(1,) + shape).astype(np.float32)


# -- tiler ------------------------------------------------------------------


def test_tiler_covers_every_output_voxel():
    t = tile_volume((30, 25, 17), core=8, fov=10)
    assert t.out_shape == (21, 16, 8)
    seen = np.zeros(t.out_shape, bool)
    for p in t.patches:
        x, y, z = p.start
        seen[x : x + t.core, y : y + t.core, z : z + t.core] = True
    assert seen.all()
    # starts stay inside the (padded) volume
    for p in t.patches:
        for s, x, pad in zip(p.start, t.vol_shape, t.pad):
            assert 0 <= s and s + t.extent <= x + pad


def test_tiler_edge_patch_is_shifted_not_clipped():
    t = tile_volume((20, 17, 17), core=8, fov=10)  # x out extent 11 -> 2 patches
    xs = sorted({p.start[0] for p in t.patches})
    assert xs == [0, 3]  # second patch shifted flush to the end, not at 8
    assert t.pad == (0, 0, 0)


def test_tiler_pads_undersized_axis_and_rejects_subfov():
    t = tile_volume((17, 17, 12), core=8, fov=10)
    assert t.pad == (0, 0, 5)
    assert t.out_shape[2] == 3
    with pytest.raises(ValueError):
        tile_volume((17, 17, 9), core=8, fov=10)


def test_tile_for_net_matches_plan_geometry():
    net = TOY_NETS["toy337"]
    m = 2
    t = tile_for_net((40, 40, 40), net, m)
    assert t.core == m * net.total_pooling()
    assert t.fov == net.field_of_view()
    assert t.extent == net.valid_input_size(m)


def test_pad_volume_is_zero_extension():
    t = tile_volume((17, 17, 12), core=8, fov=10)
    v = np.ones((2, 17, 17, 12), np.float32)
    p = pad_volume(v, t)
    assert p.shape == (2, 17, 17, 17)
    assert p[..., 12:].sum() == 0 and p[..., :12].all()


# -- tiled execution == dense oracle ---------------------------------------


@pytest.mark.parametrize("name", list(TOY_NETS))
def test_tiled_mpf_matches_dense(name, rng):
    net = TOY_NETS[name]
    params = convnet.init_params(jax.random.PRNGKey(0), net)
    vol = _volume(net, 1, rng)
    got = tiled_apply(params, net, vol, _mpf_prims(net), 1, batch=2)
    np.testing.assert_allclose(got, _dense(params, net, vol), atol=1e-3)


@pytest.mark.parametrize("name", ["toy337", "toy726"])
def test_tiled_plain_pool_matches_dense(name, rng):
    """Plain-pool plans sweep all P³ subsamplings (the naive outer loop)."""
    net = TOY_NETS[name]
    params = convnet.init_params(jax.random.PRNGKey(1), net)
    vol = _volume(net, 1, rng)
    got = tiled_apply(params, net, vol, _pool_prims(net), 1, batch=2)
    np.testing.assert_allclose(got, _dense(params, net, vol), atol=1e-3)


def test_plan_bound_executor_matches_dense(rng):
    """planner.Plan -> PlanExecutor binding (geometry from the plan)."""
    net = TOY_NETS["toy337"]
    plan = planner.plan_single(net, TPU_V5E, max_m=2, batches=(2,))
    assert plan is not None and plan.uses_mpf
    assert plan.patch_extent == plan.n_in  # MPF: extent is the plan's n_in
    params = convnet.init_params(jax.random.PRNGKey(2), net)
    vol = _volume(net, plan.m_final, rng)
    ex = PlanExecutor(params, net, plan)
    got = ex.run(vol)
    np.testing.assert_allclose(got, _dense(params, net, vol), atol=1e-3)
    s = ex.last_stats
    assert s["patches"] >= 4 and s["measured_voxps"] > 0
    assert s["out_voxels"] == float(np.prod(got.shape[1:]))


def test_pipeline2_executor_matches_dense(rng):
    """pipeline2 plans route through the two-stage scan (pod axis)."""
    net = TOY_NETS["toy726"]
    plan = planner.plan_pipeline2(net, TPU_V5E, chips_per_stage=1, max_m=1)
    assert plan is not None and 0 < plan.theta < len(net.layers)
    params = convnet.init_params(jax.random.PRNGKey(3), net)
    vol = _volume(net, plan.m_final, rng, extra=(1, 0, 0))
    ex = PlanExecutor(params, net, plan)
    got = ex.run(vol)
    np.testing.assert_allclose(got, _dense(params, net, vol), atol=1e-3)


@pytest.mark.slow
def test_pipeline2_multidevice_stream_realigns():
    """2 fake pods: the ring hand-off's outputs land on the right patches."""
    from tests.conftest import run_with_devices

    out = run_with_devices(
        """
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
        from repro.core import convnet, planner
        from repro.core.hw import TPU_V5E
        from repro.volume import PlanExecutor
        net = ConvNetConfig("t", 1, (L("conv",3,4), L("pool",2), L("conv",3,4), L("conv",2,2)))
        plan = planner.plan_pipeline2(net, TPU_V5E, chips_per_stage=1, max_m=1)
        params = convnet.init_params(jax.random.PRNGKey(0), net)
        rng = np.random.default_rng(0)
        fov, core = plan.fov, plan.core
        vol = rng.normal(size=(1, 2*core+1+fov-1, 2*core+fov-1, core+fov-1)).astype(np.float32)
        got = PlanExecutor(params, net, plan).run(vol)
        want = np.asarray(convnet.apply_dense_reference(params, net, jnp.asarray(vol)[None])[0])
        np.testing.assert_allclose(got, want, atol=1e-3)
        print("OK", got.shape)
        """,
        2,
    )
    assert "OK" in out


# -- serving engine ---------------------------------------------------------


def test_volume_engine_serves_mixed_requests(rng):
    net = TOY_NETS["toy337"]
    plan = planner.plan_single(net, TPU_V5E, max_m=1, batches=(4,))
    params = convnet.init_params(jax.random.PRNGKey(4), net)
    eng = VolumeEngine(params, net, plan)
    fov, core = plan.fov, plan.core
    vols = [
        rng.normal(size=(1, 2 * core + fov - 1, core + 2 + fov - 1, core + fov - 1)).astype(np.float32),
        rng.normal(size=(1, core + fov - 1, core + fov - 1, core + fov - 3)).astype(np.float32),
    ]
    reqs = [VolumeRequest(i, v) for i, v in enumerate(vols)]
    for r in reqs:
        eng.submit(r)
    total_patches = len(eng.queue)
    eng.run_until_drained()
    for r, v in zip(reqs, vols):
        assert r.done
        np.testing.assert_allclose(r.out, _dense(params, net, v), atol=1e-3)
    # continuous batching: patches of both requests share fused steps
    assert eng.ticks == -(-total_patches // eng.batch)


def test_volume_engine_accepts_explicit_prims(rng):
    net = TOY_NETS["toy726"]
    params = convnet.init_params(jax.random.PRNGKey(5), net)
    eng = VolumeEngine(params, net, prims=_mpf_prims(net), m=1, batch=2)
    vol = _volume(net, 1, rng, extra=(0, 0, 0))
    req = VolumeRequest(0, vol)
    eng.submit(req)
    eng.run_until_drained()
    np.testing.assert_allclose(req.out, _dense(params, net, vol), atol=1e-3)
