"""Tuned-config store + dispatch rule + executor/engine auto-load."""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs.base import ConvLayerSpec as L
from repro.configs.base import ConvNetConfig
from repro.configs.znni_nets import BENCH_NET, net_by_name
from repro.core import convnet
from repro.kernels import backend_supports_pallas, resolve_use_pallas
from repro.serving.volume_engine import VolumeEngine
from repro.tuning import (
    TunedConfig,
    config_path,
    load_tuned_config,
    normalize_device_kind,
    save_tuned_config,
)
from repro.tuning.xla_flags import bundle_flags, bundles_for, xla_flags_env
from repro.volume import PlanExecutor

NET = ConvNetConfig(
    name="tune-test-net",
    in_channels=2,
    layers=(L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2),
            L("conv", 3, 3)),
)
PRIMS = ("overlap_save", "mpf", "fft_cached", "mpf", "fft_cached")


# --------------------------------------------------------------------------
# dispatch rule
# --------------------------------------------------------------------------


def test_resolve_use_pallas_rule():
    # None -> backend detection; explicit bools always win
    assert resolve_use_pallas(None) == backend_supports_pallas()
    assert resolve_use_pallas(True) is True
    assert resolve_use_pallas(False) is False
    # this container is CPU: the Pallas path must NOT be the default
    assert jax.default_backend() != "tpu"
    assert backend_supports_pallas() is False


# --------------------------------------------------------------------------
# store round-trip
# --------------------------------------------------------------------------


def test_config_round_trip(tmp_path):
    cfg = TunedConfig(
        device_kind="cpu", net="tune-test-net", m=2, batch=1,
        fprime_chunk=4, use_pallas=False, fuse_pairs=True, seg_core=8,
        xla_flags="none", measured_voxps=123.0, tuned_at="2026-08-07",
    )
    path = save_tuned_config(cfg, root=tmp_path)
    assert path == config_path("tune-test-net", "cpu", root=tmp_path)
    assert load_tuned_config("tune-test-net", "cpu", root=tmp_path) == cfg
    # missing -> None, not an error
    assert load_tuned_config("no-such-net", "cpu", root=tmp_path) is None
    # a future schema version is ignored rather than misread
    payload = json.loads(path.read_text())
    payload["schema_version"] = 999
    path.write_text(json.dumps(payload))
    assert load_tuned_config("tune-test-net", "cpu", root=tmp_path) is None


def test_normalize_device_kind():
    assert normalize_device_kind("cpu") == "cpu"
    assert normalize_device_kind("NVIDIA H100 80GB HBM3") == "nvidia-h100-80gb-hbm3"
    assert normalize_device_kind("TPU v5e") == "tpu-v5e"
    # current process's device resolves to something non-empty and stable
    assert normalize_device_kind() == normalize_device_kind()


def test_provenance_shape():
    cfg = TunedConfig(device_kind="cpu", net="x", fuse_pairs=True)
    p = cfg.provenance()
    assert p["device_kind"] == "cpu" and p["net"] == "x"
    assert p["fuse_pairs"] is True
    assert set(p) <= {f.name for f in dataclasses.fields(TunedConfig)}


def test_committed_bench_config_loads():
    """The repo ships an autotuned config for (cpu, bench-net) — the one CI
    machines (cpu device kind) auto-load for the fused_tuned bench row."""
    cfg = load_tuned_config(BENCH_NET.name, "cpu")
    assert cfg is not None
    assert cfg.net == BENCH_NET.name and cfg.device_kind == "cpu"
    assert cfg.source == "autotune"
    assert cfg.measured_voxps and cfg.measured_voxps > 0


def test_net_by_name():
    assert net_by_name("bench-net") is BENCH_NET
    assert net_by_name("n537").name == "n537"
    with pytest.raises(ValueError, match="unknown net"):
        net_by_name("n000")


# --------------------------------------------------------------------------
# XLA flag bundles
# --------------------------------------------------------------------------


def test_xla_flag_bundles():
    assert "none" in bundles_for("cpu")
    assert "cpu-multithread" in bundles_for("cpu")
    assert "tpu-latency-hiding" not in bundles_for("cpu")
    assert bundle_flags("none") == ()
    env = xla_flags_env("cpu-multithread", base="--existing_flag=1")
    assert env.startswith("--existing_flag=1 ")
    with pytest.raises(ValueError, match="unknown XLA flag bundle"):
        bundle_flags("nope")


# --------------------------------------------------------------------------
# executor / engine auto-load
# --------------------------------------------------------------------------


def _tuned(tmp_path, **kw):
    cfg = TunedConfig(device_kind=normalize_device_kind(),
                      net="tune-test-net", **kw)
    save_tuned_config(cfg, root=tmp_path)
    return cfg


def test_executor_applies_tuned_config(rng):
    """An explicit TunedConfig fills the knobs the caller left unset; the
    executor's compiled plan reflects them and output matches untuned."""
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    cfg = TunedConfig(
        device_kind=normalize_device_kind(), net=NET.name,
        m=2, batch=1, fprime_chunk=2, use_pallas=False, fuse_pairs=True,
    )
    ex = PlanExecutor(params, NET, prims=PRIMS, tuned=cfg)
    assert ex.m == 2 and ex.batch == 1
    assert ex.fuse_pairs is True and ex.use_pallas is False
    assert ex.compiled.fuse_pairs is True
    fft_cached = [pl for pl in ex.compiled.layers if pl.prim == "fft_cached"]
    assert fft_cached and all(pl.fprime_chunk == 2 for pl in fft_cached)
    assert ex.tuned_provenance()["fuse_pairs"] is True

    base = PlanExecutor(params, NET, prims=PRIMS, m=2, batch=1, tuned=None)
    assert base.tuned is None and base.tuned_provenance() is None
    assert base.fuse_pairs is False  # CPU default: unfused
    vol = rng.normal(size=(NET.in_channels, 30, 26, 26)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(ex.run(vol)), np.asarray(base.run(vol)), atol=2e-5, rtol=1e-5
    )


def test_executor_caller_knobs_beat_tuned():
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    cfg = TunedConfig(
        device_kind=normalize_device_kind(), net=NET.name,
        m=2, batch=4, fuse_pairs=True, fprime_chunk=2,
    )
    ex = PlanExecutor(params, NET, prims=PRIMS, m=1, batch=2,
                      fuse_pairs=False, fprime_chunk=3, tuned=cfg)
    assert ex.m == 1 and ex.batch == 2
    assert ex.fuse_pairs is False
    fft_cached = [pl for pl in ex.compiled.layers if pl.prim == "fft_cached"]
    assert fft_cached and all(pl.fprime_chunk == 3 for pl in fft_cached)


def test_engine_auto_loads_tuned_config(tmp_path, monkeypatch, rng):
    """tuned="auto" loads the persisted config for (device kind, net.name)
    through VolumeEngine — the serving path the acceptance pins."""
    from repro.tuning import store

    monkeypatch.setattr(store, "CONFIG_DIR", tmp_path)
    _tuned(tmp_path, m=2, batch=1, fuse_pairs=True, fprime_chunk=2)
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    eng = VolumeEngine(params, NET, prims=PRIMS)
    ex = eng.executor
    assert ex.tuned is not None and ex.m == 2
    assert ex.fuse_pairs is True and ex.compiled.fuse_pairs is True
    # a net with no persisted config falls back to defaults
    other = ConvNetConfig(name="untuned-net", in_channels=NET.in_channels,
                          layers=NET.layers)
    eng2 = VolumeEngine(params, other, prims=PRIMS, m=2)
    assert eng2.executor.tuned is None
    assert eng2.executor.fuse_pairs is False
