"""Hypothesis shim: real hypothesis when installed, deterministic grid else.

The container image does not ship ``hypothesis``; instead of skipping the
property tests wholesale (``pytest.importorskip`` would drop the core
assertions too), test modules import ``given/settings/st`` from here.  When
hypothesis is importable we re-export it untouched.  Otherwise we provide a
tiny deterministic fallback: each strategy exposes a small sample grid
(endpoints + midpoint) and ``@given`` runs the test over a bounded,
deterministic slice of the cartesian product — so every property still gets
exercised on its boundary cases on machines without hypothesis.

Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``sampled_from``, ``lists``.
"""

from __future__ import annotations

import functools
import inspect
import itertools
import math

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _MAX_COMBOS = 16  # cap per test: endpoints-first deterministic slice

    class _Strategy:
        def __init__(self, samples):
            seen, out = set(), []
            for s in samples:
                key = repr(s)
                if key not in seen:
                    seen.add(key)
                    out.append(s)
            self.samples = out

    class _St:
        """Namespace mirroring ``hypothesis.strategies``."""

        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def floats(min_value, max_value):
            if min_value > 0:
                mid = math.sqrt(min_value * max_value)
            else:
                mid = (min_value + max_value) / 2
            return _Strategy([min_value, mid, max_value])

        @staticmethod
        def sampled_from(elements):
            xs = list(elements)
            return _Strategy([xs[0], xs[len(xs) // 2], xs[-1]])

        @staticmethod
        def lists(elem, min_size=0, max_size=None):
            es = elem.samples
            if max_size is None:
                max_size = min_size + 2
            lo = [es[0]] * min_size
            hi = [es[i % len(es)] for i in range(max_size)]
            mid_len = (min_size + max_size) // 2
            mid = [es[(i + 1) % len(es)] for i in range(mid_len)]
            return _Strategy([lo, mid, hi])

    st = _St()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco

    def given(*args, **kwargs):
        if args:
            raise TypeError("fallback @given supports keyword strategies only")
        names = list(kwargs)

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*a, **kw):
                combos = list(
                    itertools.product(*(kwargs[n].samples for n in names))
                )
                step = max(1, len(combos) // _MAX_COMBOS)
                for combo in combos[::step]:
                    fn(*a, **dict(zip(names, combo)), **kw)

            # hide the strategy params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[
                    p for name, p in sig.parameters.items() if name not in names
                ]
            )
            return wrapper

        return deco
