"""Axis-generic sweeps: reuse and planning on any volume axis (ISSUE 10).

The tentpole acceptance properties of making the sweep machinery
axis-generic.  All sweep state — segment spectra, activation halos,
strips, slabs, shard windows — lives in the tiler's WORKING frame (the
permutation that brings the sweep axis to position 0), so for every
``sweep_axis`` in {x, y, z}:

* the dense-materialized executor equals the reference conv and the
  host-staged streaming executor equals the dense path **bitwise**, and
  ``predict_counts`` matches ``last_stats`` EXACTLY — across interior,
  shifted-edge, and ragged tilings at batch 1 and 3;
* an axis-a sweep is **bitwise** identical to an axis-0 sweep of the
  pre-permuted volume (the working-frame identity: the whole pre-ISSUE-10
  runtime is the sweep_axis=0 special case);
* the planner prices the sweep-count simulation per candidate axis and
  records the argmax on ``Plan.sweep_axis`` — on a thin slab the chosen
  axis strictly beats the forced-x fallback;
* mixed-axis requests batch safely in one ``VolumeEngine`` tick (sweep
  scopes of different axes never share cache keys), and the sharded
  fleet's ``HaloPackage`` parity holds for N ∈ {1, 2, 3} on a non-x axis
  with measured halo bytes exactly equal to ``predict_shard_handoff``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.serving import VolumeEngine, VolumeRequest
from repro.serving.sharded_engine import ShardedVolumeEngine
from repro.volume import PlanExecutor
from repro.volume.tiler import sweep_perm

NET = ConvNetConfig(
    "sweep-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()  # m = 1
AXES = (0, 1, 2)

# every axis has >= 2 planes so streaming/strips engage whatever axis
# sweeps; the anisotropy differs per shape so the three working frames
# are genuinely distinct tilings
SHAPES = {
    "interior": (4 * CORE + FOV - 1, 3 * CORE + FOV - 1, 2 * CORE + FOV - 1),
    "shifted": (3 * CORE + 1 + FOV - 1, 2 * CORE + FOV - 1, 2 * CORE + FOV - 1),
    "ragged": (3 * CORE + 2 + FOV - 1, 2 * CORE + 3 + FOV - 1, 2 * CORE + 1 + FOV - 1),
}

# x deliberately SHORT (single plane: zero interior strips on a forced-x
# sweep) and y long — the anisotropic case where the axis argmax pays
THIN_SLAB = (CORE + FOV - 1, 4 * CORE + 3 + FOV - 1, 2 * CORE + FOV - 1)

COUNTER_KEYS = (
    ("os_seg_fft", "seg_fft"),
    ("os_seg_hits", "seg_hits"),
    ("os_mad_segments", "mad_segments"),
    ("deep_strip_patches", "strip_patches"),
    ("deep_full_patches", "full_patches"),
)


def _dense(params, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, NET, jnp.asarray(vol)[None])[0]
    )


def _assert_counters_exact(stats, pred):
    for skey, pkey in COUNTER_KEYS:
        assert stats[skey] == getattr(pred, pkey), (skey, stats[skey], pred)


@pytest.fixture(scope="module")
def params():
    return convnet.init_params(jax.random.PRNGKey(0), NET)


# -- per-axis exactness: dense == reference, streamed == dense bitwise,
#    predicted counters == measured counters ---------------------------------


@pytest.mark.parametrize("axis", AXES)
@pytest.mark.parametrize("batch", [1, 3])
@pytest.mark.parametrize("shape", SHAPES.values(), ids=SHAPES.keys())
def test_axis_parity_and_counter_exactness(params, rng, shape, batch, axis):
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    dense = PlanExecutor(
        params, NET, prims=MIX, m=1, batch=batch, sweep_axis=axis
    )
    out_d = dense.run(vol)
    np.testing.assert_allclose(out_d, _dense(params, vol), atol=1e-3)
    pred = dense.predict_counts(shape)
    _assert_counters_exact(dense.last_stats, pred)
    # host-staged streaming on the same axis: bitwise-equal, and the
    # axis-aware memory model stays exact (within the 10% analytic-state
    # rounding the memory suite pins for axis 0)
    stream = PlanExecutor(
        params, NET, prims=MIX, m=1, batch=batch, streaming=True,
        sweep_axis=axis,
    )
    assert stream.streaming
    out_s = stream.run(vol)
    assert np.array_equal(out_d, out_s)
    _assert_counters_exact(stream.last_stats, pred)
    measured = stream.last_stats["peak_device_bytes"]
    predicted = stream.predict_memory(shape).device_bytes
    assert abs(measured - predicted) / predicted <= 0.10
    # scopes fully released on every axis
    assert not stream._sweeps and not stream._halo_caches


def test_working_frame_identity(params, rng):
    """An axis-a sweep IS the axis-0 sweep of the jointly permuted problem
    (volume AND conv weights brought into the working frame): outputs are
    bitwise equal after permuting back.  This pins the design — one
    working-frame code path (the pre-ISSUE-10 runtime, verbatim), no
    per-axis kernels."""
    from repro.volume.executor import _permute_conv_params

    shape = SHAPES["ragged"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    for axis in (1, 2):
        perm = sweep_perm(axis)
        vol_w = np.ascontiguousarray(
            np.transpose(vol, (0, 1 + perm[0], 1 + perm[1], 1 + perm[2]))
        )
        params_w = _permute_conv_params(params, NET, perm)
        ref = PlanExecutor(params_w, NET, prims=MIX, m=1, batch=3).run(vol_w)
        got = PlanExecutor(
            params, NET, prims=MIX, m=1, batch=3, sweep_axis=axis
        ).run(vol)
        inv = [perm.index(a) for a in range(3)]
        # same working frame -> identical op sequence -> identical bits
        assert np.array_equal(
            got, np.transpose(ref, (0, 1 + inv[0], 1 + inv[1], 1 + inv[2]))
        )


def test_per_run_axis_override(params, rng):
    """One executor serves sweeps on any axis: the per-run override
    compiles the off-axis states lazily and matches a natively-built
    executor bitwise; non-reuse plans reject the override."""
    shape = SHAPES["shifted"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ex = PlanExecutor(params, NET, prims=MIX, m=1, batch=3)
    ex.run(vol)
    got = ex.run(vol, sweep_axis=2)
    native = PlanExecutor(params, NET, prims=MIX, m=1, batch=3, sweep_axis=2)
    assert np.array_equal(got, native.run(vol))
    _assert_counters_exact(ex.last_stats, ex.predict_counts(shape, sweep_axis=2))
    assert not ex._sweeps and not ex._sweep_axes
    no_reuse = PlanExecutor(
        params, NET, prims=["fft_cached" if l.kind == "conv" else "mpf"
                            for l in NET.layers], m=1, batch=3,
    )
    with pytest.raises(ValueError, match="sweep_axis"):
        no_reuse.run(vol, sweep_axis=1)


# -- planner: per-axis pricing + argmax ---------------------------------------


def test_planner_picks_best_axis_on_thin_slab(params, rng):
    """The perf claim: on an anisotropic slab the argmax axis strictly
    beats forced-x (which has zero interior strips here), and the chosen
    plan's predicted counters still match the executor exactly."""
    auto = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=3, volume_shape=THIN_SLAB
    )
    forced = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=3, volume_shape=THIN_SLAB, sweep_axis=0
    )
    assert auto.sweep_axis != 0
    assert forced.sweep_axis == 0 and forced.sweep.strip_patches == 0
    assert auto.sweep.strip_patches > 0
    assert auto.throughput > forced.throughput
    ex = PlanExecutor(params, NET, auto)  # inherits the plan's axis
    assert ex.sweep_axis == auto.sweep_axis
    vol = rng.normal(size=(1,) + THIN_SLAB).astype(np.float32)
    out = ex.run(vol)
    np.testing.assert_allclose(out, _dense(params, vol), atol=1e-3)
    _assert_counters_exact(ex.last_stats, auto.sweep)


def test_plan_single_search_is_axis_aware():
    """``plan_single``'s sweep-aware search records the argmax axis and a
    geometry simulated ON that axis; a cubic volume dedupes to one
    candidate (axis 0) by working-frame symmetry."""
    thin = planner.plan_single(
        NET, TPU_V5E, batches=(2,), max_m=2, volume_shape=THIN_SLAB,
        conv_prims=("overlap_save",),
    )
    assert thin.sweep_axis == thin.geometry.sweep_axis != 0
    cube = planner.plan_single(
        NET, TPU_V5E, batches=(2,), max_m=2,
        volume_shape=(2 * CORE + FOV - 1,) * 3,
        conv_prims=("overlap_save",),
    )
    assert cube.sweep_axis == 0
    assert planner._axis_candidates((2 * CORE + FOV - 1,) * 3, "auto") == (0,)
    assert planner._axis_candidates(THIN_SLAB, "auto") == (0, 1, 2)
    assert planner._axis_candidates(THIN_SLAB, 2) == (2,)


# -- serving: mixed-axis ticks, sharded parity off-axis -----------------------


def _run_mixed_pair(params, vol_a, vol_b, batch):
    """Serve (A axis-1, B axis-2) on one engine; return (outs, strips, engine)."""
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=batch)
    strips = {1: [], 2: []}
    reqs = [
        VolumeRequest(
            rid=ax, volume=vol, sweep_axis=ax,
            on_strip=lambda lo, hi, s, ax=ax: strips[ax].append(s.copy()),
        )
        for ax, vol in ((1, vol_a), (2, vol_b))
    ]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    return [r.out.copy() for r in reqs], strips, eng


def test_mixed_axis_requests_batch_safely(params, rng):
    """Two queued requests sweeping different axes share ONE engine tick:
    separate sweep scopes, no cache-key collisions, strips streamed along
    each request's own sweep axis.

    Request A is a single patch (one 1-row chunk) that drains mid-batch,
    so B's first rows join A's tick — the tick genuinely batches two sweep
    axes.  Mixed ticks run the spectra-stack walk fallback (a different op
    sequence than solo single-token fused ticks), so the bitwise claims
    are *determinism* (an identical mixed run reproduces exactly) and
    *isolation* (A's output is bitwise independent of the other request's
    DATA sharing its tick); correctness vs the dense path is allclose.
    """
    batch = 4
    cube = (CORE + FOV - 1,) * 3
    shape_b = (2 * CORE + 1 + FOV - 1, CORE + FOV - 1, 3 * CORE + 2 + FOV - 1)
    vol_a = rng.normal(size=(1,) + cube).astype(np.float32)
    vol_b = rng.normal(size=(1,) + shape_b).astype(np.float32)
    vol_b2 = rng.normal(size=(1,) + shape_b).astype(np.float32)
    out1, strips, eng = _run_mixed_pair(params, vol_a, vol_b, batch)
    # correctness: both requests match the dense reference
    for out, vol in zip(out1, (vol_a, vol_b)):
        np.testing.assert_allclose(
            out, _dense(params, vol), rtol=0, atol=2e-3
        )
    # strips concatenated along THIS request's sweep axis rebuild out
    for ax, out in ((1, out1[0]), (2, out1[1])):
        assert np.array_equal(np.concatenate(strips[ax], axis=1 + ax), out)
    # the tick shared: fewer ticks than the two solo drains would need
    # (A alone is 1 tick; B alone is 4 plane-capped ticks)
    assert eng.ticks <= 4
    ex = eng.executor
    assert not ex._sweeps and not ex._sweep_axes  # all scopes closed
    # determinism: an identical mixed run is bitwise-identical
    out2, _, _ = _run_mixed_pair(params, vol_a, vol_b, batch)
    for a, b in zip(out1, out2):
        assert np.array_equal(a, b)
    # isolation: swapping B's DATA (same shape/axis) cannot perturb a
    # single bit of A's output — no cache-key collisions across scopes
    out3, _, _ = _run_mixed_pair(params, vol_a, vol_b2, batch)
    assert np.array_equal(out1[0], out3[0])
    assert not np.array_equal(out1[1], out3[1])  # B really changed
    # non-reuse engines reject off-axis requests loudly, not silently
    no_reuse = VolumeEngine(
        params, NET, prims=["fft_cached" if l.kind == "conv" else "mpf"
                            for l in NET.layers], m=1, batch=2,
    )
    with pytest.raises(ValueError, match="sweep_axis"):
        no_reuse.submit(VolumeRequest(rid=9, volume=vol_a, sweep_axis=1))


@pytest.mark.parametrize("n_workers", [1, 2, 3])
def test_sharded_halo_parity_on_nonx_axis(params, rng, n_workers):
    """Sharded fleet on a y-axis sweep: bitwise equal to the single-device
    engine on the same axis for N in {1,2,3}, measured halo bytes ==
    ``predict_shard_handoff`` exactly, zero faults."""
    shape = (2 * CORE + FOV - 1, 3 * CORE + 2 + FOV - 1, CORE + 1 + FOV - 1)
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ref_eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=3)
    ref = VolumeRequest(rid=0, volume=vol, sweep_axis=1)
    ref_eng.submit(ref)
    ref_eng.run_until_drained()
    fleet = ShardedVolumeEngine(
        params, NET, prims=MIX, m=1, batch=3,
        n_workers=n_workers, sweep_axis=1,
    )
    req = VolumeRequest(rid=0, volume=vol)
    fleet.submit(req)
    fleet.run_until_drained()
    assert np.array_equal(req.out, ref.out)
    st = fleet.last_stats
    assert st["halo_bytes_in"] == st["predicted_halo_bytes_in"]
    assert st["redispatches"] == 0 and st["duplicates_dropped"] == 0
    if n_workers > 1:
        assert st["halo_exchange_bytes"] > 0  # the boundary really handed off
