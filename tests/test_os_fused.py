"""Fused overlap-save segment kernel + halo-emitting strip epilogue (ISSUE 9).

Three acceptance properties:

1. The fused segment kernel (``kernels.os_segment``, interpret mode on
   CPU) matches its pure-jnp XLA oracle AND the unfused
   ``os_apply_from_spectra`` / ``os_apply_tail_from_spectra`` /
   ``overlap_save_conv`` chain across ragged tails, shifted output edges
   (tail-only MAD with a lead crop), odd channel counts, and every
   ``fprime_chunk`` in {None, 1, 3}.

2. ``fuse_os`` is *invisible* off the Pallas path: the executor's fused
   capture/strip walks produce BITWISE-identical output to the unfused
   walks (the fused epilogue runs literally the same op sequence —
   relu∘max == max∘relu), the ``fused_pair_calls`` counter equals the
   sweep prediction exactly, and the boundary ``HaloPackage`` a sharded
   worker exports is bit-for-bit the one the unfused engine exports.

3. The tuner's cost-model shortlist is a subset of the full candidate
   grid, ``fuse_os`` is only swept on top of ``fuse_pairs``, per-conv
   ``fprime_chunk`` schedules expand to per-absolute-layer tuples, and a
   schema-v2 ``TunedConfig`` (tuple schedule + ``fuse_os``) round-trips
   through save/load while v1 files still load and future schemas are
   ignored.
"""

import json

import numpy as np

import jax
import jax.numpy as jnp

import pytest

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, primitives
from repro.core.fft_conv import precompute_kernel_fft
from repro.core.overlap_save import (
    os_apply_from_spectra,
    os_apply_tail_from_spectra,
    os_input_spectra,
    overlap_save_conv,
    plan_overlap_save,
    tail_segments,
)
from repro.kernels.os_segment import ops as seg_ops
from repro.kernels.os_segment import ref as seg_ref
from repro.serving import ShardedVolumeEngine, VolumeRequest
from repro.tuning.autotune import (
    build_candidate_grid,
    expand_fprime_schedule,
    shortlist_candidates,
)
from repro.tuning.store import TunedConfig, load_tuned_config, save_tuned_config
from repro.volume.executor import PlanExecutor

# Pallas-vs-XLA float tolerance (matmul-DFT vs jnp.fft accumulation
# order); same budget as tests/test_kernels.py.
TOL = dict(atol=1e-3, rtol=1e-4)

# -- 1. fused segment kernel vs oracle vs unfused ---------------------------

# (input extent, kernel, seg_core): ragged tail (tail_len < seg_core),
# exact tail, and a longer grid whose tail window needs input zero-padding
SPECS = {
    "ragged": ((9, 6, 6), (3, 3, 3), 4),
    "exact": ((10, 6, 6), (3, 3, 3), 4),
    "padded": ((13, 5, 7), (3, 3, 3), 5),
}
CHUNKS = (None, 1, 3)


def _problem(name, f=3, fp=5, S=2, seed=0):
    """Spec + raw input + cached kernel spectra with ODD channel counts."""
    n, k, seg_core = SPECS[name]
    spec = plan_overlap_save(n, k, seg_core)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(S, f) + n).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f) + k).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.normal(size=(fp,)).astype(np.float32))
    W = precompute_kernel_fft(w, spec.fft_shape)
    return spec, x, w, b, W


@pytest.mark.parametrize("fc", CHUNKS, ids=lambda c: f"chunk={c}")
@pytest.mark.parametrize("name", sorted(SPECS))
def test_fused_full_grid_matches_oracle_and_unfused(name, fc):
    spec, x, w, b, W = _problem(name)
    F = os_input_spectra(x, spec)
    want = os_apply_from_spectra(F, W, b, spec, use_pallas=False)
    oracle = seg_ref.os_segment_fused(F, W, b, spec)
    got = seg_ops.os_segment_fused(F, W, b, spec, fprime_chunk=fc, use_pallas=True)
    assert got.shape == want.shape == oracle.shape
    # the oracle IS the unfused math (DC-bin bias == spatial bias)
    np.testing.assert_allclose(oracle, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("fc", CHUNKS, ids=lambda c: f"chunk={c}")
@pytest.mark.parametrize("name", sorted(SPECS))
def test_fused_tail_shifted_edges(name, fc):
    """Trailing-segment MAD with a lead crop — the strip path's form."""
    spec, x, w, b, W = _problem(name, seed=1)
    F = os_input_spectra(x, spec)
    s = spec.seg_core
    # out_cols sweep: one core (deep strip), a shifted edge (not
    # core-aligned), and the full extent (degenerates to the full grid)
    for out_cols in sorted({s, min(s + 1, spec.out[0]), spec.out[0]}):
        q = tail_segments(spec, out_cols)
        Ft = F[:, spec.n_segments - q :]
        want = os_apply_tail_from_spectra(
            Ft, W, b, spec, out_cols, use_pallas=False
        )
        got = seg_ops.os_segment_fused_tail(
            Ft, W, b, spec, out_cols, fprime_chunk=fc, use_pallas=True
        )
        assert got.shape == want.shape == (x.shape[0], W.shape[0], out_cols) + spec.out[1:]
        np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("fc", CHUNKS, ids=lambda c: f"chunk={c}")
@pytest.mark.parametrize("name", sorted(SPECS))
def test_fused_conv_in_kernel_fft(name, fc):
    """Self-contained form: the miss-segment FFT runs inside the kernel."""
    spec, x, w, b, W = _problem(name, seed=2)
    want = overlap_save_conv(x, W, b, spec, use_pallas=False)
    oracle = seg_ref.os_segment_conv(x, W, b, spec)
    got = seg_ops.os_segment_conv(x, W, b, spec, fprime_chunk=fc, use_pallas=True)
    np.testing.assert_allclose(oracle, want, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(got, want, **TOL)


# -- 2. executor strip-path parity ------------------------------------------

NET = ConvNetConfig(
    "osfused-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()


@pytest.fixture(scope="module")
def params():
    return convnet.init_params(jax.random.PRNGKey(0), NET)


def _vol(seed, xc, extra=(0, 0, 0)):
    rng = np.random.default_rng(seed)
    shape = (
        xc * CORE + extra[0] + FOV - 1,
        CORE + extra[1] + FOV - 1,
        CORE + extra[2] + FOV - 1,
    )
    return rng.normal(size=(1,) + shape).astype(np.float32)


@pytest.mark.parametrize(
    "extra", [(0, 0, 0), (3, 1, 2)], ids=["interior", "ragged"]
)
def test_executor_fuse_os_bitwise_parity(params, extra):
    """Fused capture/strip walks == unfused walks BITWISE, and the
    fused-pair counter matches the sweep prediction exactly."""
    vol = _vol(3, 4, extra)
    ex_f = PlanExecutor(params, NET, prims=MIX, m=1, batch=3, tuned=None,
                        fuse_os=True)
    ex_u = PlanExecutor(params, NET, prims=MIX, m=1, batch=3, tuned=None)
    assert ex_f.fuse_os and not ex_u.fuse_os
    assert ex_f._fused_pairs == (2,)  # conv@2 (fft_cached) + pool@3 (mpf)
    out_f = ex_f.run(vol)
    out_u = ex_u.run(vol)
    assert np.array_equal(np.asarray(out_f), np.asarray(out_u))
    c = ex_f.predict_counts(vol.shape[1:])
    stats = ex_f.last_stats
    assert stats["fused_pair_calls"] == (
        (c.strip_patches + c.full_patches) * len(ex_f._fused_pairs)
    )
    assert stats["fused_pair_calls"] > 0
    # XLA path: the OS segment kernel never dispatched
    if not ex_f.use_pallas:
        assert stats["os_fused_segments"] == 0
    assert ex_u.last_stats["fused_pair_calls"] == 0


def _record_exports(eng):
    """Wrap every worker's export_handoff to capture boundary packages."""
    recs = []
    for w in eng.workers:
        orig = w.executor.export_handoff

        def wrapped(token, x_lo, _orig=orig, _acc=recs):
            pkg = _orig(token, x_lo)
            _acc.append(pkg)
            return pkg

        w.executor.export_handoff = wrapped
    return recs


def test_sharded_halo_package_parity(params):
    """N=2 sharded engine: fused-vs-unfused outputs bitwise equal AND the
    exported boundary HaloPackage is bit-for-bit identical."""
    vol = _vol(7, 5)
    outs, pkgs = {}, {}
    for fos in (False, True):
        eng = ShardedVolumeEngine(
            params, NET, n_workers=2, prims=MIX, m=1, batch=3, tuned=None,
            fuse_os=fos,
        )
        recs = _record_exports(eng)
        req = VolumeRequest(0, vol)
        eng.submit(req)
        eng.run_until_drained()
        assert req.done
        outs[fos] = np.asarray(req.out)
        pkgs[fos] = recs
    assert np.array_equal(outs[True], outs[False])
    assert len(pkgs[True]) == len(pkgs[False]) >= 1
    for a, b in zip(pkgs[True], pkgs[False]):
        assert a.x_lo == b.x_lo
        assert set(a.spectra) == set(b.spectra)
        assert set(a.halos) == set(b.halos)
        assert a.nbytes == b.nbytes
        for key in a.spectra:
            assert np.array_equal(a.spectra[key], b.spectra[key])
        for key in a.halos:
            assert len(a.halos[key]) == len(b.halos[key])
            for ha, hb in zip(a.halos[key], b.halos[key]):
                assert np.array_equal(ha, hb)


# -- 3. tuner shortlist + schema v2 -----------------------------------------


def test_candidate_grid_gates_fuse_os_on_fuse_pairs():
    grid = build_candidate_grid(2, (1, 2), (None, 1), (False, True), (False, True))
    assert not any(c.fuse_os and not c.fuse_pairs for c in grid)
    assert any(c.fuse_os for c in grid)
    # the gate halves the (fuse, fuse_os) plane: 3 combos, not 4
    assert len(grid) == 2 * 2 * 2 * 3


def test_shortlist_is_subset_of_grid():
    grid = build_candidate_grid(2, (1, 2), (None, 2), (False, True), (False, True))
    short, plans = shortlist_candidates(NET, MIX, grid, 4, quick=True)
    assert 1 <= len(short) <= 4
    assert set(short) <= set(grid)
    for cand in short:
        assert (cand.m, cand.batch) in plans


def test_expand_fprime_schedule():
    # per-CONV entries land at conv positions; pools (and past-end) None
    assert expand_fprime_schedule(NET, (4, None, 2)) == (4, None, None, None, 2)
    assert expand_fprime_schedule(NET, (4,)) == (4, None, None, None, None)
    assert expand_fprime_schedule(NET, None) is None
    assert expand_fprime_schedule(NET, 8) == 8
    sched = expand_fprime_schedule(NET, (4, None, 2))
    assert primitives.layer_fprime_chunk(sched, 0) == 4
    assert primitives.layer_fprime_chunk(sched, 1) is None
    assert primitives.layer_fprime_chunk(sched, 4) == 2
    assert primitives.layer_fprime_chunk(sched, 99) is None
    assert primitives.layer_fprime_chunk(8, 3) == 8


def test_tuned_config_v2_roundtrip(tmp_path):
    cfg = TunedConfig(
        device_kind="cpu", net="osfused-toy", m=2, batch=3,
        fprime_chunk=(4, None, None, None, 2), fuse_pairs=True, fuse_os=True,
        measured_voxps=123.0,
    )
    save_tuned_config(cfg, root=tmp_path)
    back = load_tuned_config("osfused-toy", "cpu", root=tmp_path)
    assert back == cfg
    assert back.provenance()["fuse_os"] is True


def test_tuned_config_v1_and_future_schemas(tmp_path):
    # v1 file: scalar fprime_chunk, no fuse_os key -> loads with defaults
    p = tmp_path / "cpu__osfused-toy.json"
    p.write_text(json.dumps({
        "schema_version": 1, "device_kind": "cpu", "net": "osfused-toy",
        "m": 1, "batch": 2, "fprime_chunk": 4, "fuse_pairs": False,
    }))
    v1 = load_tuned_config("osfused-toy", "cpu", root=tmp_path)
    assert v1.fprime_chunk == 4 and v1.fuse_os is None
    # a FUTURE schema is ignored, never misread
    p.write_text(json.dumps({"schema_version": 99, "device_kind": "cpu",
                             "net": "osfused-toy"}))
    assert load_tuned_config("osfused-toy", "cpu", root=tmp_path) is None
