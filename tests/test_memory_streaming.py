"""Memory-budgeted planning + host-staged streaming execution (ISSUE 5).

Four layers of guarantees:

* **Streaming correctness** — the host-staged executor (volume in host
  RAM, double-buffered x-slab staging, per-plane spectra eviction) is
  bitwise-equal to the dense-materialized path across interior, shifted-
  edge, and ragged tilings at batch 1 and 3, and its measured
  ``peak_device_bytes`` never exceeds the budget it was given.
* **Memory model exactness** — ``Plan.memory`` (the planner's streaming-
  schedule simulation) lands within 10% of the executor's measured ledger
  peak, in both streaming and dense modes.
* **The paper's constrained optimization** — under a shrinking RAM
  budget the winning primitive changes because a faster primitive's
  working set no longer fits, and the rejected (prim, patch) points are
  reported with a reason instead of silently omitted.
* **Plane-capped chunking** (the ``batch > patches-per-x-plane``
  regression) — interior patches keep the deep-reuse strip path whatever
  the batch size, pinned on ``last_stats["deep_strip_patches"]``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.serving import VolumeEngine, VolumeRequest
from repro.volume import PlanExecutor

NET = ConvNetConfig(
    "stream-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()  # m = 1


def _dense(params, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, NET, jnp.asarray(vol)[None])[0]
    )


@pytest.fixture(scope="module")
def params():
    return convnet.init_params(jax.random.PRNGKey(0), NET)


# long-x interior, shifted x edge, and ragged y/z tilings
SHAPES = {
    "interior": (8 * CORE + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1),
    "shifted_x": (6 * CORE + 1 + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1),
    "ragged_yz": (5 * CORE + 2 + FOV - 1, CORE + 3 + FOV - 1, CORE + 1 + FOV - 1),
}


# -- streaming correctness ----------------------------------------------------


@pytest.mark.parametrize("shape", SHAPES.values(), ids=SHAPES.keys())
@pytest.mark.parametrize("batch", [1, 3])
def test_streamed_equals_dense_bitwise(params, rng, shape, batch):
    """Streamed execution == dense-materialized execution, bit for bit:
    the staged slab feeds the SAME dynamic-slice + FFT ops the resident
    volume would, so there is no tolerance to hide behind.  The streamed
    sweep also stays within the budget it declares and below the dense
    path's measured peak."""
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    dense = PlanExecutor(params, NET, prims=MIX, m=1, batch=batch)
    out_d = dense.run(vol)
    peak_dense = dense.last_stats["peak_device_bytes"]
    # budget strictly below the dense footprint, above the streaming one
    stream_pred = planner.plan_stream_memory(
        NET, MIX, 1, shape, batch=batch
    ).device_bytes
    assert stream_pred < peak_dense
    budget = (stream_pred + peak_dense) / 2
    stream = PlanExecutor(
        params, NET, prims=MIX, m=1, batch=batch, ram_budget=budget
    )
    assert stream.streaming
    out_s = stream.run(vol)
    assert np.array_equal(out_d, out_s)
    s = stream.last_stats
    assert s["peak_device_bytes"] <= budget < peak_dense
    # reuse accounting is identical in both modes
    for key in ("os_seg_fft", "os_seg_hits", "os_mad_segments",
                "deep_strip_patches", "deep_full_patches"):
        assert s[key] == dense.last_stats[key], key
    # sweep scopes fully released (host copies, slabs, caches)
    assert not stream._sweep_hosts and not stream._sweep_slabs
    assert not stream._sweeps and not stream._halo_caches
    assert not stream._key_bytes


def test_dense_footprint_over_budget_still_completes(params, rng):
    """The acceptance scenario: a volume whose dense device footprint
    exceeds the budget runs to completion through the streaming executor,
    output exact, measured peak within budget."""
    shape = SHAPES["interior"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    dense_pred = planner.plan_stream_memory(
        NET, MIX, 1, shape, batch=2, streaming=False
    ).device_bytes
    stream_pred = planner.plan_stream_memory(
        NET, MIX, 1, shape, batch=2, streaming=True
    ).device_bytes
    budget = (stream_pred + dense_pred) / 2
    plan = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=2, volume_shape=shape,
        ram_budget=budget,
    )
    assert plan is not None and plan.ram_budget == budget
    ex = PlanExecutor(params, NET, plan)  # streaming via plan.ram_budget
    assert ex.streaming
    out = ex.run(vol)
    np.testing.assert_allclose(out, _dense(params, vol), atol=1e-3)
    assert ex.last_stats["peak_device_bytes"] <= budget < dense_pred


# -- memory model exactness ---------------------------------------------------


@pytest.mark.parametrize("streaming", [True, False], ids=["stream", "dense"])
@pytest.mark.parametrize("batch", [1, 3])
def test_predicted_memory_within_ten_percent(params, rng, streaming, batch):
    """``Plan.memory`` / ``predict_memory`` vs. the measured ledger peak:
    within 10% (in practice they agree exactly — both sides count the
    same objects at the same schedule points)."""
    shape = SHAPES["shifted_x"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ex = PlanExecutor(
        params, NET, prims=MIX, m=1, batch=batch, streaming=streaming
    )
    pred = ex.predict_memory(shape).device_bytes
    ex.run(vol)
    meas = ex.last_stats["peak_device_bytes"]
    assert meas > 0
    assert abs(pred - meas) / meas <= 0.10, (pred, meas)
    assert ex.last_stats["predicted_peak_device_bytes"] == pred


def test_plan_memory_prediction_matches_measured(params, rng):
    """End to end through the planner: a plan solved under a budget for a
    concrete volume carries the footprint the executor then measures."""
    shape = SHAPES["interior"]
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    plan = planner.plan_fixed(
        NET, TPU_V5E, MIX, m=1, batch=2, volume_shape=shape,
        ram_budget=float("inf"),
    )
    ex = PlanExecutor(params, NET, plan)
    ex.run(vol)
    meas = ex.last_stats["peak_device_bytes"]
    pred = plan.memory.device_bytes
    assert abs(pred - meas) / meas <= 0.10, (pred, meas)


def test_memory_footprint_fields_are_consistent():
    from repro.core.cost_model import MemoryFootprint

    m = MemoryFootprint(1.0, 2.0, 3.0, 4.0, 5.0)
    assert m.device_bytes == 15.0
    w = m.worst(MemoryFootprint(10.0, 0.0, 0.0, 0.0, 0.0))
    assert (w.input_bytes, w.output_bytes) == (10.0, 2.0)


# -- the constrained optimization (paper crossover) ---------------------------


def test_ram_budget_changes_the_winning_primitive():
    """The paper's headline tradeoff, at the ``plan_all_strategies``
    surface: at some budget the winning primitive changes because a
    faster primitive's working set no longer fits — and the rejected
    point is REPORTED, not silently dropped."""
    from repro.configs import ZNNI_NETS

    net = ZNNI_NETS["n537"]
    free = planner.plan_all_strategies(net, TPU_V5E, chips=4)["single"]
    assert free is not None and free.memory is not None
    flipped = None
    for frac in (0.5, 0.25):
        budget = free.memory.device_bytes * frac
        out = planner.plan_all_strategies(
            net, TPU_V5E, chips=4, ram_budget=budget
        )
        constrained = out["single"]
        if constrained is None or constrained.prims == free.prims:
            continue
        flipped = (free, constrained, out["infeasible"], budget)
        break
    assert flipped is not None, "no budget flipped the winner"
    free_p, con_p, pts, budget = flipped
    changed = [
        (i, a, b) for i, (a, b) in enumerate(zip(free_p.prims, con_p.prims))
        if a != b
    ]
    assert changed
    # the unconstrained winner's primitive was rejected AT THE WINNING
    # PATCH SIZE for exceeding the budget — that is WHY the winner changed
    rejected = {
        (p.prim, p.m) for p in pts
        if p.reason == "exceeds ram_budget" and p.strategy == "single"
    }
    assert any((a, con_p.m_final) in rejected or (a, free_p.m_final) in rejected
               for _, a, _ in changed), (changed, sorted(rejected)[:10])
    for p in pts:
        assert p.reason == "exceeds ram_budget"
        assert p.needed_bytes > p.budget_bytes == budget


def test_plan_all_strategies_reports_infeasible_points():
    """Rectangular reporting: the dict always carries the ``infeasible``
    key; under a budget the rejected (prim, m) points appear with byte
    evidence, without one the tuple is empty."""
    out_free = planner.plan_all_strategies(NET, TPU_V5E, chips=4)
    assert out_free["infeasible"] == ()
    budget = 1e6
    out = planner.plan_all_strategies(NET, TPU_V5E, chips=4, ram_budget=budget)
    pts = out["infeasible"]
    assert pts, "a 1 MB budget must reject some (prim, patch) points"
    prims = {p.prim for p in pts}
    assert prims & {"fft_cached", "fft_task", "fft_data", "overlap_save"}
    for p in pts:
        assert p.reason == "exceeds ram_budget"
        assert p.strategy in ("single", "baseline_naive", "direct_only")
        assert p.m >= 1 and p.needed_bytes > budget


def test_infeasible_budget_returns_none_not_crash():
    pts = []
    plan = planner.plan_single(
        NET, TPU_V5E, batches=(1,), max_m=2, ram_budget=1.0, infeasible=pts
    )
    assert plan is None and pts


# -- plane-capped chunking (batch > patches-per-x-plane regression) -----------


def test_strip_path_survives_batch_larger_than_x_plane(params, rng):
    """ISSUE 5 satellite: with ``batch`` larger than the number of patches
    per x-plane, chunks are capped at the plane boundary, so interior
    patches keep the strip path instead of degrading to the full path."""
    # 4 aligned x-planes of 2 patches each; batch 4 would previously span
    # two planes per chunk and degrade the second plane to the full path
    shape = (4 * CORE + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1)
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    ex = PlanExecutor(params, NET, prims=MIX, m=1, batch=4)
    out = ex.run(vol)
    np.testing.assert_allclose(out, _dense(params, vol), atol=1e-3)
    s = ex.last_stats
    # every aligned interior patch runs the strip path: 3 planes x 2
    assert s["deep_strip_patches"] == 6
    assert s["deep_full_patches"] == 2  # the first plane only
    assert s["batches"] == 4  # one chunk per plane, not ceil(8/4) = 2
    pred = ex.predict_counts(shape)
    assert s["deep_strip_patches"] == pred.strip_patches
    assert s["os_seg_fft"] == pred.seg_fft
    assert s["os_mad_segments"] == pred.mad_segments


def test_chunk_patches_caps_at_plane_boundaries():
    from repro.volume.tiler import HaloSpec, chunk_patches, tile_volume

    halo = HaloSpec(CORE, CORE + 2, tuple(range(0, 20, CORE)))
    t = tile_volume(
        (3 * CORE + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1),
        core=CORE, fov=FOV, halo=halo,
    )
    chunks = chunk_patches(t, 4)
    for idxs in chunks:
        xs = {t.patches[i].start[0] for i in idxs}
        assert len(xs) == 1, "chunk spans x-planes"
        assert len(idxs) <= 4
    assert sorted(i for c in chunks for i in c) == list(range(t.n_patches))


# -- serving: streaming completion + shared device budget ---------------------


def test_engine_streams_final_output_strips(params, rng):
    """Strips finalize in order as their contributing planes complete;
    the concatenated strips equal the finished output exactly, and
    ``final_rows`` is monotone through the drain."""
    shape = (4 * CORE + FOV - 1, 2 * CORE + FOV - 1, CORE + FOV - 1)
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    strips = []
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=2)
    req = VolumeRequest(
        0, vol, on_strip=lambda lo, hi, s: strips.append((lo, hi, s.copy()))
    )
    eng.submit(req)
    last = 0
    while eng.step():
        assert req.final_rows >= last
        last = req.final_rows
    assert req.done and req.final_rows == req.out.shape[1]
    bounds = [(lo, hi) for lo, hi, _ in strips]
    assert bounds[0][0] == 0 and bounds[-1][1] == req.out.shape[1]
    assert all(a[1] == b[0] for a, b in zip(bounds, bounds[1:]))  # contiguous
    got = np.concatenate([s for _, _, s in strips], axis=1)
    np.testing.assert_array_equal(got, req.out)
    np.testing.assert_allclose(req.out, _dense(params, vol), atol=1e-3)


def test_engine_device_budget_bounds_concurrent_sweeps(params, rng):
    """With a shared device budget, the scheduler defers OPENING a second
    sweep until the first drains; without one, the tail tick overlaps
    both.  Results stay exact either way."""
    shape = (3 * CORE + FOV - 1, CORE + FOV - 1, CORE + FOV - 1)
    vols = [
        rng.normal(size=(1,) + shape).astype(np.float32) for _ in range(2)
    ]

    def drain(engine):
        # count sweep-scope concurrency at the begin/end boundary: a tick
        # that mixes two requests opens the second scope BEFORE the first
        # completes, so post-tick snapshots would miss the overlap
        ex = engine.executor
        live, peak_open = set(), [0]
        real_begin, real_end = ex.begin_sweep, ex.end_sweep

        def begin(padded, **kw):
            tok = real_begin(padded, **kw)
            live.add(tok)
            peak_open[0] = max(peak_open[0], len(live))
            return tok

        def end(tok):
            live.discard(tok)
            real_end(tok)

        ex.begin_sweep, ex.end_sweep = begin, end
        reqs = [VolumeRequest(i, v) for i, v in enumerate(vols)]
        for r in reqs:
            engine.submit(r)
        while engine.step():
            pass
        for r, v in zip(reqs, vols):
            assert r.done
            np.testing.assert_allclose(r.out, _dense(params, v), atol=1e-3)
        return peak_open[0]

    ex_probe = PlanExecutor(params, NET, prims=MIX, m=1, batch=2, streaming=True)
    est = ex_probe.sweep_bytes_estimate(
        ex_probe.bucket_shape(shape)
    )
    budget = ex_probe._ledger.current + est * 1.5  # one sweep fits, two don't
    tight = VolumeEngine(
        params, NET, prims=MIX, m=1, batch=2,
        ram_budget=budget, device_budget=budget,
    )
    assert drain(tight) == 1
    free = VolumeEngine(params, NET, prims=MIX, m=1, batch=2, streaming=True)
    assert drain(free) == 2
    assert tight.executor.last_stats["peak_device_bytes"] <= budget
