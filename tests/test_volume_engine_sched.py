"""VolumeEngine scheduling satellites (ISSUE 4): priority-ordered patch
queue with aging (starvation avoidance) and padded-volume shape bucketing
(bounded jit retraces across distinct request sizes)."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet
from repro.serving import VolumeEngine, VolumeRequest

NET = ConvNetConfig(
    "sched-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()


def _dense(params, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, NET, jnp.asarray(vol)[None])[0]
    )


def _vol(rng, xc=2, extra=(0, 0, 0)):
    shape = (
        xc * CORE + extra[0] + FOV - 1,
        CORE + extra[1] + FOV - 1,
        CORE + extra[2] + FOV - 1,
    )
    return rng.normal(size=(1,) + shape).astype(np.float32)


def test_priority_orders_the_patch_queue(rng):
    """A higher-priority request submitted later is served first; outputs
    stay exact for every request."""
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=2)
    lo = VolumeRequest(0, _vol(rng), priority=0)
    hi = VolumeRequest(1, _vol(rng), priority=5)
    eng.submit(lo)
    eng.submit(hi)
    assert eng.queue[0][0] is hi  # priority beats submission order
    order = []
    while eng.step():
        for r in (lo, hi):
            if r.done and r not in order:
                order.append(r)
    assert order == [hi, lo]
    for r in (lo, hi):
        np.testing.assert_allclose(
            r.out, _dense(params, np.asarray(r.volume)), atol=1e-3
        )


def test_aging_prevents_starvation(rng):
    """A low-priority request under a steady stream of high-priority
    arrivals still completes: waiting ages its effective priority up one
    level per ``age_ticks`` ticks, so it eventually outranks the stream."""
    params = convnet.init_params(jax.random.PRNGKey(1), NET)
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=4, age_ticks=2)
    lo = VolumeRequest(0, _vol(rng), priority=0)
    eng.submit(lo)
    for t in range(40):
        eng.submit(VolumeRequest(100 + t, _vol(rng), priority=5))
        eng.step()
        if lo.done:
            break
    assert lo.done, "low-priority request starved"
    np.testing.assert_allclose(lo.out, _dense(params, lo.volume), atol=1e-3)


def test_shape_bucketing_bounds_retraces(rng):
    """Requests whose padded shapes land in the same bucket add ZERO new
    jit specializations; results stay exact (pad-and-crop).  The
    unbucketed engine retraces for the new volume shape."""
    params = convnet.init_params(jax.random.PRNGKey(2), NET)
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=2)
    base = VolumeRequest(0, _vol(rng, xc=2))
    eng.submit(base)
    eng.run_until_drained()
    seen = eng.executor.last_stats["retraces"]
    assert seen > 0
    # a differently-sized request in the same bucket: no new traces
    again = VolumeRequest(1, _vol(rng, xc=2, extra=(-1, 0, 0)))
    eng.submit(again)
    eng.run_until_drained()
    assert eng.executor.last_stats["retraces"] == seen
    for r in (base, again):
        np.testing.assert_allclose(r.out, _dense(params, r.volume), atol=1e-3)
    # unbucketed: the same pair of shapes forces new specializations
    raw = VolumeEngine(
        params, NET, prims=MIX, m=1, batch=2, bucket_shapes=False
    )
    r0 = VolumeRequest(0, _vol(rng, xc=2))
    raw.submit(r0)
    raw.run_until_drained()
    seen_raw = raw.executor.last_stats["retraces"]
    r1 = VolumeRequest(1, _vol(rng, xc=2, extra=(-1, 0, 0)))
    raw.submit(r1)
    raw.run_until_drained()
    assert raw.executor.last_stats["retraces"] > seen_raw
    np.testing.assert_allclose(r1.out, _dense(params, r1.volume), atol=1e-3)


def test_bucketing_is_exact_for_undersized_axes(rng):
    """Volumes smaller than one patch bucket up to exactly one patch and
    crop back: the zero-pad-and-crop guarantee end to end.  Axes below
    the FOV keep the tiler's clear no-valid-output error (not a numpy
    negative-dimension crash)."""
    import pytest

    params = convnet.init_params(jax.random.PRNGKey(3), NET)
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=2)
    v = rng.normal(size=(1, FOV + 1, FOV, CORE + FOV - 1)).astype(np.float32)
    req = VolumeRequest(0, v)
    eng.submit(req)
    eng.run_until_drained()
    assert req.out.shape[1:] == (2, 1, CORE)
    np.testing.assert_allclose(req.out, _dense(params, v), atol=1e-3)
    bad = rng.normal(size=(1, FOV - 2, FOV, FOV)).astype(np.float32)
    with pytest.raises(ValueError, match="no valid output"):
        eng.submit(VolumeRequest(1, bad))


def test_same_payload_duplicate_requests_stay_distinct(rng):
    """Regression: VolumeRequest compares by identity (eq=False), so two
    requests with an identical payload — same rid, same volume array,
    same priority — are distinct queue entries.  Field-based equality
    made membership tests and live-list removal conflate them: finishing
    one "finished" both, and the second was dropped half-served."""
    params = convnet.init_params(jax.random.PRNGKey(4), NET)
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=2)
    vol = _vol(rng)
    a = VolumeRequest(7, vol, priority=1)
    b = VolumeRequest(7, vol, priority=1)  # same payload, different request
    assert a is not b and a != b
    eng.submit(a)
    eng.submit(b)
    assert len({id(e[0]) for e in eng.queue}) == 2  # both admitted, distinct
    eng.run_until_drained()
    assert a.done and b.done
    ref = _dense(params, vol)
    np.testing.assert_allclose(a.out, ref, atol=1e-3)
    np.testing.assert_allclose(b.out, ref, atol=1e-3)
    assert a.out is not b.out  # each served to its own output buffer
