"""Heterogeneous device-set planning + two-backend execution (ISSUE 6).

Pins the plan_hetero contract: degenerate identical-profile parity with
plan_pipeline2, per-stage memory/peaks priced on each stage's own device
(the ISSUE-6 bugfix — the old pipeline2 aggregated over ALL layers),
per-axis transfer/halo byte formulas (no cubic assumption), θ moving more
layers onto a scaled-up profile, per-device InfeasiblePoint reporting,
the paper's CPU-vs-GPU-vs-pipeline ordering on its own machines, and the
two-backend executor path being bitwise-equal to the single-backend dense
path with its measured hand-off bytes matching the plan exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ZNNI_NETS
from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.cost_model import split_transfer_cost
from repro.core.hw import (
    PAPER_MACHINES,
    TITAN_X,
    TPU_V5E,
    XEON_E7_8890V3_4WAY,
    host_link_bw,
)
from repro.core.pipeline import hetero_stage_devices, steady_state_time
from repro.volume import PlanExecutor

TOY = ConvNetConfig(
    "toy-hetero", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("conv", 2, 2)),
)


def _layer_share(plan, device_name):
    """Layers the named device's stage carries in ``plan``."""
    n = len(plan.choices)
    return plan.theta if plan.devices[0] == device_name else n - plan.theta


def _scaled(hw, factor, name):
    return dataclasses.replace(
        hw, name=name, peak_flops=hw.peak_flops * factor, hbm_bw=hw.hbm_bw * factor
    )


# -- cost-model pieces -------------------------------------------------------


def test_host_link_bw_is_slower_link():
    assert host_link_bw(XEON_E7_8890V3_4WAY, TITAN_X) == TITAN_X.ici_bw
    assert host_link_bw(TPU_V5E, TPU_V5E) == TPU_V5E.ici_bw


def test_split_transfer_uses_per_axis_extents():
    """Anisotropic activations: bytes = S*f*nx*ny*nz*4, NOT S*f*nx^3*4."""
    nbytes, secs = split_transfer_cost(2, 3, (5, 7, 11), TPU_V5E, TITAN_X)
    assert nbytes == 2 * 3 * 5 * 7 * 11 * 4
    assert secs == nbytes / min(TPU_V5E.ici_bw, TITAN_X.ici_bw)
    # chips divide the hand-off bandwidth
    _, secs2 = split_transfer_cost(2, 3, (5, 7, 11), TPU_V5E, TITAN_X, chips=4)
    assert secs2 == secs / 4


def test_spatial_halo_bytes_per_axis():
    """Each axis contributes two faces of the OTHER axes' extents."""
    got = planner.spatial_halo_bytes(1, 2, (4, 2, 3), 3)
    assert got == 2 * (2 * 3 + 4 * 3 + 4 * 2) * (3 - 1) * 2 * 1 * 4
    # cubic case agrees with the old 6*n^2 formula
    assert planner.spatial_halo_bytes(1, 1, (5, 5, 5), 3) == 6 * 25 * 2 * 4


def test_steady_state_time():
    assert steady_state_time(3.0, 1.0, 0.5) == 3.5
    assert steady_state_time(1.0, 3.0) == 3.0


# -- degenerate parity: two identical profiles == pipeline2 ------------------


def test_identical_profiles_reproduce_pipeline2():
    for name in ("n337", "n726"):
        net = ZNNI_NETS[name]
        p2 = planner.plan_pipeline2(net, TPU_V5E, chips_per_stage=1, max_m=8)
        ph = planner.plan_hetero(net, (TPU_V5E, TPU_V5E), chips_per_stage=1, max_m=8)
        assert p2 is not None and ph is not None
        assert p2.strategy == "pipeline2" and ph.strategy == "hetero"
        assert (p2.theta, p2.m_final, p2.batch) == (ph.theta, ph.m_final, ph.batch)
        assert p2.total_time == ph.total_time
        assert p2.prims == ph.prims
        assert p2.stage_times == ph.stage_times
        assert p2.xfer_bytes == ph.xfer_bytes


def test_pipeline2_carries_per_stage_metadata():
    """The ISSUE-6 bugfix: peaks/memory per stage, not over ALL layers."""
    net = ZNNI_NETS["n726"]
    plan = planner.plan_pipeline2(net, TPU_V5E, chips_per_stage=1, max_m=8)
    th = plan.theta
    stage0, stage1 = plan.choices[:th], plan.choices[th:]
    assert plan.stage_peak_bytes == (
        max(c.cost.peak_bytes for c in stage0),
        max(c.cost.peak_bytes for c in stage1),
    )
    assert plan.peak_bytes == max(plan.stage_peak_bytes)
    # each stage's footprint sums resident state over ITS layers only
    m0, m1 = plan.stage_memory
    assert m0.spectra_bytes == sum(
        c.cost.memory.spectra_bytes for c in stage0 if c.cost.memory
    )
    assert m1.spectra_bytes == sum(
        c.cost.memory.spectra_bytes for c in stage1 if c.cost.memory
    )
    # plan.memory is the worse stage's footprint — at most the old
    # all-layers aggregate, never the double-counted sum
    agg = planner._plan_memory_analytic(plan.choices)
    assert plan.memory.device_bytes == max(m0.device_bytes, m1.device_bytes)
    assert plan.memory.device_bytes <= agg.device_bytes


def test_hetero_xfer_priced_on_slower_host_link():
    net = ZNNI_NETS["n726"]
    plan = planner.plan_hetero(net, PAPER_MACHINES, chips_per_stage=1, max_m=8)
    assert plan is not None and len(plan.devices) == 2
    S_t, f_t, n_t = plan.choices[plan.theta].in_shape
    want_bytes = S_t * f_t * n_t[0] * n_t[1] * n_t[2] * 4
    assert plan.xfer_bytes == want_bytes
    assert plan.xfer_seconds == want_bytes / host_link_bw(*PAPER_MACHINES)
    assert plan.total_time == steady_state_time(*plan.stage_times, plan.xfer_seconds)


# -- θ direction under profile scaling ---------------------------------------


def test_theta_moves_toward_scaled_up_profile():
    """Scaling one profile's peak_flops/hbm_bw moves layers onto it."""
    for name in ("n337", "n537", "n726"):
        net = ZNNI_NETS[name]
        nl = len(net.layers)
        base = planner.plan_hetero(net, (TPU_V5E, TPU_V5E), max_m=8)
        hi, lo = max(base.theta, nl - base.theta), min(base.theta, nl - base.theta)
        up = planner.plan_hetero(net, (TPU_V5E, _scaled(TPU_V5E, 8, "fast")), max_m=8)
        dn = planner.plan_hetero(net, (TPU_V5E, _scaled(TPU_V5E, 1 / 8, "slow")), max_m=8)
        # the 8x-faster device carries at least the heavier base stage; the
        # 8x-slower one at most the lighter base stage
        assert _layer_share(up, "fast") >= hi
        assert _layer_share(dn, "slow") <= lo


# -- the paper's machines (satellite: wire the dead profiles in) -------------


def test_paper_machines_ordering():
    """Analytic reproduction of the paper's CPU-vs-GPU-vs-pipeline story
    on its own machines, each budgeted to its own RAM: the GPU wins the
    small-FOV net, the CPU wins the large-FOV net (12 GiB cripples the
    GPU there), and the CPU+GPU pipeline beats BOTH singles on n726 —
    the paper's headline claim."""
    budgets = (float(XEON_E7_8890V3_4WAY.hbm_bytes), float(TITAN_X.hbm_bytes))

    def singles(net, max_m):
        cpu = planner.plan_single(net, XEON_E7_8890V3_4WAY, max_m=max_m, ram_budget=budgets[0])
        gpu = planner.plan_single(net, TITAN_X, max_m=max_m, ram_budget=budgets[1])
        return cpu, gpu

    cpu, gpu = singles(ZNNI_NETS["n337"], 24)
    assert gpu.throughput > cpu.throughput  # small FOV: GPU-favored
    cpu, gpu = singles(ZNNI_NETS["n926"], 24)
    assert cpu.throughput > gpu.throughput  # large FOV: RAM-starved GPU loses

    net = ZNNI_NETS["n726"]
    hetero = planner.plan_hetero(
        net, PAPER_MACHINES, chips_per_stage=1, max_m=40, ram_budgets=budgets
    )
    cpu, gpu = singles(net, 40)
    assert hetero is not None
    assert set(hetero.devices) == {XEON_E7_8890V3_4WAY.name, TITAN_X.name}
    assert hetero.throughput > cpu.throughput
    assert hetero.throughput > gpu.throughput


def test_plan_all_strategies_devices():
    out = planner.plan_all_strategies(TOY, devices=PAPER_MACHINES, chips=4)
    hetero = out["hetero"]
    assert hetero is not None and hetero.strategy == "hetero"
    assert len(hetero.stage_times) == 2 and len(hetero.stage_memory) == 2
    assert out["infeasible"] == ()  # unconstrained search records nothing
    # hw defaults to the accelerator of the pair for the single searches
    explicit = planner.plan_all_strategies(TOY, TITAN_X, chips=4)
    assert out["single"].throughput == explicit["single"].throughput


def test_per_device_infeasible_reporting():
    pts = []
    plan = planner.plan_hetero(
        TOY, (XEON_E7_8890V3_4WAY, TITAN_X), max_m=2,
        ram_budgets=(None, 64.0),  # 64 B: nothing fits the "GPU"
        infeasible=pts,
    )
    assert plan is None  # one stage must always land on the starved device
    assert pts and all(p.device == TITAN_X.name for p in pts)
    assert all(p.strategy == "hetero" for p in pts)


# -- two-backend execution ---------------------------------------------------


def test_hetero_executor_bitwise_equals_dense(rng):
    """The split jit0∘jit1 across two backends reproduces the one-jit
    dense path bit for bit, its hand-off bytes match the plan exactly,
    and the per-stage/transfer counters land in last_stats."""
    net = TOY
    plan = planner.plan_hetero(net, PAPER_MACHINES, chips_per_stage=1, max_m=1)
    assert plan is not None and 0 < plan.theta < len(net.layers)
    params = convnet.init_params(jax.random.PRNGKey(3), net)
    fov, core = plan.fov, plan.core
    vol = rng.normal(
        size=(1, 2 * core + 1 + fov - 1, 2 * core + fov - 1, core + fov - 1)
    ).astype(np.float32)

    ex = PlanExecutor(params, net, plan)
    assert ex.hetero and ex.theta == plan.theta
    got = ex.run(vol)
    want = np.asarray(
        convnet.apply_dense_reference(params, net, jnp.asarray(vol)[None])[0]
    )
    np.testing.assert_allclose(got, want, atol=1e-3)

    # bitwise vs the single-backend dense executor on the same prims/m/S
    dense = PlanExecutor(params, net, prims=plan.prims, m=plan.m_final, batch=plan.batch)
    np.testing.assert_array_equal(got, dense.run(vol))

    s = ex.last_stats
    n_patches = s["patches"]
    assert s["xfer_bytes"] == s["predicted_xfer_bytes"]
    assert s["predicted_xfer_bytes"] == plan.xfer_bytes / plan.batch * n_patches
    assert s["stage0_seconds"] > 0 and s["stage1_seconds"] > 0
    assert s["xfer_seconds"] > 0
    assert s["predicted_stage0_seconds"] > 0 and s["predicted_stage1_seconds"] > 0


def test_hetero_stage_devices_contract():
    d0, d1 = hetero_stage_devices()
    assert d0 == jax.devices("cpu")[0]
    assert d1 == jax.devices()[0]
