"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import ZNNI_NETS
from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import cost_model
from repro.core.mpf import mpf_reference, recombine_fragments
from repro.layers.embedding import cross_entropy
from repro.optim.adamw import _dequantize, _quantize


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 6), net_idx=st.integers(0, 3))
def test_input_size_roundtrip(m, net_idx):
    """valid_input_size and output_size are exact inverses for every net."""
    net = list(ZNNI_NETS.values())[net_idx]
    n_in = net.valid_input_size(m)
    assert net.output_size(n_in) == m


@settings(max_examples=20, deadline=None)
@given(
    S=st.integers(1, 3), f=st.integers(1, 8), fp=st.integers(1, 8),
    n=st.integers(6, 24), k=st.sampled_from([2, 3, 5]),
)
def test_cost_model_positive_and_fft_flops_beat_direct_for_big_k(S, f, fp, n, k):
    for prim in cost_model.CONV_PRIMS:
        c = cost_model.conv_cost(prim, S, f, fp, (n, n, n), k)
        assert c.flops > 0 and c.hbm_bytes > 0 and c.peak_bytes > 0
    # monotone in batch
    c1 = cost_model.conv_cost("fft_task", S, f, fp, (n, n, n), k)
    c2 = cost_model.conv_cost("fft_task", S + 1, f, fp, (n, n, n), k)
    assert c2.flops > c1.flops and c2.peak_bytes > c1.peak_bytes


@settings(max_examples=15, deadline=None)
@given(
    p1=st.integers(2, 3), p2=st.integers(2, 3), m=st.integers(1, 2),
    S=st.integers(1, 2),
)
@pytest.mark.slow  # ~20s: one compile per sampled pool stack
def test_fragment_recombination_permutes_fragment_values(p1, p2, m, S):
    """recombine_fragments only REARRANGES fragment voxels — the dense
    output is an exact multiset permutation of the fragment tensor."""
    n2 = p2 * m + p2 - 1
    n1 = p1 * n2 + p1 - 1
    rng = np.random.default_rng(p1 * 100 + p2 * 10 + m)
    vals = rng.normal(size=(S, 1, n1, n1, n1)).astype(np.float32)
    x = jnp.asarray(vals)
    y = mpf_reference(mpf_reference(x, p1), p2)
    dense = recombine_fragments(y, [p1, p2], S)
    assert dense.shape[0] == S
    np.testing.assert_array_equal(
        np.sort(np.asarray(dense).ravel()), np.sort(np.asarray(y).ravel())
    )


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), S=st.integers(2, 40), V=st.integers(3, 80))
@pytest.mark.slow  # ~25s: one compile per sampled (B, S, V)
def test_chunked_ce_matches_direct(B, S, V):
    rng = np.random.default_rng(B * 1000 + S * 10 + V)
    lg = jnp.asarray(rng.normal(size=(B, S, V)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, V, size=(B, S)).astype(np.int32))
    lf = lg.astype(jnp.float32)
    want = jnp.mean(
        jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(lf, y[..., None], -1)[..., 0]
    )
    got = cross_entropy(lg, y)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(2, 100))
def test_int8_moment_quantization_error_bounded(scale, n):
    rng = np.random.default_rng(n)
    x = jnp.asarray((rng.normal(size=(4, n)) * scale).astype(np.float32))
    q = _quantize(x)
    back = _dequantize(q)
    absmax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= absmax / 127.0 * 0.5 + 1e-9).all()


@settings(max_examples=10, deadline=None)
@given(
    ks=st.lists(st.sampled_from([2, 3, 5]), min_size=1, max_size=3),
    pools=st.integers(0, 2),
)
def test_fov_consistency_random_nets(ks, pools):
    """FOV computed forward == inversion via valid_input_size(1)."""
    layers = []
    for i, k in enumerate(ks):
        layers.append(L("conv", k, 4))
        if i < pools:
            layers.append(L("pool", 2))
    net = ConvNetConfig("rnd", 1, tuple(layers))
    # input that yields exactly one dense output voxel per fragment
    n_in = net.valid_input_size(1)
    # dense output size = n_in - FOV + 1 must equal total_pooling (fragments)
    assert n_in - net.field_of_view() + 1 == net.total_pooling()
