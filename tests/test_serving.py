"""Serving engine: continuous batching produces the same tokens as an
unbatched greedy decode of each request."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import build_model
from repro.serving import EngineConfig, Request, ServingEngine


def _greedy_reference(model, params, prompt, max_new, max_seq):
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, caches = model.prefill(params, {"tokens": toks}, cache_len=max_seq)
    out = [int(jnp.argmax(logits[0, -1]))]
    cur = jnp.array([[out[-1]]], jnp.int32)
    for _ in range(max_new - 1):
        logits, caches = model.decode_step(params, cur, caches)
        out.append(int(jnp.argmax(logits[0, 0])))
        cur = jnp.array([[out[-1]]], jnp.int32)
    return out


def _make_model():
    red = ARCHS["qwen1.5-4b"].reduced()
    cfg = dataclasses.replace(red, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.slow  # ~40s: per-request prefill compiles
def test_engine_matches_unbatched_greedy():
    cfg, model, params = _make_model()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32) for _ in range(3)]
    max_new = 6
    eng = ServingEngine(model, params, EngineConfig(slots=2, max_seq=32))
    reqs = [Request(i, p, max_new) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    for _ in range(100):
        if all(r.done for r in reqs):
            break
        eng.step()
    assert all(r.done for r in reqs)
    for r, p in zip(reqs, prompts):
        want = _greedy_reference(model, params, p, max_new, 32)
        assert r.out == want, f"req {r.rid}: {r.out} != {want}"


def test_engine_more_requests_than_slots():
    cfg, model, params = _make_model()
    rng = np.random.default_rng(1)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32), 3)
        for i in range(5)
    ]
    eng = ServingEngine(model, params, EngineConfig(slots=2, max_seq=16))
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        if all(r.done for r in reqs):
            break
        eng.step()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
