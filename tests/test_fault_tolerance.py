"""Failure detection, straggler mitigation, elastic resharding."""

import numpy as np

from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    elastic_shard_sizes,
)


def test_heartbeat_detects_failure():
    mon = HeartbeatMonitor(n_workers=4, patience=3, straggler_factor=2.0)
    t = 0.0
    for step in range(10):
        t += 1.0
        for w in range(4):
            if w == 2 and step >= 5:
                continue  # worker 2 dies at step 5
            mon.heartbeat(w, step, 1.0, now=t)
    cls = mon.classify(now=t + 20.0)
    assert cls[2] == "failed"
    assert cls[0] == "ok"
    assert mon.plan(now=t + 20.0)["action"] == "evict_and_restore"


def test_heartbeat_flags_straggler():
    mon = HeartbeatMonitor(n_workers=4, straggler_factor=2.0)
    t = 0.0
    for step in range(10):
        t += 1.0
        for w in range(4):
            mon.heartbeat(w, step, 5.0 if w == 1 else 1.0, now=t)
    cls = mon.classify(now=t)
    assert cls[1] == "straggler"
    plan = mon.plan(now=t)
    assert plan["action"] == "rebalance" and 1 in plan["workers"]


def test_elastic_shard_sizes_sum_and_proportionality():
    sizes = elastic_shard_sizes(256, 4)
    assert sizes == [64, 64, 64, 64]
    # worker 1 runs at half speed -> smaller shard
    sizes = elastic_shard_sizes(256, 4, weights=[1.0, 0.5, 1.0, 1.0])
    assert sum(sizes) == 256
    assert sizes[1] < sizes[0]
    # degenerate: 1 worker
    assert elastic_shard_sizes(7, 1) == [7]


def test_restore_with_remesh_roundtrip():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.fault_tolerance import restore_with_remesh

    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0)}
    out = restore_with_remesh(tree, {"w": NamedSharding(mesh, P())})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8.0))
