"""§Perf lever correctness: the optimizations must not change model outputs
(head padding: bit-identical; expand_kv: exact; grouped routing: standard
local-capacity semantics, drop-free case exact)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import MoEConfig
from repro.layers import moe as moe_mod
from repro.models import build_model

pytestmark = pytest.mark.slow  # LM lever equivalence, ~25s of compiles


def _embed_params_into_padded(p_small, p_big, cfg_small, cfg_big):
    """Copy real attention weights into the padded model's zero-padded slots."""
    a_s, a_b = cfg_small.attn, cfg_big.attn
    Hkv = a_s.n_kv_heads
    G, Gp = a_s.q_per_kv, a_b.pad_q_groups
    d, hd = cfg_small.d_model, a_s.head_dim

    def fix(tree_small, tree_big):
        out = jax.tree.map(lambda x: x, tree_big)  # copy
        def walk(ps, pb):
            new = {}
            for k in pb:
                if isinstance(pb[k], dict):
                    new[k] = walk(ps[k], pb[k])
                elif k == "wq":
                    # trailing dims (d, H, hd) -> (d, Hkv, G, hd)
                    w = jnp.zeros_like(pb[k]).reshape(*pb[k].shape[:-3], d, Hkv, Gp, hd)
                    w = w.at[..., :, :, :G, :].set(
                        ps[k].reshape(*ps[k].shape[:-3], d, Hkv, G, hd)
                    )
                    new[k] = w.reshape(pb[k].shape)
                elif k == "wo":
                    w = jnp.zeros_like(pb[k]).reshape(*pb[k].shape[:-3], Hkv, Gp, hd, d)
                    w = w.at[..., :, :G, :, :].set(
                        ps[k].reshape(*ps[k].shape[:-3], Hkv, G, hd, d)
                    )
                    new[k] = w.reshape(pb[k].shape)
                elif k == "bq":
                    b = jnp.zeros_like(pb[k]).reshape(*pb[k].shape[:-2], Hkv, Gp, hd)
                    b = b.at[..., :, :G, :].set(
                        ps[k].reshape(*ps[k].shape[:-2], Hkv, G, hd)
                    )
                    new[k] = b.reshape(pb[k].shape)
                else:
                    new[k] = ps[k]
            return new
        return walk(tree_small, out)

    return fix(p_small, p_big)


def test_head_padding_is_bit_exact():
    red = dataclasses.replace(ARCHS["qwen2-vl-7b"].reduced(), dtype="float32")
    # reduced: 4 heads, 1 kv head -> G=4; pad to 6
    cfg_pad = dataclasses.replace(
        red, attn=dataclasses.replace(red.attn, pad_q_groups=red.attn.q_per_kv + 2)
    )
    m0, mp = build_model(red), build_model(cfg_pad)
    p0 = m0.init(jax.random.PRNGKey(0))
    pp = _embed_params_into_padded(p0, mp.init(jax.random.PRNGKey(1)), red, cfg_pad)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, red.vocab)}
    l0, _ = m0.forward(p0, batch, remat=False)
    lp, _ = mp.forward(pp, batch, remat=False)
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(lp))


def test_expand_kv_is_exact():
    red = dataclasses.replace(ARCHS["mixtral-8x7b"].reduced(), dtype="float32")
    cfg_e = dataclasses.replace(red, attn=dataclasses.replace(red.attn, expand_kv=True))
    m0, me = build_model(red), build_model(cfg_e)
    p = m0.init(jax.random.PRNGKey(0))  # identical param trees
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, red.vocab)}
    l0, _ = m0.forward(p, batch, remat=False)
    le, _ = me.forward(p, batch, remat=False)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(le), atol=2e-5, rtol=1e-5)


def test_grouped_routing_dropfree_matches_global():
    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=8.0)  # drop-free
    d, ff = 16, 32
    p = moe_mod.moe_init(jax.random.PRNGKey(0), d, ff, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d), jnp.float32)
    y1, _ = moe_mod.moe_apply(p, x, cfg, "swiglu", routing_groups=1)
    y4, _ = moe_mod.moe_apply(p, x, cfg, "swiglu", routing_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5, rtol=1e-5)


def test_dryrun_artifacts_complete():
    """All 80 dry-run cells exist and none errored (the §Dry-run claim)."""
    import glob
    import json
    import os

    d = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
    base = glob.glob(os.path.join(d, "baseline__*.json"))
    if len(base) < 80:
        import pytest

        pytest.skip("dry-run artifacts not generated in this checkout")
    assert len(base) == 80
    skipped = errored = ok = 0
    for f in base:
        with open(f) as fh:
            r = json.load(fh)
        if "skipped" in r:
            skipped += 1
        elif "error" in r:
            errored += 1
        else:
            ok += 1
    assert errored == 0
    assert skipped == 12  # 6 full-attention archs x 2 meshes at long_500k
    assert ok == 68
