"""Overlap-save conv primitive + cross-patch input-spectra reuse (ISSUE 3).

Three layers of guarantees:

* the segmented transform-MAD-inverse pipeline equals the dense valid-conv
  oracle for arbitrary core/FOV splits (property test, including
  undersized axes that trigger zero-pad);
* the registry entry behaves like every other conv primitive (one-shot
  apply, compiled plans, planner enumeration);
* the volume executor's sweep cache actually reuses input spectra: an
  interior patch transforms strictly fewer segments than its grid holds,
  counted at ``overlap_save.slice_segment_spectra`` granularity.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from _hypothesis_compat import given, settings, st

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, cost_model, overlap_save as osm, planner, primitives
from repro.core.fft_conv import precompute_kernel_fft
from repro.core.hw import TPU_V5E
from repro.volume import PlanExecutor
from repro.serving import VolumeEngine, VolumeRequest

NET = ConvNetConfig(
    "os-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
OS_PRIMS = ["overlap_save" if l.kind == "conv" else "mpf" for l in NET.layers]


def _dense_conv(x, w, b=None):
    o = lax.conv_general_dilated(
        x, w, (1, 1, 1), "VALID",
        dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
    )
    if b is not None:
        o = o + b.reshape(1, -1, 1, 1, 1)
    return o


def _dense_net(params, net, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, net, jnp.asarray(vol)[None])[0]
    )


# -- segmentation geometry ---------------------------------------------------


def test_plan_overlap_save_geometry():
    spec = osm.plan_overlap_save((21, 21, 21), (3, 3, 3), 4)
    assert spec.out == (19, 19, 19)
    assert spec.starts == (0, 4, 8, 12, 16)  # aligned grid, no shifted tail
    assert spec.seg_extent == 6
    assert spec.tail_len == 3  # last segment owns outputs [16, 19)
    assert spec.input_pad == spec.span - 21 == 1  # grid reads 1 voxel past n
    assert spec.fft_shape[0] >= spec.seg_extent


def test_plan_overlap_save_clamps_and_degenerates():
    # seg_core > output extent: single segment covering everything
    spec = osm.plan_overlap_save((9, 9, 9), (3, 3, 3), 100)
    assert spec.n_segments == 1 and spec.seg_core == 7 and spec.tail_len == 7
    with pytest.raises(ValueError):
        osm.plan_overlap_save((2, 9, 9), (3, 3, 3))


def test_shared_segments_counts_aligned_overlap():
    spec = osm.plan_overlap_save((25, 25, 25), (3, 3, 3), 8)
    # starts (0, 8, 16); a patch 8 to the right shares segments 8 and 16
    assert osm.shared_segments(spec, 8) == 2
    assert osm.shared_segments(spec, 24) == 0


# -- segmented pipeline == dense oracle (property, incl. zero-pad) -----------


@settings(max_examples=20, deadline=None)
@given(
    nx=st.integers(4, 12), ny=st.integers(4, 10), k=st.sampled_from([2, 3]),
    seg=st.integers(1, 6),
)
def test_overlap_save_matches_dense_for_arbitrary_splits(nx, ny, k, seg):
    """Arbitrary (input extent, kernel, segment core) splits — including
    seg > n_out (degenerate single segment) and grids whose tail reads
    past the input (zero-pad) — reproduce the dense valid conv."""
    rng = np.random.default_rng(nx * 100 + ny * 10 + k + seg)
    x = jnp.asarray(rng.normal(size=(2, 2, nx, ny, ny - 1)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 2, k, k, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    spec = osm.plan_overlap_save((nx, ny, ny - 1), (k, k, k), seg)
    W = precompute_kernel_fft(w, spec.fft_shape)
    got = osm.overlap_save_conv(x, W, b, spec)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_dense_conv(x, w, b)), atol=1e-4
    )


# -- registry behaviour ------------------------------------------------------


def test_registered_one_to_one_with_cost_model():
    assert "overlap_save" in cost_model.CONV_PRIMS
    prim = primitives.conv_primitive("overlap_save")
    assert prim.cost is cost_model.conv_overlap_save_cost


def test_conv_apply_overlap_save_matches_dense(rng):
    x = jnp.asarray(rng.normal(size=(1, 2, 9, 8, 7)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 2, 3, 3, 3)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(3,)).astype(np.float32))
    got = primitives.conv_apply("overlap_save", x, w, b)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_dense_conv(x, w, b)), atol=1e-4
    )


def test_overlap_save_cost_amortizes_input_ffts():
    """The priced input-FFT work drops relative to the task-parallel model
    (overlap amortized by the executor's sweep cache), while peak memory —
    the paper's Table-II axis — shrinks with the segment spectra."""
    S, f, fp, n, k = 2, 8, 8, (33, 33, 33), 3
    os_c = cost_model.conv_overlap_save_cost(S, f, fp, n, k)
    cached = cost_model.conv_fft_cached_kernels_cost(S, f, fp, n, k)
    assert os_c.peak_bytes < cached.peak_bytes
    assert os_c.flops > 0 and os_c.hbm_bytes > 0


def test_planner_enumerates_overlap_save():
    """A forced overlap_save plan exists and carries the right prims; the
    default enumeration includes the primitive (1:1 with the registry is
    asserted in test_planner_invariants)."""
    plan = planner.plan_single(
        NET, TPU_V5E, max_m=1, batches=(2,),
        conv_prims=("overlap_save",), strategy_name="os",
    )
    assert plan is not None
    assert all(c.prim == "overlap_save" for c in plan.choices if c.kind == "conv")


def test_plan_fixed_prices_mixed_assignment():
    prims = [
        "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
        for i, l in enumerate(NET.layers)
    ]
    plan = planner.plan_fixed(NET, TPU_V5E, prims, m=1, batch=2)
    assert plan is not None and plan.prims == tuple(prims)
    assert plan.n_in == 21 and plan.core == 4
    assert plan.throughput > 0 and plan.peak_bytes > 0
    assert plan.out_voxels == 2 * float(4) ** 3
    assert plan.peak_bytes <= TPU_V5E.hbm_bytes
    # feasibility rule matches the searches: over-budget -> None
    assert planner.plan_fixed(NET, TPU_V5E, prims, m=1, batch=2, mem_bytes=1.0) is None
    with pytest.raises(ValueError):
        planner.plan_fixed(NET, TPU_V5E, ["overlap_save"], m=1)


# -- executor: the sweep cache reuses input spectra --------------------------


def _volume(net, m, rng, extra=(1, 0, 0), xcores=3):
    fov = net.field_of_view()
    core = m * net.total_pooling()
    shape = (xcores * core + extra[0] + fov - 1,
             2 * core + extra[1] + fov - 1, core + extra[2] + fov - 1)
    return rng.normal(size=(1,) + shape).astype(np.float32)


def test_executor_reuses_boundary_spectra(rng):
    """The acceptance property: across a sweep, interior-patch input-FFT
    count is strictly lower than the per-patch segment count — counted at
    rfftn (segment-transform) granularity.  The segment FFTs are fused
    into the per-batch jit, so the count of transforms actually *executed*
    is the miss-batch size of each step call, intercepted at the jit
    boundary (a trace-level monkeypatch would count compilations, not
    executions).  ``deep_reuse=False`` pins the PR-3 accounting — every
    patch resolves its full segment grid; the deep-reuse strip path has
    its own exact accounting test in ``test_sweep_accounting.py``."""
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    vol = _volume(NET, 1, rng)  # 4 x-rows (one shifted), 2x1 columns
    ex = PlanExecutor(params, NET, prims=OS_PRIMS, m=1, batch=1, deep_reuse=False)
    spec0 = ex.compiled.layers[0].os_spec
    assert spec0.seg_core == ex.core  # executor pinned the grid to the core

    seg_counts = []
    real_step = ex._jit_os_step

    def counted(states, svol, starts, parents, *, pattern):
        seg_counts.append(0 if starts is None else int(starts.shape[0]))
        return real_step(states, svol, starts, parents, pattern=pattern)

    ex._jit_os_step = counted

    got = ex.run(vol)
    np.testing.assert_allclose(got, _dense_net(params, NET, vol), atol=1e-3)
    s = ex.last_stats
    n_patches, n_seg = int(s["patches"]), spec0.n_segments
    # bookkeeping is exact: every (patch, segment) slot is a hit or a miss
    assert s["os_seg_fft"] + s["os_seg_hits"] == n_patches * n_seg
    # reuse happened: strictly fewer input FFTs than a reuse-free sweep
    assert 0 < s["os_seg_fft"] < n_patches * n_seg
    assert s["os_seg_fft"] == sum(seg_counts)  # stats == actual transforms
    # batch=1 makes per-patch attribution exact: an interior x-row patch
    # transforms only the segments the sweep newly entered (core/seg_core),
    # strictly fewer than its full grid
    interior = [c for c in seg_counts if c < n_seg]
    assert interior and max(interior) == ex.core // spec0.seg_core == 1

    # a second sweep is a fresh scope: same counts, no cross-request leak
    first = s["os_seg_fft"]
    seg_counts.clear()
    ex.run(vol)
    assert ex.last_stats["os_seg_fft"] == first == sum(seg_counts)
    assert not ex._sweeps and not ex._sweep_vols  # scopes closed


def test_executor_reuse_batched_matches_unbatched(rng):
    """Batching (including the ragged tail) must not change results or the
    miss pattern semantics.  (``deep_reuse=False``: the strip path picks
    per-patch FFT shapes by batch-dependent eligibility, so bitwise-level
    equality across batch sizes is only pinned for the full path; deep
    equivalence is covered in ``test_sweep_accounting.py``.)"""
    params = convnet.init_params(jax.random.PRNGKey(1), NET)
    vol = _volume(NET, 1, rng)
    ex1 = PlanExecutor(params, NET, prims=OS_PRIMS, m=1, batch=1, deep_reuse=False)
    ex3 = PlanExecutor(params, NET, prims=OS_PRIMS, m=1, batch=3, deep_reuse=False)
    got1, got3 = ex1.run(vol), ex3.run(vol)
    np.testing.assert_allclose(got1, got3, atol=1e-5)
    assert ex1.last_stats["os_seg_fft"] == ex3.last_stats["os_seg_fft"]
    np.testing.assert_allclose(got3, _dense_net(params, NET, vol), atol=1e-3)


def test_tiler_segment_keys_shared_between_x_neighbours():
    from repro.volume.tiler import HaloSpec, tile_volume

    halo = HaloSpec(seg_core=8, seg_extent=10, rel_starts=(0, 8, 16))
    t = tile_volume((52, 33, 33), core=8, fov=18, halo=halo)
    rows = sorted({p.start[0] for p in t.patches})
    assert rows[:2] == [0, 8]
    p0 = next(p for p in t.patches if p.start == (0, 0, 0))
    p1 = next(p for p in t.patches if p.start == (8, 0, 0))
    k0, k1 = set(t.segment_keys(p0)), set(t.segment_keys(p1))
    assert k0 & k1 == {(8, 0, 0), (16, 0, 0)}  # the shared halo
    # different y column: disjoint keys (no false sharing)
    py = next(p for p in t.patches if p.start == (0, 8, 0))
    assert not (k0 & set(t.segment_keys(py)))
    # plain tiling has no segment identity
    with pytest.raises(ValueError):
        tile_volume((52, 33, 33), core=8, fov=18).segment_keys(p0)


def test_volume_engine_scopes_reuse_per_request(rng):
    """Cross-request continuous batching: spectra never leak between
    requests (different volumes), every output matches the oracle, and
    sweep scopes are freed on completion."""
    params = convnet.init_params(jax.random.PRNGKey(2), NET)
    eng = VolumeEngine(params, NET, prims=OS_PRIMS, m=1, batch=4)
    vols = [_volume(NET, 1, rng), _volume(NET, 1, rng, xcores=2)]
    reqs = [VolumeRequest(i, v) for i, v in enumerate(vols)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    for r, v in zip(reqs, vols):
        assert r.done
        np.testing.assert_allclose(r.out, _dense_net(params, NET, v), atol=1e-3)
    assert not eng.executor._sweeps and not eng.executor._sweep_vols
    # resubmitting a completed request opens a FRESH scope (no stale token)
    again = reqs[1]
    eng.submit(again)
    eng.run_until_drained()
    np.testing.assert_allclose(
        again.out, _dense_net(params, NET, vols[1]), atol=1e-3
    )
    assert not eng.executor._sweeps and not eng.executor._sweep_vols


def test_plan_driven_executor_with_overlap_save(rng):
    """planner.Plan -> PlanExecutor binding for a forced overlap_save plan."""
    plan = planner.plan_single(
        NET, TPU_V5E, max_m=1, batches=(2,),
        conv_prims=("overlap_save",), strategy_name="os",
    )
    params = convnet.init_params(jax.random.PRNGKey(3), NET)
    vol = _volume(NET, plan.m_final, rng)
    ex = PlanExecutor(params, NET, plan)
    got = ex.run(vol)
    np.testing.assert_allclose(got, _dense_net(params, NET, vol), atol=1e-3)
    assert ex.last_stats["os_seg_fft"] > 0
