"""Deterministic fault injection for ShardedVolumeEngine drills.

The sharded fleet consults two hooks per (worker, tick):

* ``down(wid, tick)``  — True: the worker is dead/hung this tick (runs no
  chunk, sends no heartbeat; the monitor's synthetic-clock deadline does
  the detecting);
* ``step_time(wid, tick)`` — the tick's reported step duration (feeds the
  monitor's rolling median; a factor > the monitor's ``straggler_factor``
  flags the worker).

``FaultScript`` turns scripted events — kill/revive/slowdown at a chosen
tick — into those hooks.  Everything is tick-indexed and the engine clock
is synthetic, so fault drills are ordinary fast tier-1 tests: no
wall-clock sleeps, no flakiness, same outcome on every run.

Note revival is two-sided: ``revive(wid, at_tick)`` makes ``down`` False
again, but an *evicted* worker also needs the engine's consent —
``ShardedVolumeEngine.revive_worker(wid)`` re-admits it, after which it
resumes its zombie tasks (whose completions the done-set drops as
duplicates — the idempotency drill).
"""

from typing import Dict, Optional, Tuple


class FaultScript:
    """Scripted per-tick worker faults (death, revival, slowdown)."""

    def __init__(self) -> None:
        self._death: Dict[int, int] = {}  # wid -> first down tick
        self._revival: Dict[int, int] = {}  # wid -> first up-again tick
        self._slow: Dict[int, Tuple[int, Optional[int], float]] = {}

    # -- scripting ----------------------------------------------------------

    def kill(self, wid: int, at_tick: int) -> "FaultScript":
        """Worker ``wid`` stops running and heartbeating from ``at_tick``."""
        self._death[wid] = at_tick
        return self

    def revive(self, wid: int, at_tick: int) -> "FaultScript":
        """Worker ``wid`` is up again from ``at_tick`` (pair with the
        engine's ``revive_worker`` if it was evicted meanwhile)."""
        self._revival[wid] = at_tick
        return self

    def slow(
        self, wid: int, at_tick: int, factor: float,
        until: Optional[int] = None,
    ) -> "FaultScript":
        """Worker ``wid`` reports ``factor``x step times in
        [``at_tick``, ``until``) (open-ended when ``until`` is None)."""
        self._slow[wid] = (at_tick, until, float(factor))
        return self

    # -- engine hooks -------------------------------------------------------

    def down(self, wid: int, tick: int) -> bool:
        d = self._death.get(wid)
        if d is None or tick < d:
            return False
        r = self._revival.get(wid)
        return r is None or tick < r

    def step_time(self, wid: int, tick: int) -> float:
        s = self._slow.get(wid)
        if s is not None:
            start, until, factor = s
            if tick >= start and (until is None or tick < until):
                return factor
        return 1.0
