"""Layer-zoo unit tests: RoPE/M-RoPE, norms, MoE routing, Mamba2 SSD."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, SSMConfig
from repro.layers import moe as moe_mod
from repro.layers import norms, rope
from repro.layers import ssm as ssm_mod


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------


def test_mrope_reduces_to_rope_for_text(rng):
    B, S, H, d = 2, 7, 3, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = rope.apply_rope(x, pos, 10_000.0)
    b = rope.apply_mrope(x, rope.text_mrope_positions(pos), 10_000.0, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_rope_preserves_norm_and_relative_angles(rng):
    B, S, H, d = 1, 5, 1, 32
    x = jnp.asarray(rng.normal(size=(B, S, H, d)).astype(np.float32))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y = rope.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative-position property: <R(p)q, R(p+delta)k> depends only on delta
    q = jnp.asarray(rng.normal(size=(1, 1, 1, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1, 1, d)).astype(np.float32))
    def dot_at(pq, pk):
        rq = rope.apply_rope(q, jnp.array([[pq]]), 10_000.0)
        rk = rope.apply_rope(k, jnp.array([[pk]]), 10_000.0)
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(3, 7) - dot_at(10, 14)) < 1e-3


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------


def test_rmsnorm_unit_rms(rng):
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 7.0
    y = norms.rmsnorm(x, jnp.zeros((64,)))
    rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_layernorm_zero_mean_unit_var(rng):
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32)) * 3 + 5
    y = norms.layernorm(x, jnp.ones((64,)), jnp.zeros((64,)))
    np.testing.assert_allclose(np.mean(np.asarray(y), -1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.var(np.asarray(y), -1), 1.0, rtol=1e-2)


# --------------------------------------------------------------------------
# MoE
# --------------------------------------------------------------------------


def _dense_moe_reference(p, x, cfg: MoEConfig, act: str):
    """Oracle: run every expert densely, combine with top-k router weights."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_in"][e])
        outs.append(h @ p["w_out"][e])
    dense = jnp.stack(outs, 1)  # (T, E, d)
    w = jnp.zeros((T, cfg.n_experts))
    for kk in range(cfg.top_k):
        w = w + jax.nn.one_hot(topi[:, kk], cfg.n_experts) * topw[:, kk : kk + 1]
    return jnp.einsum("te,ted->td", w, dense).reshape(B, S, d)


def test_moe_dropfree_matches_dense_reference(rng):
    cfg = MoEConfig(n_experts=4, top_k=2, capacity_factor=4.0)
    d, ff = 16, 32
    p = moe_mod.moe_init(jax.random.PRNGKey(0), d, ff, cfg, "swiglu", jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 5, d)).astype(np.float32))
    got, aux = moe_mod.moe_apply(p, x, cfg, "swiglu")
    want = _dense_moe_reference(p, x, cfg, "swiglu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_tokens(rng):
    """With capacity_factor ~0 every token drops and the output is ~0."""
    cfg = MoEConfig(n_experts=4, top_k=1, capacity_factor=1e-9)
    d, ff = 8, 16
    p = moe_mod.moe_init(jax.random.PRNGKey(0), d, ff, cfg, "swiglu", jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, d)).astype(np.float32))
    got, _ = moe_mod.moe_apply(p, x, cfg, "swiglu")
    # capacity C>=1 keeps at most E tokens; most of the 8 are dropped
    assert float(jnp.abs(got).sum()) < float(jnp.abs(_dense_moe_reference(p, x, cfg, "swiglu")).sum())


# --------------------------------------------------------------------------
# Mamba2 SSD
# --------------------------------------------------------------------------


def _ssd_sequential(x, dt, A_log, B, C, D):
    """O(L·N·P) sequential-state oracle for the chunked SSD."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    a = -jnp.exp(A_log)[None] * dt  # (b,l,h)
    s = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        s = s * jnp.exp(a[:, t])[:, :, None, None] + jnp.einsum(
            "bn,bh,bhp->bhpn", B[:, t], dt[:, t], x[:, t]
        )
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t], s))
    y = jnp.stack(ys, 1) + x * D[None, None, :, None]
    return y, s


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_ssd_chunked_matches_sequential(chunk, rng):
    b, l, h, p, n = 1, 8, 2, 4, 3
    x = jnp.asarray(rng.normal(size=(b, l, h, p)).astype(np.float32))
    dt = jnp.asarray(rng.uniform(0.1, 1.0, size=(b, l, h)).astype(np.float32))
    A_log = jnp.asarray(rng.uniform(-1, 1, size=(h,)).astype(np.float32))
    B = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    C = jnp.asarray(rng.normal(size=(b, l, n)).astype(np.float32))
    D = jnp.ones((h,), jnp.float32)
    got_y, got_s = ssm_mod.ssd_chunked(x, dt, A_log, B, C, D, chunk)
    want_y, want_s = _ssd_sequential(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=1e-4, rtol=1e-4)


@pytest.mark.slow  # ~10s: SSD chunked-scan compiles
def test_ssm_prefill_decode_continuity(rng):
    """prefill state + one decode step == full-sequence apply on L+1 tokens."""
    s = SSMConfig(d_state=8, d_conv=4, expand=2, headdim=8, chunk=4)
    d_model = 16
    p = ssm_mod.ssm_init(jax.random.PRNGKey(0), d_model, s, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, d_model)).astype(np.float32))
    x_next = jnp.asarray(rng.normal(size=(2, 1, d_model)).astype(np.float32))
    _, (conv_c, state) = ssm_mod.ssm_prefill(p, x, s, d_model)
    y_dec, _ = ssm_mod.ssm_decode(p, x_next, s, d_model, conv_c, state)
    y_full = ssm_mod.ssm_apply(p, jnp.concatenate([x, x_next], 1), s, d_model)
    np.testing.assert_allclose(
        np.asarray(y_dec[:, 0]), np.asarray(y_full[:, -1]), atol=1e-4, rtol=1e-3
    )
