"""Sharding-rule unit tests (no multi-device mesh needed — rules are pure)."""

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_shape
from repro.models import build_model


def _mesh16():
    # a 16x16 LOGICAL mesh shape is what the rules key on; build it on one
    # device by reusing the device — rules only read mesh.shape/axis_names.

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    return FakeMesh()


def _specs(cfg, mesh, zero=False):
    model = build_model(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # monkeypatch NamedSharding construction by capturing specs
    import repro.distributed.sharding as sh

    captured = {}
    orig = sh.NamedSharding

    class Cap:
        def __init__(self, mesh, spec):
            self.mesh, self.spec = mesh, spec

    sh.NamedSharding = Cap
    try:
        tree = sh.param_shardings(cfg, params_sds, mesh, zero=zero)
    finally:
        sh.NamedSharding = orig
    flat = jax.tree_util.tree_leaves_with_path(
        tree, is_leaf=lambda x: isinstance(x, Cap)
    )
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", p)) for p in path)
        captured[key] = leaf.spec
    return captured


def test_jamba_experts_use_expert_parallelism():
    specs = _specs(ARCHS["jamba-v0.1-52b"], _mesh16())
    # 16 experts over a 16-way model axis -> expert dim sharded
    # (jamba MoE lives at odd pattern indices; block 0 is a dense-MLP mamba)
    key = next(k for k in specs if "blocks/1/ffn/w_in" in k)
    assert specs[key][-3] == "model"


def test_mixtral_experts_fall_back_to_tensor_parallel():
    specs = _specs(ARCHS["mixtral-8x7b"], _mesh16())
    key = next(k for k in specs if "ffn/w_in" in k)
    # 8 experts cannot shard over 16 -> d_ff sharded instead
    assert specs[key][-1] == "model" and specs[key][-3] is None


def test_qwen15_attention_replicated_mlp_sharded():
    specs = _specs(ARCHS["qwen1.5-4b"], _mesh16())
    wq = next(k for k in specs if k.endswith("mixer/wq"))
    assert all(s is None for s in specs[wq]), "20 heads must not shard over 16"
    w_in = next(k for k in specs if "ffn/w_in" in k)
    assert specs[w_in][-1] == "model"


def test_gemma3_full_head_sharding():
    specs = _specs(ARCHS["gemma3-27b"], _mesh16())
    wq = next(k for k in specs if k.endswith("mixer/wq"))
    wk = next(k for k in specs if k.endswith("mixer/wk"))
    assert specs[wq][-2] == "model"  # 32 q heads
    assert specs[wk][-2] == "model"  # 16 kv heads


def test_zero_adds_data_axis_to_large_leaves():
    specs = _specs(ARCHS["grok-1-314b"], _mesh16(), zero=True)
    w_in = next(k for k in specs if "ffn/w_in" in k)
    assert "data" in specs[w_in] and "model" in specs[w_in]
    # genuinely small leaves (unstacked final norm, d=6144 < 2^16 elems)
    # stay unsharded; STACKED norm scales (64 x 6144) may take the data axis
    norm = next(k for k in specs if k.startswith("final_norm"))
    assert "data" not in specs[norm]


def test_mamba_projections_shard_cleanly():
    specs = _specs(ARCHS["mamba2-2.7b"], _mesh16())
    for leaf in ("w_z", "w_x", "conv_x", "norm_scale"):
        key = next(k for k in specs if k.endswith(f"mixer/{leaf}"))
        assert "model" in specs[key], leaf


def test_decode_cache_sequence_sharding():
    import repro.distributed.sharding as sh

    cfg = ARCHS["phi3-medium-14b"]
    model = build_model(cfg)
    shape = get_shape("decode_32k")
    specs = model.input_specs(shape)
    mesh = _mesh16()
    orig = sh.NamedSharding

    class Cap:
        def __init__(self, mesh, spec):
            self.mesh, self.spec = mesh, spec

    sh.NamedSharding = Cap
    try:
        tree = sh.batch_shardings(cfg, shape, mesh, specs)
    finally:
        sh.NamedSharding = orig
    k_spec = tree["caches"]["blocks"]["0"]["k"].spec
    assert k_spec[1] in ("data", ("data",))  # batch 128 over data
    assert k_spec[2] == "model"  # sequence over model (flash-decode layout)
    assert tree["caches"]["lengths"].spec == P()
