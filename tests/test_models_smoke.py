"""Per-arch smoke tests (assignment requirement): every assigned arch, as a
REDUCED same-family config, runs one forward/train step on CPU with correct
output shapes and no NaNs — plus prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.layers import stubs
from repro.models import build_model
from repro.optim import AdamWConfig, apply_updates, init_state

pytestmark = pytest.mark.slow  # ~6 min of per-arch compiles; CI PR job runs them

ARCH_IDS = list(ARCHS)


def _batch_for(cfg, B, S, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    if cfg.frontend == "patch":
        n_patch = min(8, S // 2)
        batch["patch_embeds"] = (
            jax.random.normal(key, (B, n_patch, cfg.d_model)) * 0.02
        ).astype(jnp.dtype(cfg.dtype))
    if cfg.frontend == "audio":
        batch["frame_embeds"] = (
            jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.05
        ).astype(jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, S = 2, 32
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))

    logits, aux = model.forward(params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), "NaN/Inf in logits"

    # one real train step
    ocfg = AdamWConfig(lr=1e-3)
    opt = init_state(params, ocfg)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch, remat=True))(params)
    assert bool(jnp.isfinite(loss))
    new_params, _ = apply_updates(params, grads, opt, ocfg)
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()), params, new_params),
    )
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_matches_forward(arch):
    red = ARCHS[arch].reduced()
    kw = {"dtype": "float32"}
    if red.moe:
        kw["moe"] = dataclasses.replace(red.moe, capacity_factor=float(red.moe.n_experts))
    cfg = dataclasses.replace(red, **kw)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    batch.pop("labels")
    lg, caches = model.prefill(params, batch, cache_len=S + 4)
    tok = jnp.ones((B, 1), jnp.int32)
    lg2, caches = model.decode_step(params, tok, caches)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    ref, _ = model.forward(params, batch2, remat=False)
    np.testing.assert_allclose(
        np.asarray(lg2[:, 0]), np.asarray(ref[:, -1]), atol=5e-4, rtol=1e-3
    )


def test_vlm_patch_splice_changes_output():
    cfg = dataclasses.replace(ARCHS["qwen2-vl-7b"].reduced(), dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 300
    toks = jnp.zeros((B, S), jnp.int32)
    pe1 = jnp.ones((B, stubs.VLM_N_PATCHES, cfg.d_model), jnp.float32) * 0.01
    pe2 = -pe1
    l1, _ = model.forward(params, {"tokens": toks, "patch_embeds": pe1}, remat=False)
    l2, _ = model.forward(params, {"tokens": toks, "patch_embeds": pe2}, remat=False)
    assert float(jnp.abs(l1 - l2).max()) > 1e-6
