"""Shared fixtures/helpers.  NOTE: no XLA_FLAGS here — tests must see the
real single CPU device; only launch/dryrun.py forces 512 placeholders."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 560) -> str:
    """Run a python snippet in a subprocess with N fake host devices.

    Used by tests that need a real multi-device mesh (pipeline, halo
    exchange, ring collectives) without polluting this process's jax."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"subprocess failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.default_rng(0)
