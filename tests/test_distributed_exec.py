"""Multi-device semantics: pipeline schedule, halo exchange, ring collectives.

Real multi-device cases run in a subprocess with forced host devices so
this process keeps its single CPU device.
"""

import pytest

from repro.core.pipeline import pipeline_schedule, split_net_at_theta
from tests.conftest import run_with_devices


def test_pipeline_schedule_queue_depth_one():
    """§VII-C: producer may not run ahead; steady state = max stage time."""
    mk, events = pipeline_schedule(5, t_stage0=1.0, t_stage1=2.0)
    # consumer is the bottleneck: makespan = fill(1) + 5*2
    assert abs(mk - 11.0) < 1e-9
    # producer stalls: stage0 of patch t+1 never starts before consumer
    # picked up patch t
    s0 = {t: (s, e) for (st, t, s, e) in events if st == "stage0"}
    s1 = {t: (s, e) for (st, t, s, e) in events if st == "stage1"}
    for t in range(4):
        assert s0[t + 1][0] >= s1[t][0] - 1e-9


def test_pipeline_schedule_balanced_is_ideal():
    mk, _ = pipeline_schedule(100, 1.0, 1.0)
    assert mk <= 102.0  # fill bubble + N steps


def test_split_net():
    a, b = split_net_at_theta(["c", "p", "c", "c"], 2)
    assert a == (0, 1) and b == (2, 3)


@pytest.mark.slow  # subprocess multi-device mesh
def test_pipelined_apply_two_pods():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core.pipeline import pipelined_apply

        mesh = jax.make_mesh((2,), ('pod',))
        stage0 = lambda x: x * 2.0
        stage1 = lambda x: x + 1.0
        T = 6
        xs = jnp.arange(T * 4, dtype=jnp.float32).reshape(T, 4)

        def run(xs):
            return pipelined_apply(stage0, stage1, xs, axis_name='pod')

        f = shard_map(run, mesh=mesh, in_specs=P(None, None), out_specs=P(None, None), check_rep=False)
        ys = f(xs)
        # each pod's stream: stage1(stage0(x_t)) delivered to the *next* pod;
        # with replicated input both pods compute identical streams, so the
        # result equals the functional composition.
        want = xs * 2.0 + 1.0
        np.testing.assert_allclose(np.asarray(ys), np.asarray(want), rtol=1e-6)
        print('PIPE OK')
        """,
        n_devices=2,
    )
    assert "PIPE OK" in out


@pytest.mark.slow  # subprocess multi-device mesh
def test_halo_sharded_convnet_matches_single_device():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
        from repro.core import convnet
        from repro.core.distributed_inference import halo_sharded_apply

        net = ConvNetConfig('t', 1, (L('conv', 3, 4), L('conv', 2, 2)))
        params = convnet.init_params(jax.random.PRNGKey(0), net)
        prims = ['direct', 'direct']
        W = 4                      # chips along x
        cx = 8                     # x extent per chip
        nx = W * cx
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, nx, 10, 10), jnp.float32)

        mesh = jax.make_mesh((W,), ('x',))
        f = shard_map(
            lambda xl: halo_sharded_apply(params, net, xl, prims, axis_name='x'),
            mesh=mesh, in_specs=P(None, None, 'x', None, None),
            out_specs=P(None, None, 'x', None, None),
        )
        got = f(x)
        want = convnet.apply_plan(params, net, x, prims)
        # valid region: all but the last chip's garbage tail (FOV-1 = 3)
        v = nx - 3
        np.testing.assert_allclose(
            np.asarray(got)[:, :, :v], np.asarray(want)[:, :, :v], atol=2e-4, rtol=1e-4)
        print('HALO OK')
        """,
        n_devices=4,
    )
    assert "HALO OK" in out


@pytest.mark.slow  # subprocess multi-device mesh
def test_ring_allgather_matmul():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import ring_allgather_matmul

        A = 4
        K, N = 32, 16
        x = jax.random.normal(jax.random.PRNGKey(0), (8, K))
        w = jax.random.normal(jax.random.PRNGKey(1), (K, N))
        mesh = jax.make_mesh((A,), ('m',))
        f = shard_map(
            lambda xx, ws: ring_allgather_matmul(xx, ws, 'm'),
            mesh=mesh, in_specs=(P(None, None), P('m', None)), out_specs=P(None, None),
            check_rep=False,
        )
        got = f(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(x @ w), atol=1e-3, rtol=1e-4)
        print('RING OK')
        """,
        n_devices=4,
    )
    assert "RING OK" in out


@pytest.mark.slow  # subprocess multi-device mesh
def test_psum_compressed_error_feedback():
    out = run_with_devices(
        """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.collectives import psum_compressed

        mesh = jax.make_mesh((4,), ('p',))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 64))

        def step(gl, err):
            return psum_compressed(gl, 'p', error=err)

        f = shard_map(step, mesh=mesh, in_specs=(P('p', None), P('p', None)),
                      out_specs=(P(None, None), P('p', None)))
        err = jnp.zeros_like(g)
        # accumulated compressed means converge to the true mean over steps
        acc_c = jnp.zeros((1, 64))
        true = jnp.mean(g, axis=0, keepdims=True)
        for _ in range(30):
            mean, err = f(g, err)
            acc_c = acc_c + mean[:1]
        np.testing.assert_allclose(np.asarray(acc_c / 30), np.asarray(true), atol=1e-2)
        print('PSUMC OK')
        """,
        n_devices=4,
    )
    assert "PSUMC OK" in out
