"""Primitive registry + CompiledPlan (ISSUE 2): the ``fft_cached`` path is
real — kernel spectra are computed exactly once per plan and reused across
patches, batch sizes, and sweeps — and the registry is the single place
primitive names resolve to code."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, cost_model, fft_conv, planner, primitives
from repro.core.hw import TPU_V5E
from repro.volume import PlanExecutor

NET = ConvNetConfig(
    "cached-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
N_CONVS = sum(1 for l in NET.layers if l.kind == "conv")


def _cached_prims(net):
    return ["fft_cached" if l.kind == "conv" else "mpf" for l in net.layers]


def _dense(params, net, vol):
    return np.asarray(
        convnet.apply_dense_reference(params, net, jnp.asarray(vol)[None])[0]
    )


def _volume(net, m, rng, extra=(3, 0, 0)):
    fov = net.field_of_view()
    core = m * net.total_pooling()
    shape = tuple(2 * core + e + fov - 1 for e in extra)
    return rng.normal(size=(1,) + shape).astype(np.float32)


# -- the cached path is correct ---------------------------------------------


def test_fft_cached_forced_plan_matches_dense(rng):
    plan = planner.plan_single(
        NET, TPU_V5E, max_m=1, batches=(2,),
        conv_prims=("fft_cached",), strategy_name="fft_cached",
    )
    assert plan is not None
    assert all(c.prim == "fft_cached" for c in plan.choices if c.kind == "conv")
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    vol = _volume(NET, plan.m_final, rng)
    ex = PlanExecutor(params, NET, plan)
    got = ex.run(vol)
    np.testing.assert_allclose(got, _dense(params, NET, vol), atol=1e-3)
    assert ex.last_stats["patches"] > 1  # the sweep actually reused spectra


def test_kernel_fft_runs_exactly_once_per_conv_layer(rng, monkeypatch):
    """The tentpole property: across a multi-patch sweep (with a ragged
    tail batch and a second full sweep), ``kernel_rfftn`` runs exactly
    N_CONVS times — all at compile_plan setup, none per patch."""
    calls = []
    real = fft_conv.kernel_rfftn

    def counted(w, fft_shape):
        calls.append(tuple(fft_shape))
        return real(w, fft_shape)

    monkeypatch.setattr(fft_conv, "kernel_rfftn", counted)
    params = convnet.init_params(jax.random.PRNGKey(1), NET)
    ex = PlanExecutor(params, NET, prims=_cached_prims(NET), m=1, batch=5)
    assert len(calls) == N_CONVS  # setup transformed each conv kernel once

    vol = _volume(NET, 1, rng)
    out1 = ex.run(vol)
    assert ex.last_stats["patches"] % ex.batch != 0  # exercises the tail path
    out2 = ex.run(vol)
    np.testing.assert_allclose(out1, out2, atol=0)
    np.testing.assert_allclose(out1, _dense(params, NET, vol), atol=1e-3)
    assert len(calls) == N_CONVS  # no per-patch / per-compile recompute


def test_compiled_plan_matches_apply_plan(rng):
    """CompiledPlan.apply == the string-prims compatibility walk."""
    params = convnet.init_params(jax.random.PRNGKey(2), NET)
    prims = ["direct", "mpf", "fft_task", "mpf", "fft_cached"]
    compiled = primitives.compile_plan(params, NET, prims=prims, m=1)
    x = jnp.asarray(
        rng.normal(size=(2, 1) + (compiled.n_in,) * 3).astype(np.float32)
    )
    want = convnet.apply_plan(params, NET, x, prims)
    got = compiled.apply(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    # states round-trip as explicit jit arguments (the executor's calling
    # convention): same result, same prepared buffers
    f = jax.jit(lambda states, xs: compiled.apply(xs, states=states))
    got2 = f(compiled.states, x)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want), atol=1e-4)


def test_fft_shapes_are_chosen_at_setup():
    params = convnet.init_params(jax.random.PRNGKey(3), NET)
    compiled = primitives.compile_plan(
        params, NET, prims=_cached_prims(NET), m=1
    )
    n = compiled.n_in
    for pl in compiled.layers:
        if pl.kind == "conv":
            assert pl.fft_shape is not None
            assert all(s >= n for s in pl.fft_shape)  # covers the layer input
            # cached spectra exist and match the chosen FFT shape
            W = pl.state["W"]
            na, nb, nc = pl.fft_shape
            assert W.shape[-3:] == (na, nb, nc // 2 + 1)
            n = n - NET.layers[pl.index].size + 1
        else:
            assert pl.pool_size == NET.layers[pl.index].size
            n = n // pl.pool_size


# -- ragged tail ------------------------------------------------------------


def test_ragged_tail_uses_smaller_batch_not_padding(rng):
    params = convnet.init_params(jax.random.PRNGKey(4), NET)
    ex = PlanExecutor(params, NET, prims=_cached_prims(NET), m=1, batch=4)
    vol = _volume(NET, 1, rng, extra=(3, 3, 0))  # patches not divisible by 4
    got = ex.run(vol)
    s = ex.last_stats
    assert s["patches"] % 4 != 0
    assert s["padded_patches"] == 0
    assert s["batches"] == -(-s["patches"] // 4)
    # the tail ran at its own (smaller) size, full batches at the plan's
    assert ex._seen_batch_sizes == {4, int(s["patches"]) % 4}
    np.testing.assert_allclose(got, _dense(params, NET, vol), atol=1e-3)


def test_padded_batch_size_bounds_serving_compiles():
    """Continuous serving drains arbitrary ready-counts; bucketing keeps the
    distinct compiled batch sizes O(log batch)."""
    params = convnet.init_params(jax.random.PRNGKey(5), NET)
    ex = PlanExecutor(params, NET, prims=_cached_prims(NET), m=1, batch=16)
    assert ex.padded_batch_size(16) == 16
    assert ex.padded_batch_size(20) == 16  # never above the plan batch
    assert ex.padded_batch_size(5) == 8  # bucket up: 5,6,7,8 share a compile
    assert ex.padded_batch_size(1) == 1
    ex._seen_batch_sizes.add(5)  # already compiled -> run exactly
    assert ex.padded_batch_size(5) == 5


# -- bias broadcasting: one rule for every registered conv primitive --------


@pytest.mark.parametrize("name", primitives.registered_conv_names())
def test_conv_apply_bias_matches_dense_oracle_on_ragged_patch(name, rng):
    """ISSUE 3 satellite: the one-shot path and the registry apply agree on
    bias broadcasting for EVERY registered conv primitive, pinned to the
    dense oracle on a ragged patch — anisotropic spatial extent and f'=5
    channels, multiples of neither the Pallas FP_BLOCK nor the x-tile."""
    import jax.numpy as jnp
    from jax import lax

    x = jnp.asarray(rng.normal(size=(2, 3, 9, 8, 7)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(5, 3, 3, 3, 3)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(5,)).astype(np.float32))
    want = lax.conv_general_dilated(
        x, w, (1, 1, 1), "VALID",
        dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
    ) + b.reshape(1, 5, 1, 1, 1)
    got = primitives.conv_apply(name, x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)
    # one-shot path == registry setup+apply (the same prepared state walk)
    prim = primitives.conv_primitive(name)
    pl = prim.setup(w, b, (9, 8, 7))
    got2 = prim.apply(pl, x, pl.state)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(got), atol=0)


@pytest.mark.parametrize("name", primitives.registered_conv_names())
def test_conv_apply_bias_contract_is_uniform(name, rng):
    """Scalar bias broadcasts, wrong-length bias raises — identically for
    every primitive (the pre-fix state let each apply re-derive f' from a
    different tensor, so mismatches failed differently per primitive)."""
    import jax.numpy as jnp

    x = jnp.asarray(rng.normal(size=(1, 2, 7, 7, 7)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 2, 3, 3, 3)).astype(np.float32))
    none = np.asarray(primitives.conv_apply(name, x, w, None))
    scalar = np.asarray(primitives.conv_apply(name, x, w, jnp.float32(0.5)))
    np.testing.assert_allclose(scalar, none + 0.5, atol=1e-5)
    with pytest.raises(ValueError):
        primitives.conv_apply(name, x, w, jnp.zeros((4,), jnp.float32))


# -- one-shot registry apply (sublayer / halo paths) ------------------------


def test_conv_apply_resolves_aliases(rng):
    x = jnp.asarray(rng.normal(size=(1, 2, 6, 6, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 2, 3, 3, 3)).astype(np.float32))
    b = jnp.zeros((3,), jnp.float32)
    want = np.asarray(primitives.conv_apply("fft_task", x, w, b))
    got = np.asarray(primitives.conv_apply("fft", x, w, b))  # sublayer alias
    np.testing.assert_allclose(got, want, atol=1e-5)
    direct = np.asarray(primitives.conv_apply("direct", x, w, b))
    np.testing.assert_allclose(direct, want, atol=1e-3)
    with pytest.raises(ValueError):
        primitives.conv_apply("winograd", x, w, b)


def test_cost_model_dispatch_goes_through_registry():
    c1 = cost_model.conv_cost("fft_cached", 2, 4, 4, (12, 12, 12), 3)
    c2 = cost_model.conv_fft_cached_kernels_cost(2, 4, 4, (12, 12, 12), 3)
    assert c1 == c2
    p1 = cost_model.pool_cost_by_name("mpf", 2, 4, (12, 12, 12), 2)
    assert p1 == cost_model.mpf_cost(2, 4, (12, 12, 12), 2)
    with pytest.raises(ValueError):
        cost_model.conv_cost("nope", 1, 1, 1, (8, 8, 8), 3)


def test_fft_cached_cost_drops_kernel_weight_bytes():
    """Satellite: cached cost drops kernel-FFT flops AND the weights read."""
    S, f, fp, n, k = 2, 8, 16, (16, 16, 16), 3
    task = cost_model.conv_fft_task_parallel_cost(S, f, fp, n, k)
    cached = cost_model.conv_fft_cached_kernels_cost(S, f, fp, n, k)
    assert cached.flops < task.flops
    assert cached.hbm_bytes == task.hbm_bytes - fp * f * k**3 * cost_model.F32
    assert cached.peak_bytes == task.peak_bytes  # spectra residency still paid
