"""ConvNet assembly (C8): plan execution equals the dense sliding-window
oracle; paper net geometry (Table III) is self-consistent."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ZNNI_NETS
from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet

TINY = ConvNetConfig(
    "tiny", 1,
    (L("conv", 2, 4), L("pool", 2), L("conv", 3, 5), L("pool", 2), L("conv", 3, 2)),
)


@pytest.mark.parametrize("prims", [
    ["direct", "mpf", "direct", "mpf", "direct"],
    ["fft_task", "mpf", "fft_data", "mpf", "fft_task"],
    ["fft_data", "mpf", "fft_task", "mpf", "direct"],
])
def test_plan_matches_dense_reference(prims, rng):
    m = 2
    n_in = TINY.valid_input_size(m)
    params = convnet.init_params(jax.random.PRNGKey(0), TINY)
    x = jnp.asarray(rng.normal(size=(1, 1, n_in, n_in, n_in)).astype(np.float32))
    got = convnet.apply_plan(params, TINY, x, prims)
    want = convnet.apply_dense_reference(params, TINY, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-4)


def test_plain_pool_plan_is_one_subsampling(rng):
    """pool (not MPF) computes the stride-P subsampling of the dense output."""
    m = 2
    # plain-pool valid input: conv adds k-1, pool multiplies by p
    n = m
    for layer in reversed(TINY.layers):
        n = n + layer.size - 1 if layer.kind == "conv" else n * layer.size
    params = convnet.init_params(jax.random.PRNGKey(1), TINY)
    x = jnp.asarray(rng.normal(size=(1, 1, n, n, n)).astype(np.float32))
    got = convnet.apply_plan(params, TINY, x, ["direct", "pool", "direct", "pool", "direct"])
    dense = convnet.apply_dense_reference(params, TINY, x)
    want = dense[:, :, :: TINY.total_pooling(), :: TINY.total_pooling(), :: TINY.total_pooling()]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-4)


def test_batch_fragments_bookkeeping(rng):
    m, S = 1, 2
    n_in = TINY.valid_input_size(m)
    params = convnet.init_params(jax.random.PRNGKey(2), TINY)
    x = jnp.asarray(rng.normal(size=(S, 1, n_in, n_in, n_in)).astype(np.float32))
    raw = convnet.apply_plan(params, TINY, x, ["direct", "mpf", "direct", "mpf", "direct"], recombine=False)
    assert raw.shape[0] == S * TINY.total_pooling() ** 3
    rec = convnet.apply_plan(params, TINY, x, ["direct", "mpf", "direct", "mpf", "direct"])
    want = convnet.apply_dense_reference(params, TINY, x)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(want), atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("name", list(ZNNI_NETS))
def test_paper_net_geometry(name):
    net = ZNNI_NETS[name]
    for m in (1, 2, 5):
        n_in = net.valid_input_size(m)
        assert net.output_size(n_in) == m
    # Table III field-of-view sanity: n537 deepest FOV, n337 smallest
    fovs = {k: v.field_of_view() for k, v in ZNNI_NETS.items()}
    assert fovs["n337"] < fovs["n726"] < fovs["n926"] < fovs["n537"]


def test_paper_nets_tiny_forward(rng):
    """Run n337 structure (reduced channels) end-to-end once."""

    net = ZNNI_NETS["n337"]
    small = ConvNetConfig(
        "n337-small", 1,
        tuple(
            L(l.kind, l.size, min(l.out_channels, 4) if l.kind == "conv" else 0)
            for l in net.layers
        ),
    )
    n_in = small.valid_input_size(1)
    params = convnet.init_params(jax.random.PRNGKey(3), small)
    x = jnp.asarray(rng.normal(size=(1, 1, n_in, n_in, n_in)).astype(np.float32))
    prims = ["fft_task" if l.kind == "conv" else "mpf" for l in small.layers]
    out = convnet.apply_plan(params, small, x, prims)
    P = small.total_pooling()
    assert out.shape == (1, 3 if False else small.layers[-1].out_channels, P, P, P)
    assert bool(jnp.isfinite(out).all())
