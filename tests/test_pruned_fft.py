"""C1: pruned FFTs equal the naive pad-then-rfftn transform (ZNNi §III)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import pruned_fft as pf


@pytest.mark.parametrize("in_shape,fft_shape", [
    ((2, 2, 2), (8, 8, 8)),
    ((3, 5, 7), (9, 10, 12)),
    ((5, 5, 5), (5, 5, 5)),  # no padding at all
    ((1, 1, 1), (4, 6, 8)),
    ((4, 3, 2), (16, 3, 2)),  # pad one axis only
])
def test_pruned_forward_matches_naive(in_shape, fft_shape, rng):
    x = jnp.asarray(rng.normal(size=(2, 3) + in_shape).astype(np.float32))
    a = pf.pruned_rfftn(x, fft_shape)
    b = pf.naive_rfftn(x, fft_shape)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_pruned_inverse_with_crop(rng):
    x = jnp.asarray(rng.normal(size=(2, 4, 5, 6)).astype(np.float32))
    fft_shape = (8, 9, 10)
    X = pf.pruned_rfftn(x, fft_shape)
    got = pf.pruned_irfftn(X, fft_shape, (1, 2, 3), (3, 4, 5))
    full = jnp.fft.irfftn(X, s=fft_shape, axes=(-3, -2, -1))
    want = full[..., 1:4, 2:6, 3:8]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fft_correlate_valid_equals_lax_conv(rng):
    from repro.kernels.direct_conv3d import ref as conv_ref

    x = jnp.asarray(rng.normal(size=(1, 1, 9, 8, 7)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(1, 1, 3, 2, 4)).astype(np.float32))
    got = pf.fft_correlate_valid(x[0], w[0])
    want = conv_ref.conv3d(x, w)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_optimal_sizes_are_smooth():
    for n in [1, 2, 17, 97, 100, 127, 129, 250, 333]:
        m = pf.fft_optimal_size(n)
        assert m >= n
        r = m
        for p in (2, 3, 5, 7):
            while r % p == 0:
                r //= p
        assert r == 1, f"{m} not 7-smooth"
        # minimality within the smooth set
        for c in range(n, m):
            rr = c
            for p in (2, 3, 5, 7):
                while rr % p == 0:
                    rr //= p
            assert rr != 1


def test_pruned_speedup_increases_with_padding_ratio():
    """The paper reports ~5-10x for small kernels in large images."""
    s_small = pf.pruned_speedup((3, 3, 3), (128, 128, 128))
    s_large = pf.pruned_speedup((64, 64, 64), (128, 128, 128))
    assert s_small > 2.5  # k << n: most 1D passes pruned (~3x bound per §III-A)
    assert s_small > s_large  # less padding -> less pruning win
    assert s_large >= 1.0


@settings(max_examples=20, deadline=None)
@given(
    a=st.integers(1, 6), b=st.integers(1, 6), c=st.integers(1, 6),
    pa=st.integers(0, 6), pb=st.integers(0, 6), pc=st.integers(0, 6),
)
def test_property_pruned_equals_naive(a, b, c, pa, pb, pc):
    rng = np.random.default_rng(a * 100 + b * 10 + c)
    x = jnp.asarray(rng.normal(size=(1, a, b, c)).astype(np.float32))
    fft_shape = (a + pa, b + pb, c + pc)
    got = pf.pruned_rfftn(x, fft_shape)
    want = pf.naive_rfftn(x, fft_shape)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)
