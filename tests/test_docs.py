"""Docs surface (ISSUE 3 satellites): README/docs exist, internal links
resolve, and the link checker itself works — the same check the CI docs
job runs, kept in tier-1 so a broken link fails locally first."""

import os
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "scripts"))

import check_docs_links  # noqa: E402


def test_readme_and_architecture_doc_exist():
    readme = (REPO / "README.md").read_text()
    arch = (REPO / "docs" / "architecture.md").read_text()
    # README covers the quickstart + package map the issue asks for
    for needle in ("pytest", "volume_throughput", "core", "volume", "serving",
                   "benchmarks", "docs/architecture.md"):
        assert needle in readme, needle
    # architecture doc documents the plan->execution contract + recipe
    for needle in ("CompiledPlan", "compile_plan", "states", "Adding a primitive",
                   "CONV_PRIMS", "overlap_save"):
        assert needle in arch, needle


def test_no_broken_relative_links():
    problems = check_docs_links.broken_links(REPO)
    assert problems == []


def test_link_checker_catches_breakage(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "[ok](docs/a.md) [bad](docs/missing.md) [ext](https://x.invalid/y) "
        "[anchor](#sec) [img skipped] ![alt](missing.png)"
    )
    (tmp_path / "docs" / "a.md").write_text("[up](../README.md)")
    problems = check_docs_links.broken_links(tmp_path)
    assert problems == ["README.md: broken link -> docs/missing.md"]


def test_module_docstrings_state_patch_invariants():
    """The satellite: tiler/executor docstrings carry the geometry
    contract new contributors need (core, FOV overlap, shifted edges)."""
    from repro.volume import executor, tiler

    for mod in (tiler, executor):
        doc = mod.__doc__ or ""
        for needle in ("core", "FOV", "shifted"):
            assert needle in doc, (mod.__name__, needle)


def test_example_commands_in_readme_are_runnable():
    """Quickstart commands reference real files."""
    readme = (REPO / "README.md").read_text()
    for path in ("benchmarks/volume_throughput.py", "benchmarks/table5_throughput.py",
                 "tests/_hypothesis_compat.py"):
        assert path in readme
        assert os.path.exists(REPO / path), path
