"""Fused strip-path epilogue: Pallas (interpret=True) vs ref.py oracles.

Three layers of equivalence, bottom-up:

* ``cmul_mad_bias`` — the fused MAD-accumulate-across-f-chunks + DC-bin
  bias ``pallas_call`` against the einsum+``.at[...,0,0,0]`` oracle,
  across ragged/padded shapes, odd channel counts, and multi-f-chunk
  grids;
* ``mpf_pool_window`` — the fused inverse-window + MPF kernel against
  crop-then-pool, including windows strictly inside the input (the
  uncropped-last-axis case the conv+pool pair produces);
* ``fft_conv_pool_fused`` / ``compile_plan(fuse_pairs=True)`` — the whole
  fused pair against the unfused conv -> bias -> relu -> pool walk,
  including ``fprime_chunk`` splits (which route bias through the chunked
  DC-bin path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ConvLayerSpec as L
from repro.configs.base import ConvNetConfig
from repro.core import convnet
from repro.core.fft_conv import (
    fft_conv_pool_fused,
    fft_conv_task_parallel,
    precompute_kernel_fft,
)
from repro.core.mpf import mpf
from repro.core.primitives import compile_plan
from repro.core.pruned_fft import fft_optimal_shape
from repro.kernels.cmul_mad import ops as cmul_ops
from repro.kernels.cmul_mad import ref as cmul_ref
from repro.kernels.mpf_pool import ops as mp_ops
from repro.kernels.mpf_pool import ref as mp_ref


# --------------------------------------------------------------------------
# cmul_mad_bias: fused MAD + DC-bin bias kernel vs oracle
# --------------------------------------------------------------------------


@pytest.mark.parametrize("S,f,fp,sp", [
    (1, 1, 1, (4, 4, 3)),
    (2, 3, 5, (5, 4, 3)),      # ragged everything
    (1, 17, 9, (8, 9, 9)),     # odd f -> multi f-chunk; B > one bin block
    (3, 8, 2, (2, 3, 3)),      # fp < FP_BLOCK
    (1, 16, 12, (6, 5, 7)),    # exact f-chunk multiple
])
def test_cmul_mad_bias_sweep(S, f, fp, sp, rng):
    X = jnp.asarray(
        (rng.normal(size=(S, f) + sp) + 1j * rng.normal(size=(S, f) + sp))
        .astype(np.complex64)
    )
    W = jnp.asarray(
        (rng.normal(size=(fp, f) + sp) + 1j * rng.normal(size=(fp, f) + sp))
        .astype(np.complex64)
    )
    b = jnp.asarray(rng.normal(size=(fp,)).astype(np.float32))
    got = cmul_ops.cmul_mad_bias(X, W, b, fft_shape=sp, use_pallas=True)
    want = cmul_ref.cmul_mad_bias(X, W, b, sp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


def test_cmul_mad_bias_none_matches_plain(rng):
    sp = (5, 4, 3)
    X = jnp.asarray(
        (rng.normal(size=(2, 3) + sp) + 1j * rng.normal(size=(2, 3) + sp))
        .astype(np.complex64)
    )
    W = jnp.asarray(
        (rng.normal(size=(4, 3) + sp) + 1j * rng.normal(size=(4, 3) + sp))
        .astype(np.complex64)
    )
    got = cmul_ops.cmul_mad_bias(X, W, None, fft_shape=sp, use_pallas=True)
    want = cmul_ref.cmul_mad(X, W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


def test_dc_bin_bias_equals_spatial_bias(rng):
    """Adding b*N to spectral bin (0,0,0) == adding b after the inverse."""
    n, k, f, fp = (7, 7, 7), (3, 3, 3), 3, 5
    fs = fft_optimal_shape(n)
    x = jnp.asarray(rng.normal(size=(2, f) + n).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f) + k).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(fp,)).astype(np.float32))
    W = precompute_kernel_fft(w, fs)
    got = fft_conv_pool_fused(
        x, W, b, fft_shape=fs, k=k, p=2, use_pallas=False, relu=False
    )
    want = mpf(fft_conv_task_parallel(x, w, b, fft_shape=fs, use_pallas=False), 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# mpf_pool_window: fused inverse-window + pool kernel vs crop-then-pool
# --------------------------------------------------------------------------


@pytest.mark.parametrize("S,f,p,n,window", [
    (1, 2, 2, (7, 8, 9), (5, 7, 7)),   # window strictly inside per axis
    (2, 9, 3, (6, 6, 6), (5, 5, 5)),   # f not multiple of F_BLOCK; p=3
    (1, 1, 2, (3, 3, 3), (3, 3, 3)),   # window == input (degenerate crop)
])
def test_mpf_pool_window_sweep(S, f, p, n, window, rng):
    x = jnp.asarray(rng.normal(size=(S, f) + n).astype(np.float32))
    got = mp_ops.mpf_pool_window(x, p, window, use_pallas=True)
    want = mp_ref.mpf_pool_window(x, p, window)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mpf_pool_window_validates():
    x = jnp.zeros((1, 1, 6, 6, 6))
    with pytest.raises(ValueError, match=r"\(window\+1\)%p"):
        mp_ops.mpf_pool_window(x, 2, (4, 5, 5), use_pallas=False)
    with pytest.raises(ValueError, match="larger than input"):
        mp_ops.mpf_pool_window(x, 2, (7, 5, 5), use_pallas=False)


# --------------------------------------------------------------------------
# whole fused pair vs the unfused walk
# --------------------------------------------------------------------------

NET = ConvNetConfig(
    name="fused-test-net",
    in_channels=2,
    layers=(L("conv", 3, 4), L("pool", 2), L("conv", 3, 5), L("pool", 2),
            L("conv", 3, 3)),
)
PRIMS = ("fft_cached", "mpf", "fft_cached", "mpf", "fft_cached")


@pytest.mark.parametrize("fprime_chunk", [None, 3, 1])
def test_compiled_fused_pairs_match_unfused(fprime_chunk, rng):
    """fuse_pairs=True walks bit-match the unfused registry walk, with and
    without fprime_chunk splits (which route bias through the chunked
    DC-bin path)."""
    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    base = compile_plan(params, NET, prims=PRIMS, m=2,
                        use_pallas=False, fuse_pairs=False)
    fused = compile_plan(params, NET, prims=PRIMS, m=2, use_pallas=False,
                         fuse_pairs=True, fprime_chunk=fprime_chunk)
    assert fused.fuse_pairs and not base.fuse_pairs
    x = jnp.asarray(
        rng.normal(size=(2, NET.in_channels) + (base.n_in,) * 3)
        .astype(np.float32)
    )
    y0, y1 = base.apply(x), fused.apply(x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               atol=2e-5, rtol=1e-5)


def test_fused_pair_interpret_pallas_matches_oracle(rng):
    """The fused pair with the Pallas kernels (interpret mode) against the
    pure-XLA fused pair — the end-to-end kernel-dispatch equivalence."""
    n, k, f, fp, p = (9, 9, 9), (3, 3, 3), 2, 3, 2
    fs = fft_optimal_shape(n)
    x = jnp.asarray(rng.normal(size=(1, f) + n).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f) + k).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(fp,)).astype(np.float32))
    W = precompute_kernel_fft(w, fs)
    got = fft_conv_pool_fused(x, W, b, fft_shape=fs, k=k, p=p, use_pallas=True)
    want = fft_conv_pool_fused(x, W, b, fft_shape=fs, k=k, p=p, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-4)


def test_relu_commutes_with_pool(rng):
    """relu(mpf(y)) == mpf(relu(y)) bitwise — the reordering the fused
    epilogue relies on to shrink ReLU to the pooled extent."""
    y = jnp.asarray(rng.normal(size=(2, 3, 7, 7, 7)).astype(np.float32))
    a = jax.nn.relu(mpf(y, 2))
    b = mpf(jax.nn.relu(y), 2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
