"""C5: planner invariants and reproduction of the paper's qualitative claims."""

import pytest

from repro.configs import ZNNI_NETS
from repro.core import planner
from repro.core.hw import TPU_V5E


@pytest.fixture(scope="module")
def plans():
    return {
        name: planner.plan_all_strategies(net, TPU_V5E, chips=256)
        for name, net in ZNNI_NETS.items()
    }


def test_memory_budget_respected(plans):
    for name, ps in plans.items():
        p = ps["single"]
        assert p is not None
        assert p.peak_bytes <= TPU_V5E.hbm_bytes


def test_mpf_beats_naive_baseline(plans):
    """The paper's headline: MPF >> all-subsamplings baseline (Table V)."""
    for name, ps in plans.items():
        if ps["baseline_naive"] is None:
            continue
        assert ps["single"].throughput > 5 * ps["baseline_naive"].throughput, name


def test_fft_wins_for_large_kernels(plans):
    """Table IV structure: interior k>=5 layers (f=f'=80) pick an
    FFT-family primitive (fft_* or the segmented overlap_save variant); the
    first (f=1) and last (f'=3) layers may legitimately pick direct — the
    same per-layer variation the paper's Table IV shows."""
    FFT_FAMILY = ("fft_data", "fft_task", "fft_cached", "overlap_save")
    for name in ("n537", "n726", "n926"):
        convs = [c for c in plans[name]["single"].choices if c.kind == "conv"]
        assert all(c.prim in FFT_FAMILY for c in convs[1:-1]), name
        # and the FFT plan strictly beats a direct-only plan
        assert plans[name]["single"].throughput > plans[name]["direct_only"].throughput


def test_batch_one_is_optimal_single_chip(plans):
    """§VI-A: S=1 maximizes throughput under the memory ceiling (2+ pools)."""
    for name, ps in plans.items():
        assert ps["single"].batch == 1, name


def test_streamed_extends_memory_and_throughput(plans):
    """C6: aggregate-HBM streaming beats the single-chip ceiling (Fig. 7)."""
    for name, ps in plans.items():
        assert ps["streamed"].throughput > ps["single"].throughput, name
        assert ps["streamed"].n_in >= ps["single"].n_in, name


def test_bigger_patch_higher_throughput():
    """§II: throughput grows with patch size (border waste shrinks)."""
    net = ZNNI_NETS["n537"]
    t = []
    for m in (1, 4, 8, 16):
        p = planner.plan_single(net, TPU_V5E, batches=(1,), max_m=m)
        # restrict search to exactly this m by bounding, take best <= m
        t.append(p.throughput)
    assert t == sorted(t)


def test_pipeline_theta_split_valid(plans):
    for name, ps in plans.items():
        p = ps["pipeline2"]
        assert p is not None
        assert 0 < p.theta < len(ZNNI_NETS[name].layers)


def test_plan_summary_prints(plans):
    s = plans["n337"]["single"].summary()
    assert "n337" in s and "L0" in s
