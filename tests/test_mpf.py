"""C4: max-pooling fragments — equivalence with dense sliding-window pooling."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import mpf as mpf_mod


@pytest.mark.parametrize("p,m", [(2, 3), (2, 1), (3, 2)])
def test_mpf_matches_reference(p, m, rng):
    n = p * m + p - 1
    x = jnp.asarray(rng.normal(size=(2, 3, n, n, n)).astype(np.float32))
    got = mpf_mod.mpf(x, p)
    want = mpf_mod.mpf_reference(x, p)
    assert got.shape == (2 * p**3, 3, m, m, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_single_mpf_recombines_to_dense_max_filter(rng):
    """Fragments of one MPF layer tile the stride-1 max filter output."""
    p, m = 2, 3
    n = p * m + p - 1
    x = jnp.asarray(rng.normal(size=(1, 2, n, n, n)).astype(np.float32))
    frags = mpf_mod.mpf(x, p)
    dense = mpf_mod.recombine_fragments(frags, [p], 1)
    want = mpf_mod.naive_sliding_pool(x, p)  # (1, 2, n-p+1 ...)
    # dense covers offsets 0..p-1 strided: dense[v*p + o] == want[v*p + o]
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(want))


def test_two_level_fragment_composition(rng):
    """Offsets of stacked MPF layers compose with stride p1 (§V)."""
    p1, p2 = 2, 2
    m = 1
    n2 = p2 * m + p2 - 1  # input to pool2 per fragment
    n1 = p1 * n2 + p1 - 1
    x = jnp.asarray(rng.normal(size=(1, 1, n1, n1, n1)).astype(np.float32))
    y = mpf_mod.mpf(mpf_mod.mpf(x, p1), p2)
    dense = mpf_mod.recombine_fragments(y, [p1, p2], 1)
    # oracle: dense sliding window of pool2(pool1(.)) == dilated max filters
    from repro.core.convnet import _dilated_max_filter

    want = _dilated_max_filter(x, p1, 1)
    want = _dilated_max_filter(want, p2, p1)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(p=st.integers(2, 3), m=st.integers(1, 3), f=st.integers(1, 3))
def test_property_mpf_fragment_values_are_pool_outputs(p, m, f):
    rng = np.random.default_rng(p * 10 + m)
    n = p * m + p - 1
    x = jnp.asarray(rng.normal(size=(1, f, n, n, n)).astype(np.float32))
    frags = np.asarray(mpf_mod.mpf(x, p))
    xn = np.asarray(x)
    for o, (ox, oy, oz) in enumerate(itertools.product(range(p), repeat=3)):
        for v in itertools.product(range(m), repeat=3):
            blk = xn[0, :, ox + v[0] * p: ox + v[0] * p + p,
                     oy + v[1] * p: oy + v[1] * p + p,
                     oz + v[2] * p: oz + v[2] * p + p]
            np.testing.assert_array_equal(
                frags[o, :, v[0], v[1], v[2]], blk.max(axis=(1, 2, 3))
            )
