"""Planner invariants across every strategy in plan_all_strategies:
budget compliance, MPF divisibility of n_in, out_voxels/m_final
consistency, and the runtime-geometry metadata the volume executor binds
to (ISSUE 1 satellite)."""

import pytest

from repro.configs import ZNNI_NETS
from repro.core import planner
from repro.core.hw import TPU_V5E

CHIPS = 16


@pytest.fixture(scope="module")
def all_plans():
    return {
        name: planner.plan_all_strategies(net, TPU_V5E, chips=CHIPS)
        for name, net in ZNNI_NETS.items()
    }


def _budget(strategy: str) -> float:
    hbm = TPU_V5E.hbm_bytes
    return {
        "single": hbm,
        "streamed": hbm * CHIPS,
        "pipeline2": hbm * (CHIPS // 2),
        "spatial": hbm,
        "baseline_naive": hbm,
        "direct_only": hbm,
    }[strategy]


def _iter_plans(all_plans):
    for name, plans in all_plans.items():
        for strategy, plan in plans.items():
            if strategy == "infeasible":  # the budget-rejection report
                continue
            if plan is not None:
                yield name, strategy, plan


def test_every_strategy_produces_a_plan(all_plans):
    for name, plans in all_plans.items():
        for strategy, plan in plans.items():
            if strategy == "infeasible":
                # unconstrained searches reject nothing: the report key is
                # present (rectangular output) and empty
                assert plan == ()
                continue
            assert plan is not None, f"{name}/{strategy} infeasible"


def test_peak_bytes_within_budget(all_plans):
    for name, strategy, plan in _iter_plans(all_plans):
        assert plan.peak_bytes <= _budget(strategy), (name, strategy)
        assert plan.peak_bytes > 0, (name, strategy)
        assert plan.memory is not None and plan.memory.device_bytes > 0


def test_n_in_satisfies_pooling_divisibility(all_plans):
    """Walk n_in forward through the plan's own primitives: MPF pools need
    (n+1) % p == 0, plain pools need n % p == 0, and the final fragment
    size must equal m_final."""
    for name, strategy, plan in _iter_plans(all_plans):
        net = ZNNI_NETS[name]
        n = plan.n_in
        for layer, prim in zip(net.layers, plan.prims):
            if layer.kind == "conv":
                n -= layer.size - 1
            elif prim == "mpf":
                assert (n + 1) % layer.size == 0, (name, strategy, n)
                n //= layer.size
            else:
                assert n % layer.size == 0, (name, strategy, n)
                n //= layer.size
            assert n > 0, (name, strategy)
        assert n == plan.m_final, (name, strategy)


def test_out_voxels_consistent_with_m_final(all_plans):
    for name, strategy, plan in _iter_plans(all_plans):
        net = ZNNI_NETS[name]
        P = net.total_pooling()
        if strategy == "baseline_naive":
            # one subsampling per pass: m³ effective voxels per call
            want = plan.batch * float(plan.m_final) ** 3
        elif strategy == "spatial":
            want = plan.chips * plan.batch * float(plan.m_final * P) ** 3
        else:
            want = plan.batch * float(plan.m_final * P) ** 3
        assert plan.out_voxels == pytest.approx(want), (name, strategy)


def test_runtime_geometry_metadata(all_plans):
    """The Plan fields the volume runtime binds to (fov/core/extent)."""
    for name, strategy, plan in _iter_plans(all_plans):
        net = ZNNI_NETS[name]
        assert plan.fov == net.field_of_view(), (name, strategy)
        assert plan.core == plan.m_final * net.total_pooling(), (name, strategy)
        assert plan.overlap == plan.fov - 1
        assert plan.patch_extent == plan.core + plan.fov - 1
        if plan.uses_mpf:
            assert plan.patch_extent == plan.n_in, (name, strategy)
        else:
            assert plan.patch_extent == plan.n_in + net.total_pooling() - 1
        assert len(plan.prims) == len(net.layers)


def test_layer_chain_shapes_are_consistent(all_plans):
    """Each choice's out_shape is the next choice's in_shape."""
    for name, strategy, plan in _iter_plans(all_plans):
        for a, b in zip(plan.choices, plan.choices[1:]):
            assert a.out_shape == b.in_shape, (name, strategy, a.index)


def test_cost_model_names_and_runtime_registry_agree():
    """No string drift: every name the planner enumerates resolves in the
    runtime registry, and every registered primitive is enumerable — the
    bug class where a costed primitive silently executes as another one
    (ISSUE 2) cannot reappear."""
    from repro.core import cost_model, primitives

    assert set(cost_model.CONV_PRIMS) == set(primitives.registered_conv_names())
    assert set(cost_model.POOL_PRIMS) == set(primitives.registered_pool_names())
    for name in cost_model.CONV_PRIMS:
        p = primitives.conv_primitive(name)
        assert p.kind == "conv" and p.name == name
        assert callable(p.cost) and callable(p.setup) and callable(p.apply)
    for name in cost_model.POOL_PRIMS:
        p = primitives.pool_primitive(name)
        assert p.kind == "pool" and p.name == name
        assert callable(p.cost) and callable(p.setup) and callable(p.apply)


def test_every_planned_prim_resolves_in_registry(all_plans):
    from repro.core import primitives

    for name, strategy, plan in _iter_plans(all_plans):
        for choice in plan.choices:
            prim = primitives.get_primitive(choice.prim)
            assert prim.kind == choice.kind, (name, strategy, choice.index)
