"""End-to-end smoke of the paper's Table III nets through ``VolumeEngine``.

Wires ``configs/znni_nets.py`` into the serving stack: every net (n337,
n537, n726, n926) must *plan* — ``plan_fixed`` over the reuse-capable mix
(overlap-save at the input conv, direct deeper convs, MPF pools) on a
minimal one-patch volume — and *admit* a request into a ``VolumeEngine``
built from that plan.  The full serve (drain + finite output of the right
shape) runs unmarked for n337; the bigger nets' serves are ``slow`` —
their FOVs (163/117/155) make even one patch minutes of compute.

Direct convolution deeper in the net (rather than fft_cached) keeps the
compile and memory footprint CI-sized: cached kernel spectra for 80-map
layers at these FOVs are GBs, the direct path is MBs.
"""

import numpy as np
import pytest

import jax

from repro.configs.znni_nets import ZNNI_NETS, net_by_name
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.serving import VolumeEngine, VolumeRequest

NAMES = tuple(ZNNI_NETS)  # n337, n537, n726, n926


def _mix(net):
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    return [
        "overlap_save" if i == first_conv
        else ("direct" if l.kind == "conv" else "mpf")
        for i, l in enumerate(net.layers)
    ]


def _one_patch_shape(net):
    """Smallest volume shape serving exactly one output patch at m=1."""
    p = net.total_pooling()
    return (p + net.field_of_view() - 1,) * 3


@pytest.mark.parametrize("name", NAMES)
def test_plan_and_admit(name):
    """Every Table III net prices a fixed reuse mix and admits a request."""
    net = net_by_name(name)
    shape = _one_patch_shape(net)
    plan = planner.plan_fixed(
        net, TPU_V5E, _mix(net), m=1, batch=1, volume_shape=shape
    )
    assert plan is not None, f"{name} failed to plan"
    assert plan.throughput > 0
    assert plan.sweep is not None  # sweep-count simulation ran
    params = convnet.init_params(jax.random.PRNGKey(0), net)
    eng = VolumeEngine(
        params, net, plan, batch=1, deep_reuse=False, bucket_shapes=False
    )
    vol = np.zeros((1,) + shape, np.float32)
    req = VolumeRequest(rid=0, volume=vol)
    eng.submit(req)
    assert req._remaining == 1  # one-patch tiling admitted
    assert req.out.shape == (net.layers[-1].out_channels,) + (net.total_pooling(),) * 3


@pytest.mark.parametrize(
    "name",
    [
        "n337",
        pytest.param("n537", marks=pytest.mark.slow),
        pytest.param("n726", marks=pytest.mark.slow),
        pytest.param("n926", marks=pytest.mark.slow),
    ],
)
def test_serve_one_volume(name, rng):
    """The net serves a one-patch volume end to end: drained queue, finite
    output of shape (out_maps, P, P, P)."""
    net = net_by_name(name)
    shape = _one_patch_shape(net)
    plan = planner.plan_fixed(
        net, TPU_V5E, _mix(net), m=1, batch=1, volume_shape=shape
    )
    params = convnet.init_params(jax.random.PRNGKey(1), net)
    eng = VolumeEngine(
        params, net, plan, batch=1, deep_reuse=False, bucket_shapes=False
    )
    vol = rng.normal(size=(1,) + shape).astype(np.float32) * 0.1
    req = VolumeRequest(rid=0, volume=vol)
    eng.submit(req)
    done = eng.run_until_drained()
    assert [r.rid for r in done] == [0]
    p = net.total_pooling()
    assert req.out.shape == (net.layers[-1].out_channels, p, p, p)
    assert np.all(np.isfinite(req.out))
    assert float(np.abs(req.out).max()) > 0
