"""C2: FFT-based conv layers equal direct convolution (all variants)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fft_conv
from repro.core.pruned_fft import fft_optimal_shape
from repro.kernels.direct_conv3d import ref as conv_ref


@pytest.mark.parametrize("S,f,fp,n,k", [
    (1, 1, 1, 8, 3),
    (2, 3, 5, 10, 3),
    (1, 4, 4, 12, 5),
    (2, 2, 7, 9, 2),
    (1, 8, 8, 7, 7),  # kernel == almost image
])
def test_variants_match_direct(S, f, fp, n, k, rng):
    x = jnp.asarray(rng.normal(size=(S, f, n, n, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f, k, k, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(fp,)).astype(np.float32))
    want = conv_ref.conv3d(x, w) + b.reshape(1, -1, 1, 1, 1)
    got_task = fft_conv.fft_conv_task_parallel(x, w, b)
    got_data = fft_conv.fft_conv_data_parallel(x, w, b, fprime_chunk=3)
    np.testing.assert_allclose(np.asarray(got_task), np.asarray(want), atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_data), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_precomputed_kernel_spectra_path(rng):
    """The inference-service path: kernel FFTs cached across patches."""
    S, f, fp, n, k = 2, 3, 4, 11, 3
    x = jnp.asarray(rng.normal(size=(S, f, n, n, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f, k, k, k)).astype(np.float32))
    fft_shape = fft_optimal_shape((n, n, n))
    W = fft_conv.precompute_kernel_fft(w, fft_shape)
    got = fft_conv.fft_conv_with_precomputed(x, W, None, fft_shape, (k, k, k))
    want = conv_ref.conv3d(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_anisotropic_shapes(rng):
    x = jnp.asarray(rng.normal(size=(1, 2, 9, 11, 8)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 2, 2, 3, 4)).astype(np.float32))
    got = fft_conv.fft_conv_task_parallel(x, w)
    want = conv_ref.conv3d(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_streamed_sublayer_decomposition(rng):
    """C6: Fig. 6 sub-layer splits produce identical results."""
    from repro.core import sublayer

    S, f, fp, n, k = 4, 3, 7, 9, 3
    x = jnp.asarray(rng.normal(size=(S, f, n, n, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f, k, k, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(fp,)).astype(np.float32))
    want = conv_ref.conv3d(x, w) + b.reshape(1, -1, 1, 1, 1)
    got_fp = sublayer.streamed_conv_out_channels(x, w, b, chunk=3, variant="fft")
    got_b = sublayer.streamed_conv_batch(x, w, b, chunk=2, variant="direct")
    np.testing.assert_allclose(np.asarray(got_fp), np.asarray(want), atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want), atol=1e-3, rtol=1e-4)
