"""End-to-end training loop + checkpoint/restart (fault-tolerance drill)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.launch.train import train_loop


def test_training_loss_decreases(tmp_path):
    out = train_loop(
        arch="qwen1.5-4b", steps=30, batch=4, seq=64, reduced=True,
        ckpt_dir=None, lr=3e-3, log_every=1000,
    )
    losses = out["losses"]
    assert np.mean(losses[:5]) > np.mean(losses[-5:]), "loss did not decrease"


@pytest.mark.slow  # ~20s: two train loops + restore
def test_checkpoint_restart_is_deterministic(tmp_path):
    """Kill at step 20 of 30, restore, and land on the same loss curve."""
    d1 = os.path.join(tmp_path, "a")
    d2 = os.path.join(tmp_path, "b")
    full = train_loop(
        arch="qwen1.5-4b", steps=30, batch=4, seq=64, reduced=True,
        ckpt_dir=d1, ckpt_every=10, lr=3e-3, log_every=1000,
    )
    # simulated crash: run only 20 steps, checkpointing every 10
    train_loop(
        arch="qwen1.5-4b", steps=20, batch=4, seq=64, reduced=True,
        ckpt_dir=d2, ckpt_every=10, lr=3e-3, log_every=1000,
    )
    resumed = train_loop(
        arch="qwen1.5-4b", steps=30, batch=4, seq=64, reduced=True,
        ckpt_dir=d2, ckpt_every=10, lr=3e-3, log_every=1000, resume=True,
    )
    # the resumed run continues from step 20 and matches the full run's tail
    np.testing.assert_allclose(
        np.asarray(resumed["losses"]), np.asarray(full["losses"][20:]), rtol=1e-4
    )


def test_checkpoint_roundtrip_and_prune(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, tree)
    assert ckpt.latest_step(str(tmp_path)) == 4
    ckpt.prune_old(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    assert len(os.listdir(tmp_path)) == 2
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = ckpt.restore(str(tmp_path), 4, like)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_async_checkpoint_is_atomic(tmp_path):
    tree = {"w": jnp.ones((256, 256))}
    t = ckpt.save(str(tmp_path), 7, tree, async_=True)
    t.join()
    assert ckpt.latest_step(str(tmp_path)) == 7
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
