"""Fault drills for the sharded serving fleet (ISSUE 8).

Every drill is deterministic: ``tests/_fault_harness.FaultScript`` injects
death/slowdown at a chosen tick, the engine clock is synthetic (no
wall-clock sleeps), and the ``HeartbeatMonitor`` deadline math runs on
scripted step times.  The acceptance property throughout: whatever the
fleet suffers, the output stays **bitwise equal** to the single-device
engine — recovery replays shards from their retained boundary packages,
and replayed completions are dropped idempotently.
"""

import numpy as np

import jax

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet
from repro.serving import ShardedVolumeEngine, VolumeEngine, VolumeRequest

from _fault_harness import FaultScript

import pytest

NET = ConvNetConfig(
    "fault-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()
XC = 8  # planes per sweep: shard 0 = planes 0-3 (worker 0), shard 1 = 4-7


@pytest.fixture(scope="module")
def params():
    return convnet.init_params(jax.random.PRNGKey(3), NET)


@pytest.fixture(scope="module")
def volume():
    rng = np.random.default_rng(8)
    shape = (XC * CORE + FOV - 1, CORE + FOV - 1, CORE + FOV - 1)
    return rng.normal(size=(1,) + shape).astype(np.float32)


@pytest.fixture(scope="module")
def reference(params, volume):
    eng = VolumeEngine(params, NET, prims=MIX, m=1, batch=3, tuned=None)
    req = VolumeRequest(0, volume)
    eng.submit(req)
    eng.run_until_drained()
    return req.out


def _fleet(params, faults, **kw):
    return ShardedVolumeEngine(
        params, NET, prims=MIX, m=1, batch=3, tuned=None,
        n_workers=2, fault_hooks=faults, **kw,
    )


def test_worker_death_redispatches_bitwise(params, volume, reference):
    """Kill worker 1 mid-shard: its unfinished planes re-queue onto the
    survivor as a replay from the retained boundary package; the output
    is still bitwise-equal and every counter is exactly accountable."""
    faults = FaultScript().kill(1, at_tick=5)
    eng = _fleet(params, faults)
    req = VolumeRequest(0, volume)
    eng.submit(req)
    eng.run_until_drained()
    st = eng.last_stats
    assert np.array_equal(req.out, reference)  # BITWISE under failure
    assert st["redispatches"] == 1
    assert st["alive_workers"] == 1
    # worker 1 finished some patches before dying; the replay re-completed
    # them and the done-set dropped every one
    assert st["duplicates_dropped"] >= 1
    # halo accounting stays exact: the no-fault schedule predicts the
    # boundary package once (worker 1's import); the replay imports the
    # SAME package again on the survivor, so measured = predicted + one
    # extra delivery of that boundary — nothing else moved
    pred = st["predicted_halo_bytes_in"]
    boundary_bytes = pred[1]
    assert boundary_bytes > 0
    assert st["halo_exchange_bytes"] == sum(pred) + boundary_bytes
    assert st["halo_bytes_in"] == [boundary_bytes, boundary_bytes]


def test_straggler_rebalances_before_evict(params, volume, reference):
    """A slow-but-alive worker keeps heartbeating, so the policy REBALANCEs
    (its trailing unstarted planes split off to the fast worker) and never
    EVICTs; the contiguous re-partition keeps the output bitwise."""
    faults = FaultScript().slow(1, at_tick=0, factor=5.0)
    eng = _fleet(params, faults)
    req = VolumeRequest(0, volume)
    eng.submit(req)
    shard_planes = len(req._tasks[1].planes)
    eng.run_until_drained()
    st = eng.last_stats
    assert np.array_equal(req.out, reference)
    assert st["rebalances"] >= 1
    assert st["redispatches"] == 0  # shrunk, not evicted
    assert st["alive_workers"] == 2
    # the straggler's plane share really shrank...
    straggler_task = eng.workers[1].tasks[0]
    assert len(straggler_task.planes) < shard_planes
    # ...and the split-off tail ran on the other worker
    assert any(
        t.req is req and t.planes and t.planes[0] > straggler_task.planes[-1]
        for t in eng.workers[0].tasks
    )


def test_revived_worker_duplicates_dropped(params, volume, reference):
    """Kill, recover via re-dispatch, then revive the dead worker: it
    finishes its zombie shard, and every completion lands in the request's
    done-set as a duplicate — dropped idempotently, output unchanged."""
    faults = FaultScript().kill(1, at_tick=5)
    eng = _fleet(params, faults)
    req = VolumeRequest(0, volume)
    eng.submit(req)
    eng.run_until_drained()
    assert req.done and np.array_equal(req.out, reference)
    dups_before = eng.last_stats["duplicates_dropped"]
    zombie = eng.workers[1].tasks[0]
    assert zombie.zombie and not zombie.done and len(zombie.queue) > 0
    pending = len(zombie.queue)
    # the worker comes back: both the script and the engine re-admit it
    faults.revive(1, at_tick=eng.ticks)
    eng.revive_worker(1)
    for _ in range(pending + 2):
        eng.step()
    assert zombie.done
    assert eng.last_stats["duplicates_dropped"] == dups_before + pending
    assert np.array_equal(req.out, reference)  # replays never corrupt


def test_death_before_handoff_replays_from_start(params, volume, reference):
    """Worker 0 dies before exporting its boundary: the whole first shard
    replays on worker 1, which then hands off to ITSELF-chained successor
    state and finishes the sweep alone, still bitwise."""
    faults = FaultScript().kill(0, at_tick=1)
    eng = _fleet(params, faults)
    req = VolumeRequest(0, volume)
    eng.submit(req)
    eng.run_until_drained()
    st = eng.last_stats
    assert np.array_equal(req.out, reference)
    assert st["redispatches"] == 1
    # both shards ultimately ran on worker 1, with the boundary package
    # exchanged between its own two sweep scopes
    assert st["halo_bytes_in"][1] == st["predicted_halo_bytes_in"][1]
