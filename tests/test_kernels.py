"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cmul_mad import ops as cmul_ops, ref as cmul_ref
from repro.kernels.decode_attn import ops as da_ops, ref as da_ref
from repro.kernels.direct_conv3d import ops as c3_ops, ref as c3_ref
from repro.kernels.mpf_pool import ops as mp_ops, ref as mp_ref


# --------------------------------------------------------------------------
# cmul_mad
# --------------------------------------------------------------------------


@pytest.mark.parametrize("S,f,fp,sp", [
    (1, 1, 1, (4, 4, 3)),
    (2, 3, 5, (5, 4, 3)),
    (1, 8, 16, (8, 8, 5)),
    (3, 2, 9, (7, 3, 2)),  # fp not multiple of FP_BLOCK
])
def test_cmul_mad_sweep(S, f, fp, sp, rng):
    X = jnp.asarray((rng.normal(size=(S, f) + sp) + 1j * rng.normal(size=(S, f) + sp)).astype(np.complex64))
    W = jnp.asarray((rng.normal(size=(fp, f) + sp) + 1j * rng.normal(size=(fp, f) + sp)).astype(np.complex64))
    got = cmul_ops.cmul_mad(X, W, use_pallas=True)
    want = cmul_ref.cmul_mad(X, W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# direct_conv3d
# --------------------------------------------------------------------------


@pytest.mark.parametrize("S,f,fp,n,k", [
    (1, 1, 1, 6, 2),
    (2, 3, 5, 8, 3),
    # heavy cases (~20s combined): fp not multiple of FP_BLOCK / odd n'
    pytest.param(1, 4, 9, 9, 5, marks=pytest.mark.slow),
    pytest.param(1, 2, 8, 11, 7, marks=pytest.mark.slow),
])
def test_direct_conv3d_sweep(S, f, fp, n, k, rng):
    x = jnp.asarray(rng.normal(size=(S, f, n, n, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f, k, k, k)).astype(np.float32))
    got = c3_ops.conv3d(x, w, use_pallas=True)
    want = c3_ref.conv3d(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


# --------------------------------------------------------------------------
# mpf_pool
# --------------------------------------------------------------------------


@pytest.mark.parametrize("S,f,p,m", [
    (1, 1, 2, 2),
    (2, 3, 2, 3),
    (1, 9, 3, 1),  # f not multiple of F_BLOCK
])
def test_mpf_pool_sweep(S, f, p, m, rng):
    n = p * m + p - 1
    x = jnp.asarray(rng.normal(size=(S, f, n, n, n)).astype(np.float32))
    got = mp_ops.mpf_pool(x, p, use_pallas=True)
    want = mp_ref.mpf_pool(x, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_mpf_pool_rejects_bad_sizes(rng):
    x = jnp.zeros((1, 1, 4, 4, 4))
    with pytest.raises(ValueError):
        mp_ops.mpf_pool(x, 2)


# --------------------------------------------------------------------------
# decode_attn
# --------------------------------------------------------------------------


@pytest.mark.parametrize("B,H,Hkv,S,d,dtype", [
    (1, 4, 4, 128, 32, np.float32),      # MHA
    (2, 8, 2, 600, 16, np.float32),      # GQA, S not multiple of S_BLOCK
    (2, 8, 1, 1024, 64, np.float32),     # MQA
    (2, 4, 2, 513, 32, "bfloat16"),      # bf16 + ragged S
])
def test_decode_attn_sweep(B, H, Hkv, S, d, dtype, rng):
    dt = jnp.dtype(dtype)
    q = jnp.asarray(rng.normal(size=(B, H, d)).astype(np.float32)).astype(dt)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, d)).astype(np.float32)).astype(dt)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, d)).astype(np.float32)).astype(dt)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=(B,)).astype(np.int32))
    got = da_ops.decode_attn(q, k, v, lengths, use_pallas=True)
    want = da_ref.decode_attn(q, k, v, lengths)
    atol = 2e-2 if dt == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=atol, rtol=1e-2
    )


def test_decode_attn_masks_beyond_length(rng):
    """Entries past `lengths` must not affect the output."""
    B, H, Hkv, S, d = 1, 2, 2, 256, 16
    q = jnp.asarray(rng.normal(size=(B, H, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, d)).astype(np.float32))
    lengths = jnp.array([100], jnp.int32)
    out1 = da_ops.decode_attn(q, k, v, lengths, use_pallas=True)
    k2 = k.at[:, 100:].set(1e6)
    v2 = v.at[:, 100:].set(-1e6)
    out2 = da_ops.decode_attn(q, k2, v2, lengths, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)
