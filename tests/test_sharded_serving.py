"""Sharded serving fleet parity (ISSUE 8).

The acceptance property: ``ShardedVolumeEngine`` output is **bitwise**
equal to the single-device ``VolumeEngine`` for N ∈ {1, 2, 3} workers —
across interior, bucketed-ragged, and shifted-edge volumes — because a
shard is exactly a window of the single-device sweep schedule and the
boundary ``HaloPackage`` reconstructs the cache state bit-for-bit.  Strip
finalization order is preserved, and the measured per-worker
halo-exchange bytes equal the tiler's predicted schedule EXACTLY
(``predict_shard_handoff`` counts x ``handoff_entry_nbytes`` sizes).

The property test (hypothesis, deterministic-grid fallback via
``_hypothesis_compat``) checks the plane partition invariants for
arbitrary (x-extent, worker count, FOV): full single coverage, symmetric
halo pairs at every boundary, per-worker slab within its RAM share.
"""

import math

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet
from repro.serving import ShardedVolumeEngine, VolumeEngine, VolumeRequest
from repro.volume.tiler import (
    plane_shards,
    plane_starts,
    shard_input_span,
    tile_volume,
)

from _hypothesis_compat import given, settings, st

import pytest

NET = ConvNetConfig(
    "sharded-toy", 1,
    (L("conv", 3, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
)
MIX = [
    "overlap_save" if i == 0 else ("fft_cached" if l.kind == "conv" else "mpf")
    for i, l in enumerate(NET.layers)
]
FOV = NET.field_of_view()
CORE = NET.total_pooling()

# volume scenarios: interior (plane grid exact), ragged (bucket padding +
# output crop), shifted (bucketing off -> true shifted edge planes on
# every axis, including a non-core-aligned x plane that runs full-path)
SCENARIOS = {
    "interior": dict(extra=(0, 0, 0), xc=5, bucket=True),
    "ragged": dict(extra=(3, 1, 2), xc=4, bucket=True),
    "shifted": dict(extra=(2, 1, 0), xc=4, bucket=False),
}


def _vol(seed, xc, extra):
    rng = np.random.default_rng(seed)
    shape = (
        xc * CORE + extra[0] + FOV - 1,
        CORE + extra[1] + FOV - 1,
        CORE + extra[2] + FOV - 1,
    )
    return rng.normal(size=(1,) + shape).astype(np.float32)


@pytest.fixture(scope="module")
def params():
    return convnet.init_params(jax.random.PRNGKey(0), NET)


@pytest.fixture(scope="module")
def references(params):
    """Single-device VolumeEngine output + strip order per scenario."""
    out = {}
    for seed, (name, sc) in enumerate(SCENARIOS.items()):
        vol = _vol(seed, sc["xc"], sc["extra"])
        eng = VolumeEngine(
            params, NET, prims=MIX, m=1, batch=3, tuned=None,
            bucket_shapes=sc["bucket"],
        )
        strips = []
        req = VolumeRequest(0, vol)
        req.on_strip = lambda lo, hi, s, acc=strips: acc.append((lo, hi))
        eng.submit(req)
        eng.run_until_drained()
        assert req.done
        dense = np.asarray(
            convnet.apply_dense_reference(params, NET, jnp.asarray(vol)[None])[0]
        )
        np.testing.assert_allclose(req.out, dense, atol=1e-3)
        out[name] = (vol, req.out, strips)
    return out


def _run_sharded(params, vol, *, n_workers, batch=3, bucket=True):
    eng = ShardedVolumeEngine(
        params, NET, prims=MIX, m=1, batch=batch, tuned=None,
        n_workers=n_workers, bucket_shapes=bucket,
    )
    strips = []
    req = VolumeRequest(0, vol)
    req.on_strip = lambda lo, hi, s, acc=strips: acc.append((lo, hi))
    eng.submit(req)
    eng.run_until_drained()
    assert req.done
    return eng, req, strips


@pytest.mark.parametrize("n_workers", [1, 2, 3])
def test_bitwise_parity_interior(params, references, n_workers):
    vol, ref_out, ref_strips = references["interior"]
    eng, req, strips = _run_sharded(params, vol, n_workers=n_workers)
    assert np.array_equal(req.out, ref_out)  # BITWISE, not allclose
    assert strips == ref_strips  # identical strip finalization order
    st_ = eng.last_stats
    assert st_["redispatches"] == 0 and st_["duplicates_dropped"] == 0
    # measured per-worker halo-exchange bytes == the tiler's schedule
    assert st_["halo_bytes_in"] == st_["predicted_halo_bytes_in"]
    if n_workers > 1:
        assert st_["halo_exchange_bytes"] > 0


@pytest.mark.parametrize(
    "scenario,n_workers", [("ragged", 2), ("shifted", 3)]
)
def test_bitwise_parity_edge_volumes(params, references, scenario, n_workers):
    vol, ref_out, ref_strips = references[scenario]
    eng, req, strips = _run_sharded(
        params, vol, n_workers=n_workers,
        bucket=SCENARIOS[scenario]["bucket"],
    )
    assert np.array_equal(req.out, ref_out)
    assert strips == ref_strips
    assert eng.last_stats["halo_bytes_in"] == eng.last_stats["predicted_halo_bytes_in"]


def test_bitwise_parity_batch_one(params, references):
    """Chunk-size independence: batch 1 shards == batch 3 single device
    is NOT required (different strip schedules) — batch must match.  At
    batch 1 both sides run one patch per chunk; parity still bitwise."""
    vol, _, _ = references["interior"]
    ref = VolumeEngine(params, NET, prims=MIX, m=1, batch=1, tuned=None)
    rref = VolumeRequest(0, vol)
    ref.submit(rref)
    ref.run_until_drained()
    eng, req, _ = _run_sharded(params, vol, n_workers=2, batch=1)
    assert np.array_equal(req.out, rref.out)
    assert eng.last_stats["halo_bytes_in"] == eng.last_stats["predicted_halo_bytes_in"]


def test_admission_and_buckets(params, references):
    """saxml contract: sorted batch buckets; max_live_batches admission."""
    vol, ref_out, _ = references["interior"]
    eng = ShardedVolumeEngine(
        params, NET, prims=MIX, m=1, batch=3, tuned=None,
        n_workers=2, max_live_batches=1,
    )
    assert list(eng.batch_buckets) == sorted(eng.batch_buckets)
    assert eng.batch_buckets[-1] == eng.batch
    reqs = [VolumeRequest(i, vol) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    # only one request holds runtime state; the rest wait in admission
    assert len(eng.live) == 1 and len(eng.pending) == 2
    eng.run_until_drained()
    assert len(eng.finished) == 3
    for r in reqs:
        assert np.array_equal(r.out, ref_out)


# ---------------------------------------------------------------------------
# Property: plane partition invariants (arbitrary extent / workers / FOV)
# ---------------------------------------------------------------------------


@settings(max_examples=24, deadline=None)
@given(
    xc=st.integers(1, 6),
    extra=st.integers(0, 3),
    n_workers=st.integers(1, 5),
    fov=st.sampled_from([3, 5, 9]),
)
def test_plane_partition_properties(xc, extra, n_workers, fov):
    core = 4
    shape = (xc * core + extra + fov - 1, core + fov - 1, core + fov - 1)
    tiling = tile_volume(shape, core=core, fov=fov)
    shards = plane_shards(tiling, n_workers)
    planes = plane_starts(tiling)
    assert len(shards) == n_workers
    # 1. every plane covered exactly once, in sweep order
    assert [x for s in shards for x in s] == list(planes)
    # 2. halo pairs symmetric: at each boundary the exporter's trailing
    # input rows and the importer's leading input rows are the SAME
    # interval, of at least FOV-1 rows (exactly FOV-1 at core-spaced
    # boundaries; more when a shifted edge plane overlaps deeper)
    nonempty = [s for s in shards if s]
    for a, b in zip(nonempty, nonempty[1:]):
        _, hi_a = shard_input_span(tiling, a)
        lo_b, _ = shard_input_span(tiling, b)
        overlap = hi_a - lo_b
        assert overlap == tiling.extent - (b[0] - a[-1])
        assert overlap >= fov - 1
        if b[0] - a[-1] == core:
            assert overlap == fov - 1
    # 3. no worker's slab exceeds its ram-budget share: balanced plane
    # counts differ by at most one, so a fair per-worker budget is the
    # ceil-share of planes plus one patch-extent of halo rows
    plane_share = math.ceil(len(planes) / n_workers)
    row_budget = (plane_share - 1) * core + tiling.extent
    yz = shape[1] * shape[2]
    ram_share = row_budget * yz * 4
    for s in shards:
        lo, hi = shard_input_span(tiling, s)
        assert (hi - lo) * yz * 4 <= ram_share
        assert len(s) <= plane_share
