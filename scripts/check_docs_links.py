#!/usr/bin/env python3
"""Fail on broken relative links in README.md and docs/*.md (CI docs job).

Checks every markdown inline link ``[text](target)`` whose target is
relative (no URL scheme, not a bare in-page anchor): the referenced file
must exist relative to the markdown file's directory.  External URLs are
not fetched — this guards repo-internal references only, so doc-only PRs
get a deterministic, offline check.

Usage: python scripts/check_docs_links.py [repo_root]
Exit status 1 lists every broken link.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links only; reference-style links are not used in this repo.
_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SCHEME = re.compile(r"^[a-zA-Z][a-zA-Z0-9+.-]*:")


def doc_files(root: Path) -> list[Path]:
    files = [p for p in (root / "docs").glob("*.md")] if (root / "docs").is_dir() else []
    readme = root / "README.md"
    if readme.is_file():
        files.append(readme)
    return sorted(files)


def broken_links(root: Path) -> list[str]:
    problems = []
    for md in doc_files(root):
        text = md.read_text(encoding="utf-8")
        for match in _LINK.finditer(text):
            target = match.group(1)
            if _SCHEME.match(target) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                problems.append(f"{md.relative_to(root)}: broken link -> {target}")
    return problems


def main(argv: list[str]) -> int:
    root = Path(argv[1]).resolve() if len(argv) > 1 else Path(__file__).resolve().parents[1]
    files = doc_files(root)
    if not files:
        print("no markdown files found to check", file=sys.stderr)
        return 1
    problems = broken_links(root)
    for p in problems:
        print(p, file=sys.stderr)
    print(f"checked {len(files)} files: {len(problems)} broken links")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
