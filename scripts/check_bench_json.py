"""Gate the benchmark JSON breadcrumb's *shape* (not its wall clocks).

CI runs ``benchmarks/volume_throughput.py --quick --ram-budget ...`` and
then this check: every row must carry the ISSUE-5 memory counters, the
budget-sweep block must exist, and any row solved under a RAM budget must
report a measured peak within it.  Perf numbers stay advisory; a missing
counter is a regression in the instrumentation contract and fails.

Usage: python scripts/check_bench_json.py BENCH_volume_throughput.json
"""

import json
import sys

REQUIRED_ROW_KEYS = (
    "measured_voxps",
    "predicted_voxps",
    "peak_device_bytes",
    "predicted_peak_device_bytes",
    "predicted_memory",
    "ram_budget",
)


def check(path: str) -> int:
    with open(path) as fh:
        payload = json.load(fh)
    errors = []
    rows = payload.get("rows")
    if not rows:
        errors.append("no rows in payload")
    for name, row in (rows or {}).items():
        for key in REQUIRED_ROW_KEYS:
            if key not in row:
                errors.append(f"row {name!r}: missing {key!r}")
        peak = row.get("peak_device_bytes")
        if not isinstance(peak, (int, float)) or peak <= 0:
            errors.append(f"row {name!r}: peak_device_bytes not positive: {peak!r}")
        budget = row.get("ram_budget")
        if budget is not None and peak is not None and peak > budget:
            errors.append(
                f"row {name!r}: measured peak {peak:.0f} exceeds "
                f"ram_budget {budget:.0f}"
            )
    sweep = payload.get("budget_sweep")
    if not sweep:
        errors.append("missing budget_sweep block")
    else:
        for i, row in enumerate(sweep):
            for key in ("ram_budget", "feasible", "predicted_voxps"):
                if key not in row:
                    errors.append(f"budget_sweep[{i}]: missing {key!r}")
    if payload.get("ram_budget") is not None:
        budgeted = [
            name for name, row in (rows or {}).items()
            if row.get("ram_budget") is not None
        ]
        if not budgeted:
            errors.append("--ram-budget was set but no row carries it")
    for e in errors:
        print(f"BENCH JSON: {e}", file=sys.stderr)
    if errors:
        return 1
    print(f"BENCH JSON ok: {len(rows)} rows, {len(sweep)} budget-sweep rows")
    return 0


if __name__ == "__main__":
    sys.exit(check(sys.argv[1] if len(sys.argv) > 1 else "BENCH_volume_throughput.json"))
