"""Gate the benchmark JSON breadcrumb's *shape* (not its wall clocks).

CI runs ``benchmarks/volume_throughput.py --quick --ram-budget ...`` and
then this check: every row must carry the ISSUE-5 memory counters, the
budget-sweep block must exist, and any row solved under a RAM budget must
report a measured peak within it.  The ``hetero`` row (ISSUE 6) is
mandatory and must carry the two-backend split counters with its measured
hand-off bytes equal to the plan's prediction EXACTLY (per-patch hand-off
size is chunk-size independent, so any mismatch is a contract break, not
noise).

The throughput trend gate runs by default: the baseline is the highest-
numbered committed ``BENCH_NNN.json`` next to the checked file (the
previous PR's breadcrumb), and a row present in both files must not lose
more than ``--tolerance`` (default 50%) of the baseline's measured vox/s.
The wide tolerance absorbs shared-CI noise while still catching order-of-
magnitude breakage; per-counter exactness is enforced separately above.
``--baseline PATH`` pins an explicit baseline, ``--baseline none``
disables the gate (e.g. for the very first breadcrumb).

The ``fused_os`` row (ISSUE 9) is mandatory: it must report
``bitwise_equal_unfused: true`` (fused strip-path output identical to the
unfused walk) and its measured ``fused_pair_calls`` must equal the sweep
prediction exactly.

The ``anisotropic`` row (ISSUE 10) is mandatory: the planner's sweep-axis
argmax on a thin-slab volume must pick a non-x axis and its measured
throughput must STRICTLY beat the forced-x fallback, with the chosen
sweep's reuse counters equal to the planner's prediction exactly.

The long-horizon drift gate (ISSUE 10) complements the adjacent-baseline
trend gate: over the WHOLE committed ``BENCH_NNN.json`` series (plus the
checked file as the newest snapshot), a row whose measured vox/s decayed
strictly monotonically across its last >= 3 snapshots AND lost more than
``--drift-tolerance`` (default 20%) cumulatively over that tail fails the
check — the slow-leak regression pattern where each adjacent step stays
inside the 50% noise tolerance but the trajectory is clearly downhill.

Usage: python scripts/check_bench_json.py BENCH_volume_throughput.json \
           [--baseline BENCH_006.json | --baseline none] [--tolerance 0.5] \
           [--drift-tolerance 0.2]
"""

import argparse
import glob
import json
import os
import re
import sys

REQUIRED_ROW_KEYS = (
    "measured_voxps",
    "predicted_voxps",
    "peak_device_bytes",
    "predicted_peak_device_bytes",
    "predicted_memory",
    "ram_budget",
    # tuned-config provenance (ISSUE 7): which persisted per-hardware
    # config shaped the row's executor — null for untuned legacy rows,
    # but the KEY must exist so a row can never silently drop it
    "tuned_config",
)

SHARDED_ROW_KEYS = (
    "workers",
    "batch_buckets",
    "halo_bytes_in",
    "predicted_halo_bytes_in",
    "halo_exchange_bytes",
    "predicted_halo_exchange_bytes",
    "redispatches",
    "rebalances",
    "duplicates_dropped",
)

HETERO_ROW_KEYS = (
    "theta",
    "devices",
    "stage0_seconds",
    "stage1_seconds",
    "xfer_seconds",
    "xfer_bytes",
    "predicted_stage0_seconds",
    "predicted_stage1_seconds",
    "predicted_xfer_seconds",
    "predicted_xfer_bytes",
)


def discover_baseline(path: str) -> str:
    """The previous committed breadcrumb: the highest-numbered
    ``BENCH_NNN.json`` in the checked file's directory, excluding the
    checked file itself.  Returns None when there is none (first PR)."""
    root = os.path.dirname(os.path.abspath(path)) or "."
    best, best_n = None, -1
    for cand in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(cand))
        if m is None:
            continue
        if os.path.exists(path) and os.path.samefile(cand, path):
            continue
        n = int(m.group(1))
        if n > best_n:
            best, best_n = cand, n
    return best


def history_series(path: str):
    """All committed ``BENCH_NNN.json`` next to ``path`` (excluding the
    checked file itself), as ``[(n, rows_dict), ...]`` sorted by n."""
    root = os.path.dirname(os.path.abspath(path)) or "."
    out = []
    for cand in glob.glob(os.path.join(root, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(cand))
        if m is None:
            continue
        if os.path.exists(path) and os.path.samefile(cand, path):
            continue
        try:
            with open(cand) as fh:
                rows = json.load(fh).get("rows") or {}
        except (OSError, ValueError):
            continue
        out.append((int(m.group(1)), rows))
    return sorted(out)


def drift_errors(path: str, rows: dict, drift_tolerance: float):
    """The slow-leak gate: strictly monotone decay across >= 3 trailing
    snapshots of a row's measured vox/s, with a cumulative decline beyond
    ``drift_tolerance``, over the whole committed series + this run."""
    snapshots = [r for _, r in history_series(path)] + [rows or {}]
    errors = []
    for name in sorted({k for snap in snapshots for k in snap}):
        series = [
            snap[name]["measured_voxps"]
            for snap in snapshots
            if name in snap and snap[name].get("measured_voxps")
        ]
        # longest strictly-decreasing tail
        tail = 1
        while tail < len(series) and series[-tail - 1] > series[-tail]:
            tail += 1
        if tail < 3:
            continue
        first, last = series[-tail], series[-1]
        decline = (first - last) / first
        if decline > drift_tolerance:
            errors.append(
                f"row {name!r}: measured_voxps decayed monotonically over "
                f"its last {tail} snapshots ({first:,.0f} -> {last:,.0f}, "
                f"-{decline:.0%} > drift tolerance {drift_tolerance:.0%})"
            )
    return errors


def check(path: str, baseline: str = None, tolerance: float = 0.5,
          drift_tolerance: float = 0.2) -> int:
    with open(path) as fh:
        payload = json.load(fh)
    errors = []
    rows = payload.get("rows")
    if not rows:
        errors.append("no rows in payload")
    for name, row in (rows or {}).items():
        for key in REQUIRED_ROW_KEYS:
            if key not in row:
                errors.append(f"row {name!r}: missing {key!r}")
        peak = row.get("peak_device_bytes")
        if not isinstance(peak, (int, float)) or peak <= 0:
            errors.append(f"row {name!r}: peak_device_bytes not positive: {peak!r}")
        budget = row.get("ram_budget")
        if budget is not None and peak is not None and peak > budget:
            errors.append(
                f"row {name!r}: measured peak {peak:.0f} exceeds "
                f"ram_budget {budget:.0f}"
            )
    # the heterogeneous two-backend row is part of the contract (ISSUE 6)
    hetero = (rows or {}).get("hetero")
    if hetero is None:
        errors.append("missing mandatory 'hetero' row")
    else:
        for key in HETERO_ROW_KEYS:
            if key not in hetero:
                errors.append(f"row 'hetero': missing {key!r}")
        got, want = hetero.get("xfer_bytes"), hetero.get("predicted_xfer_bytes")
        if got is not None and want is not None and got != want:
            errors.append(
                f"row 'hetero': measured xfer_bytes {got!r} != "
                f"predicted {want!r} (must match exactly)"
            )
        devs = hetero.get("devices")
        if devs is not None and len(devs) != 2:
            errors.append(f"row 'hetero': expected 2 devices, got {devs!r}")
    # the sharded serving-fleet row (ISSUE 8) is part of the contract:
    # its measured per-worker halo-exchange bytes must equal the tiler's
    # predicted handoff schedule EXACTLY (the boundary package size is
    # chunk-size independent — a mismatch is a contract break, not noise),
    # and a fault-free bench run must report zero re-dispatches
    sharded = (rows or {}).get("sharded")
    if sharded is None:
        errors.append("missing mandatory 'sharded' row")
    else:
        for key in SHARDED_ROW_KEYS:
            if key not in sharded:
                errors.append(f"row 'sharded': missing {key!r}")
        got = sharded.get("halo_bytes_in")
        want = sharded.get("predicted_halo_bytes_in")
        if got is not None and want is not None and got != want:
            errors.append(
                f"row 'sharded': measured halo_bytes_in {got!r} != "
                f"predicted {want!r} (must match exactly)"
            )
        nw = sharded.get("workers")
        if got is not None and nw is not None and len(got) != nw:
            errors.append(
                f"row 'sharded': {nw} workers but {len(got)} halo counters"
            )
        for key in ("redispatches", "rebalances", "duplicates_dropped"):
            if sharded.get(key):
                errors.append(
                    f"row 'sharded': fault-free bench run reported "
                    f"{key}={sharded[key]!r}"
                )
    # the tuned row (ISSUE 7) must really be tuned: non-null provenance
    # carrying the (device kind, net) key the config was persisted under
    fused = (rows or {}).get("fused_tuned")
    if fused is not None:
        tc = fused.get("tuned_config")
        if not isinstance(tc, dict):
            errors.append(
                "row 'fused_tuned': tuned_config is null — no persisted "
                "config was loaded (run python -m repro.tuning.autotune)"
            )
        else:
            for key in ("device_kind", "net"):
                if not tc.get(key):
                    errors.append(f"row 'fused_tuned': tuned_config missing {key!r}")
    # the fused strip-path row (ISSUE 9) is part of the contract: fused
    # output bitwise-identical to the unfused walk, fused-pair counter
    # equal to the sweep prediction exactly
    fos = (rows or {}).get("fused_os")
    if fos is None:
        errors.append("missing mandatory 'fused_os' row")
    else:
        for key in ("bitwise_equal_unfused", "fused_pair_calls",
                    "predicted_fused_pair_calls", "os_fused_segments"):
            if key not in fos:
                errors.append(f"row 'fused_os': missing {key!r}")
        if fos.get("bitwise_equal_unfused") is not True:
            errors.append(
                "row 'fused_os': bitwise_equal_unfused is not true — fused "
                "strip-path output diverged from the unfused walk"
            )
        got = fos.get("fused_pair_calls")
        want = fos.get("predicted_fused_pair_calls")
        if got is not None and want is not None and got != want:
            errors.append(
                f"row 'fused_os': fused_pair_calls {got!r} != predicted "
                f"{want!r} (must match exactly)"
            )
        if not fos.get("fused_pair_calls"):
            errors.append(
                "row 'fused_os': fused_pair_calls is 0 — the fused "
                "epilogue never dispatched"
            )
    # the anisotropic axis-argmax row (ISSUE 10) is part of the contract:
    # on a thin slab the planner-chosen sweep axis must strictly beat the
    # forced-x fallback, and the chosen sweep's measured reuse counters
    # must equal the planner's prediction exactly
    aniso = (rows or {}).get("anisotropic")
    if aniso is None:
        errors.append("missing mandatory 'anisotropic' row")
    else:
        for key in ("sweep_axis", "forced_x_voxps", "allclose_forced_x",
                    "planner_sweep", "os_seg_fft", "deep_strip_patches"):
            if key not in aniso:
                errors.append(f"row 'anisotropic': missing {key!r}")
        if aniso.get("sweep_axis") == 0:
            errors.append(
                "row 'anisotropic': planner picked sweep_axis 0 on the "
                "thin slab — the axis argmax is not engaging"
            )
        got = aniso.get("measured_voxps")
        fx = aniso.get("forced_x_voxps")
        if got is not None and fx is not None and not got > fx:
            errors.append(
                f"row 'anisotropic': chosen-axis {got:,.0f} vox/s does not "
                f"strictly beat forced-x {fx:,.0f} vox/s"
            )
        if aniso.get("allclose_forced_x") is not True:
            errors.append(
                "row 'anisotropic': chosen-axis output diverged from the "
                "forced-x sweep (allclose_forced_x is not true)"
            )
        ps = aniso.get("planner_sweep") or {}
        for pkey, mkey in (("seg_fft", "os_seg_fft"),
                           ("mad_segments", "os_mad_segments"),
                           ("strip_patches", "deep_strip_patches"),
                           ("full_patches", "deep_full_patches")):
            want, meas = ps.get(pkey), aniso.get(mkey)
            if want is not None and meas is not None and want != meas:
                errors.append(
                    f"row 'anisotropic': measured {mkey} {meas!r} != "
                    f"predicted {want!r} (must match exactly)"
                )
        if not aniso.get("deep_strip_patches"):
            errors.append(
                "row 'anisotropic': deep_strip_patches is 0 — the chosen "
                "axis ran no strip path, so there was nothing to win"
            )
    errors.extend(drift_errors(path, rows, drift_tolerance))
    sweep = payload.get("budget_sweep")
    if not sweep:
        errors.append("missing budget_sweep block")
    else:
        for i, row in enumerate(sweep):
            for key in ("ram_budget", "feasible", "predicted_voxps"):
                if key not in row:
                    errors.append(f"budget_sweep[{i}]: missing {key!r}")
    if payload.get("ram_budget") is not None:
        budgeted = [
            name for name, row in (rows or {}).items()
            if row.get("ram_budget") is not None
        ]
        if not budgeted:
            errors.append("--ram-budget was set but no row carries it")
    if baseline is not None:
        with open(baseline) as fh:
            base = json.load(fh)
        base_rows = base.get("rows") or {}
        common = sorted(set(base_rows) & set(rows or {}))
        if not common:
            errors.append(f"baseline {baseline!r}: no rows in common")
        for name in common:
            b = base_rows[name].get("measured_voxps")
            c = (rows or {})[name].get("measured_voxps")
            if not b or not c:
                continue
            if c < b * (1.0 - tolerance):
                errors.append(
                    f"row {name!r}: measured_voxps {c:,.0f} regressed more "
                    f"than {tolerance:.0%} vs baseline {b:,.0f}"
                )
    for e in errors:
        print(f"BENCH JSON: {e}", file=sys.stderr)
    if errors:
        return 1
    msg = f"BENCH JSON ok: {len(rows)} rows, {len(sweep)} budget-sweep rows"
    if baseline is not None:
        msg += f", regression-gated vs {baseline}"
    print(msg)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("path", nargs="?", default="BENCH_volume_throughput.json")
    ap.add_argument("--baseline", default="auto",
                    help="committed BENCH_NNN.json to gate throughput "
                         "against; 'auto' (default) picks the highest-"
                         "numbered one next to PATH, 'none' disables")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="max fractional measured_voxps drop vs baseline")
    ap.add_argument("--drift-tolerance", type=float, default=0.2,
                    help="max cumulative measured_voxps decline over a "
                         "strictly-monotone >=3-snapshot tail of the "
                         "committed BENCH_NNN.json series")
    args = ap.parse_args()
    baseline = args.baseline
    if baseline == "auto":
        baseline = discover_baseline(args.path)
        if baseline is None:
            print("BENCH JSON: no committed BENCH_NNN.json found — "
                  "trend gate skipped")
    elif baseline == "none":
        baseline = None
    sys.exit(check(args.path, baseline=baseline, tolerance=args.tolerance,
                   drift_tolerance=args.drift_tolerance))
