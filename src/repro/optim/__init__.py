"""Optimizers and schedules."""

from .adamw import AdamWConfig, apply_updates, init_state  # noqa: F401
from .schedule import cosine_warmup  # noqa: F401
