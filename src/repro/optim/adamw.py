"""AdamW with selectable state dtype (f32 / bf16 / int8-quantized moments).

The moment-dtype knob is the optimizer-memory half of the framework's
distributed-optimization toolkit (DESIGN.md §6): grok-1 training on 256
chips fits only with bf16 or int8 moments (EXPERIMENTS.md §Dry-run).
int8 moments use per-tensor-block absmax scaling (block = last dim), the
standard 8-bit-Adam construction.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | bfloat16 | int8


class QTensor(NamedTuple):
    q: jnp.ndarray  # int8 payload
    scale: jnp.ndarray  # f32 absmax per last-dim block


def _quantize(x: jnp.ndarray) -> QTensor:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale.astype(jnp.float32))


def _dequantize(t: QTensor) -> jnp.ndarray:
    return t.q.astype(jnp.float32) * t.scale


def _to_state_dtype(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quantize(x)
    return x.astype(jnp.dtype(dtype))


def _from_state_dtype(x, dtype: str) -> jnp.ndarray:
    if dtype == "int8":
        return _dequantize(x)
    return x.astype(jnp.float32)


def init_state(params: Any, cfg: AdamWConfig) -> dict:
    def zeros_like_state(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _to_state_dtype(z, cfg.state_dtype)

    return {
        "m": jax.tree.map(zeros_like_state, params),
        "v": jax.tree.map(zeros_like_state, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def apply_updates(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> Tuple[Any, dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_q = cfg.state_dtype == "int8"

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _from_state_dtype(m, cfg.state_dtype)
        vf = _from_state_dtype(v, cfg.state_dtype)
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        mhat = mf / b1c
        vhat = vf / b2c
        u = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - cfg.lr * u).astype(p.dtype)
        return newp, _to_state_dtype(mf, cfg.state_dtype), _to_state_dtype(vf, cfg.state_dtype)

    treedef = jax.tree.structure(params)
    flat_p = treedef.flatten_up_to(params)
    flat_g = treedef.flatten_up_to(grads)
    if is_q:
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
    else:
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
