"""Process-level flags (read once at import, set via environment).

REPRO_UNROLL_INNER=1 — unroll inner chunk loops (attention q-chunks, CE
chunks, SSD chunk scan).  Used by the dry-run's roofline PROBES: XLA's
HLO cost analysis counts a while-loop body once regardless of trip count,
so the probes unroll every inner loop and extrapolate the outer layer scan
from two probe depths (see launch/dryrun.py::probe_cell).  Never set for
normal training/serving — unrolling bloats compile time.
"""

from __future__ import annotations

import os

from jax import lax

UNROLL_INNER = os.environ.get("REPRO_UNROLL_INNER", "0") == "1"


def chunk_map(f, xs):
    """lax.map, or a fully-unrolled equivalent under REPRO_UNROLL_INNER."""
    if UNROLL_INNER:
        _, ys = lax.scan(lambda c, x: (c, f(x)), None, xs, unroll=True)
        return ys
    return lax.map(f, xs)


def chunk_scan(f, init, xs):
    """lax.scan with carry, unrolled under REPRO_UNROLL_INNER."""
    return lax.scan(f, init, xs, unroll=True if UNROLL_INNER else 1)
