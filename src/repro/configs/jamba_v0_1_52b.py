"""Jamba-v0.1 52B [arXiv:2403.19887; hf].

32L, d_model=4096, attention 32 heads (GQA kv=8), d_ff=14336, vocab=65536.
Mamba:attention 7:1 interleave (attention at index 4 of each 8-layer block),
MoE 16 experts top-2 on every other layer.
"""

from .base import AttnConfig, MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab=65536,
    # 8-layer Jamba block: attn at idx 4, MoE on odd indices (every 2nd layer)
    block_pattern=(
        "mamba", "mamba_moe", "mamba", "mamba_moe",
        "attn", "mamba_moe", "mamba", "mamba_moe",
    ),
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, rope_kind="none"),
    moe=MoEConfig(n_experts=16, top_k=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=64, chunk=256),
    sub_quadratic=True,  # 1:7 attn:mamba -> long_500k runs
    notes="hybrid Mamba+attn 1:7; MoE 16e top-2 every other layer",
)
