"""Config dataclasses for the repro framework.

Two config families:
  * ``ModelConfig``   — LM-family transformer/SSM/hybrid architectures (the 10
    assigned archs).  A single dataclass covers dense / MoE / SSM / hybrid /
    enc-dec via a ``block_pattern`` of layer tokens.
  * ``ConvNetConfig`` — the paper's 3D sliding-window ConvNets (Table III).

Everything is a frozen dataclass so configs are hashable and safe to close
over in jitted functions.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer-token grammar for ``block_pattern``
#
#   attn        full (causal) GQA attention block
#   local       sliding-window GQA attention block (window = swa_window)
#   global      full attention block (used inside local/global interleaves)
#   mamba       Mamba2 SSD block
#   <tok>_moe   same mixer, MLP replaced by an MoE
# ---------------------------------------------------------------------------

VALID_MIXERS = ("attn", "local", "global", "mamba")


def parse_block_token(tok: str) -> Tuple[str, bool]:
    """Return (mixer_kind, is_moe) for a block-pattern token."""
    is_moe = tok.endswith("_moe")
    mixer = tok[: -len("_moe")] if is_moe else tok
    if mixer not in VALID_MIXERS:
        raise ValueError(f"unknown block token {tok!r}")
    return mixer, is_moe


@dataclass(frozen=True)
class AttnConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_kind: str = "rope"  # 'rope' | 'mrope' | 'none'
    swa_window: Optional[int] = None  # used by 'local' blocks (and SWA archs)
    # mrope sections (temporal, height, width) fractions of head_dim/2
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # group-preserving q-head padding (beyond-paper sharding lever): pad the
    # per-kv-head query group from q_per_kv to `pad_q_groups` with ZERO
    # heads so n_heads_eff = n_kv_heads * pad_q_groups becomes divisible by
    # the model axis.  Padded heads contribute nothing (zero wq AND zero wo
    # rows; gradients stay zero) — outputs are bit-identical, but attention
    # activations/weights become shardable.  See EXPERIMENTS.md §Perf H1.
    pad_q_groups: Optional[int] = None
    # expand kv heads to full H inside attention (GSPMD-friendly when the
    # model axis divides H but not (Hkv, G) separately) — §Perf H1 lever.
    expand_kv: bool = False

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def n_heads_eff(self) -> int:
        if self.pad_q_groups is None:
            return self.n_heads
        assert self.pad_q_groups >= self.q_per_kv
        return self.n_kv_heads * self.pad_q_groups


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    # router aux-loss weight for training
    aux_loss_weight: float = 0.01
    # GShard-style expert capacity = cf * T * K / E; tokens beyond capacity
    # are dropped.  Set cf >= n_experts for drop-free routing (tests).
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 256  # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_ssm_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    block_pattern: Tuple[str, ...]
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # encoder-decoder (whisper): decoder uses block_pattern; encoder is
    # n_enc_layers of full attention over enc_seq precomputed frames.
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0
    frontend: str = "none"  # none | patch | audio  (stub frontends per spec)
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # decode-time activation replication (serve lever, §Perf H3): with
    # 2-axis-sharded weights, replicated activations make GSPMD psum tiny
    # activation partials instead of circulating huge weight shards.
    decode_replicate_activations: bool = False
    # MoE dispatch routing groups (shard-local routing when == dp degree;
    # see layers/moe.py and EXPERIMENTS.md §Perf H2)
    moe_routing_groups: int = 1
    # sub-quadratic in sequence length => long_500k cell runs
    sub_quadratic: bool = False
    notes: str = ""

    # -- derived ------------------------------------------------------------
    def mixer_counts(self) -> dict:
        """How many layers of each mixer kind / how many MoE layers."""
        counts = {"attn": 0, "local": 0, "global": 0, "mamba": 0, "moe": 0}
        for i in range(self.n_layers):
            mixer, is_moe = parse_block_token(
                self.block_pattern[i % len(self.block_pattern)]
            )
            counts[mixer] += 1
            counts["moe"] += int(is_moe)
        return counts

    def param_count(self) -> int:
        """Total parameter count (embedding included)."""
        d = self.d_model
        c = self.mixer_counts()
        n = 0
        # embeddings (+ untied head)
        n += self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.attn is not None:
            a = self.attn
            qkv = d * a.n_heads * a.head_dim + 2 * d * a.n_kv_heads * a.head_dim
            if a.qkv_bias:
                qkv += (a.n_heads + 2 * a.n_kv_heads) * a.head_dim
            out = a.n_heads * a.head_dim * d
            n += (c["attn"] + c["local"] + c["global"]) * (qkv + out)
        if c["mamba"] and self.ssm is not None:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_ssm_heads(d)
            per = (
                d * (2 * di + 2 * s.d_state + nh)  # in_proj (x,z,B,C,dt)
                + s.d_conv * (di + 2 * s.d_state)  # conv1d
                + nh  # A_log
                + nh  # D
                + di * d  # out_proj
            )
            n += c["mamba"] * per
        # MLPs: swiglu = 3 mats, gelu = 2
        mats = 3 if self.act == "swiglu" else 2
        dense_mlp_layers = self.n_layers - c["moe"]
        n += dense_mlp_layers * mats * d * self.d_ff
        if self.moe is not None and c["moe"]:
            per = self.moe.n_experts * mats * d * self.d_ff + d * self.moe.n_experts
            n += c["moe"] * per
        # norms (2 per layer) + final norm
        n += (2 * self.n_layers + 1) * d
        if self.enc_dec:
            # encoder layers: attn + mlp, plus decoder cross-attn already
            # counted? no — cross attention adds qkv+out per decoder layer.
            a = self.attn
            enc_per = (
                d * a.n_heads * a.head_dim
                + 2 * d * a.n_kv_heads * a.head_dim
                + a.n_heads * a.head_dim * d
                + mats * d * self.d_ff
                + 2 * d
            )
            n += self.n_enc_layers * enc_per
            cross_per = (
                d * a.n_heads * a.head_dim
                + 2 * d * a.n_kv_heads * a.head_dim
                + a.n_heads * a.head_dim * d
                + d
            )
            n += self.n_layers * cross_per
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        c = self.mixer_counts()
        mats = 3 if self.act == "swiglu" else 2
        full = self.param_count()
        inactive_experts = self.moe.n_experts - self.moe.top_k
        inactive = c["moe"] * inactive_experts * mats * self.d_model * self.d_ff
        return full - inactive

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat_len = len(self.block_pattern)
        n_layers = max(pat_len, 2) if pat_len > 1 else 2
        attn = None
        if self.attn is not None:
            a = self.attn
            attn = dataclasses.replace(
                a,
                n_heads=4,
                n_kv_heads=max(1, min(4, 4 * a.n_kv_heads // max(a.n_heads, 1))),
                head_dim=16,
                swa_window=16 if a.swa_window else None,
                mrope_sections=(2, 3, 3),  # sums to head_dim // 2
            )
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, n_experts=4, top_k=min(2, self.moe.top_k))
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=8, headdim=8, chunk=8)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=64,
            d_ff=128,
            vocab=256,
            attn=attn,
            moe=moe,
            ssm=ssm,
            n_enc_layers=2 if self.enc_dec else 0,
            enc_seq=16 if self.enc_dec else 0,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned; LM shapes are seq_len x global_batch)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4_096, 256),
    ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    ShapeConfig("decode_32k", "decode", 32_768, 128),
    ShapeConfig("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether (arch x shape) runs, per DESIGN.md §Arch-applicability."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full attention (see DESIGN.md)"
    return True, ""


# ---------------------------------------------------------------------------
# ZNNi 3D ConvNets (paper Table III)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLayerSpec:
    kind: str  # 'conv' | 'pool'
    size: int  # kernel size k (conv) or pooling window p (pool)
    out_channels: int = 0  # conv only


@dataclass(frozen=True)
class ConvNetConfig:
    name: str
    in_channels: int
    layers: Tuple[ConvLayerSpec, ...]

    def field_of_view(self) -> int:
        """FOV of the sliding window (1D extent; isotropic)."""
        fov, stride = 1, 1
        for l in self.layers:
            if l.kind == "conv":
                fov += (l.size - 1) * stride
            else:
                fov += (l.size - 1) * stride
                stride *= l.size
        return fov

    def total_pooling(self) -> int:
        p = 1
        for l in self.layers:
            if l.kind == "pool":
                p *= l.size
        return p

    def valid_input_size(self, n_out: int) -> int:
        """Smallest input size that yields >= n_out output voxels per axis.

        Walks the net backwards: conv adds k-1; MPF pooling needs n ≡ p-1 (mod p)
        i.e. n = p*m + (p-1) to produce fragments of size m.
        """
        n = n_out
        for l in reversed(self.layers):
            if l.kind == "conv":
                n = n + l.size - 1
            else:
                n = l.size * n + l.size - 1
        return n

    def output_size(self, n_in: int) -> int:
        """Output voxels per axis for input size n_in (MPF fragments)."""
        n = n_in
        for l in self.layers:
            if l.kind == "conv":
                n = n - l.size + 1
            else:
                if (n + 1) % l.size != 0:
                    return -1  # invalid input size
                n = n // l.size
        return n
