"""Phi-3-medium 14B [arXiv:2404.14219; unverified].

40L, d_model=5120, 40 heads (GQA kv=10), d_ff=17920, vocab=100352.
RoPE + SwiGLU + GQA.
"""

from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    d_ff=17920,
    vocab=100352,
    block_pattern=("attn",),
    attn=AttnConfig(
        n_heads=40,
        n_kv_heads=10,
        head_dim=128,
        rope_theta=10_000.0,
    ),
    sub_quadratic=False,
    notes="dense GQA; kv=10 not divisible by model axis -> seq-sharded KV",
)
