"""Gemma-3-27B [hf:google/gemma-3-1b-pt family; unverified].

62L, d_model=5376, 32 heads (GQA kv=16), d_ff=21504, vocab=262144.
5:1 local:global attention interleave, 128k context, head_dim=128
(explicit, as in the real model: 32*128 != d_model).
62 = 10 full (local^5, global) repeats + 2 trailing local layers.
"""

from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    d_ff=21504,
    vocab=262144,
    block_pattern=("local", "local", "local", "local", "local", "global"),
    attn=AttnConfig(
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        rope_theta=1_000_000.0,
        swa_window=1024,
    ),
    sub_quadratic=True,  # SWA-dominant (5:1) -> long_500k runs
    notes="5:1 local:global; global layers O(S) per decoded token",
)
