"""The paper's four benchmark ConvNets (ZNNi Table III).

All nets have 80 feature maps per hidden layer and 3 output maps; input is a
single-channel 3D volume (EM connectomics setting).  n926's row-6 "Pool 9^3"
entry in Table III is a typo — the text (§VI-B) says n726/n926 are CPCPCCCC
with 6 conv + 2 pool layers — we follow the text.
"""

from .base import ConvLayerSpec as L
from .base import ConvNetConfig

F = 80  # feature maps (Table III)
OUT = 3  # output maps


def _conv(k: int, f: int = F) -> L:
    return L("conv", k, f)


def _pool(p: int = 2) -> L:
    return L("pool", p)


N337 = ConvNetConfig(
    name="n337",
    in_channels=1,
    layers=(
        _conv(2), _pool(), _conv(3), _pool(), _conv(3), _pool(),
        _conv(3), _conv(3), _conv(3), _conv(3, OUT),
    ),
)

N537 = ConvNetConfig(
    name="n537",
    in_channels=1,
    layers=(
        _conv(4), _pool(), _conv(5), _pool(), _conv(5), _pool(),
        _conv(5), _conv(5), _conv(5), _conv(5, OUT),
    ),
)

N726 = ConvNetConfig(
    name="n726",
    in_channels=1,
    layers=(
        _conv(6), _pool(), _conv(7), _pool(), _conv(7),
        _conv(7), _conv(7), _conv(7, OUT),
    ),
)

N926 = ConvNetConfig(
    name="n926",
    in_channels=1,
    layers=(
        _conv(8), _pool(), _conv(9), _pool(), _conv(9),
        _conv(9), _conv(9), _conv(9, OUT),
    ),
)

ZNNI_NETS = {c.name: c for c in (N337, N537, N726, N926)}

# The CI-sized benchmark net (not a paper net): 8 input channels so layer-0
# input transforms carry real work, small enough that the full strategy
# matrix sweeps in seconds.  Shared by benchmarks/volume_throughput.py and
# repro.tuning.autotune so the tuned-config key "bench-net" means one net.
BENCH_NET = ConvNetConfig(
    name="bench-net",
    in_channels=8,
    layers=(_conv(3, 8), _pool(), _conv(3, 8), _pool(), _conv(3, OUT)),
)


def net_by_name(name: str) -> ConvNetConfig:
    """Resolve a net name: the four Table III nets plus ``bench-net``."""
    if name in (BENCH_NET.name, "bench"):
        return BENCH_NET
    try:
        return ZNNI_NETS[name]
    except KeyError:
        raise ValueError(
            f"unknown net {name!r}; known: {sorted(ZNNI_NETS) + [BENCH_NET.name]}"
        ) from None
