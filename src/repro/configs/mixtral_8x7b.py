"""Mixtral-8x7B [arXiv:2401.04088; hf].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=32000,
MoE 8 experts top-2 on every layer, sliding-window attention (4096).
"""

from .base import AttnConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab=32000,
    block_pattern=("local_moe",),  # SWA + MoE every layer
    attn=AttnConfig(
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        rope_theta=1_000_000.0,
        swa_window=4096,
    ),
    moe=MoEConfig(n_experts=8, top_k=2),
    sub_quadratic=True,  # SWA bounds per-token KV -> long_500k runs
    notes="8 experts top-2; SWA window 4096",
)
