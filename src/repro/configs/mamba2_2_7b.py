"""Mamba2-2.7B [arXiv:2405.21060; unverified].

64L, d_model=2560, attention-free SSD (state-space duality) blocks,
ssm_state=128, headdim=64 => 80 SSM heads, expand=2 (d_inner=5120).
No MLP (d_ff=0): the Mamba2 block is the whole layer.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab=50280,
    block_pattern=("mamba",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, chunk=256),
    norm="rmsnorm",
    tie_embeddings=True,
    sub_quadratic=True,  # O(1)-in-S decode state -> long_500k runs
    notes="attention-free SSD; decode carries (nheads, headdim, d_state) state",
)
