"""Qwen2.5-14B [hf:Qwen/Qwen2.5-0.5B family; hf].

48L, d_model=5120, 40 heads (GQA kv=8), d_ff=13824, vocab=152064, QKV bias.
"""

from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    d_ff=13824,
    vocab=152064,
    block_pattern=("attn",),
    attn=AttnConfig(
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    sub_quadratic=False,
    notes="GQA with QKV bias",
)
