"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family; hf].

40L, d_model=2560, 20 heads (MHA: kv=20), d_ff=6912, vocab=151936, QKV bias.
20 heads is not divisible by the 16-way model axis; the sharding rules fall
back to contraction-sharded attention projections (DESIGN.md §6).
"""

from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    d_ff=6912,
    vocab=151936,
    block_pattern=("attn",),
    attn=AttnConfig(
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    ),
    sub_quadratic=False,
    notes="MHA (kv=heads=20); QKV bias",
)
