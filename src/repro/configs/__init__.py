"""Config registry: ``get_config(arch_id)`` / ``ARCHS`` / shapes / ZNNi nets."""

from .base import (
    AttnConfig,
    ConvLayerSpec,
    ConvNetConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    SHAPES_BY_NAME,
    ShapeConfig,
    SSMConfig,
    cell_applicable,
    parse_block_token,
)
from .znni_nets import N337, N537, N726, N926, ZNNI_NETS

from . import (
    gemma3_27b,
    grok1_314b,
    jamba_v0_1_52b,
    mamba2_2_7b,
    mixtral_8x7b,
    phi3_medium_14b,
    qwen1_5_4b,
    qwen2_5_14b,
    qwen2_vl_7b,
    whisper_tiny,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_vl_7b,
        mixtral_8x7b,
        grok1_314b,
        phi3_medium_14b,
        qwen2_5_14b,
        qwen1_5_4b,
        gemma3_27b,
        mamba2_2_7b,
        jamba_v0_1_52b,
        whisper_tiny,
    )
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(shape_id: str) -> ShapeConfig:
    if shape_id not in SHAPES_BY_NAME:
        raise KeyError(f"unknown shape {shape_id!r}; known: {sorted(SHAPES_BY_NAME)}")
    return SHAPES_BY_NAME[shape_id]


__all__ = [
    "ARCHS",
    "AttnConfig",
    "ConvLayerSpec",
    "ConvNetConfig",
    "MoEConfig",
    "ModelConfig",
    "N337",
    "N537",
    "N726",
    "N926",
    "SHAPES",
    "SHAPES_BY_NAME",
    "SSMConfig",
    "ShapeConfig",
    "ZNNI_NETS",
    "cell_applicable",
    "get_config",
    "get_shape",
    "parse_block_token",
]
