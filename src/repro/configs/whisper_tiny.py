"""Whisper-tiny [arXiv:2212.04356; unverified].

Enc-dec, 4L each side, d_model=384, 6 heads (MHA), d_ff=1536, vocab=51865.
Conv audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed mel-frame embeddings (1500 frames) for the encoder.
"""

from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    d_ff=1536,
    vocab=51865,
    block_pattern=("attn",),
    attn=AttnConfig(
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        rope_kind="none",  # whisper uses learned/sinusoidal positions
    ),
    enc_dec=True,
    n_enc_layers=4,
    enc_seq=1500,
    frontend="audio",
    norm="layernorm",
    act="gelu",
    sub_quadratic=False,
    notes="enc-dec; conv frontend stubbed (precomputed frame embeddings)",
)
