"""Grok-1 314B [hf:xai-org/grok-1; unverified].

64L, d_model=6144, 48 heads (GQA kv=8), d_ff=32768, vocab=131072,
MoE 8 experts top-2 on every layer.
"""

from .base import AttnConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab=131072,
    block_pattern=("attn_moe",),
    attn=AttnConfig(
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        rope_theta=10_000.0,
    ),
    moe=MoEConfig(n_experts=8, top_k=2),
    sub_quadratic=False,  # full attention -> long_500k skipped
    notes="8 experts top-2; largest assigned arch (ZeRO-sharded training)",
)
