"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf].

28L, d_model=3584, 28 heads (GQA kv=4), d_ff=18944, vocab=152064.
M-RoPE (temporal/height/width rotary sections); dynamic-resolution vision
frontend is a STUB per the assignment — ``input_specs()`` provides
precomputed patch embeddings alongside text tokens.
"""

from .base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    d_ff=18944,
    vocab=152064,
    block_pattern=("attn",),
    attn=AttnConfig(
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        rope_kind="mrope",
        mrope_sections=(16, 24, 24),
    ),
    frontend="patch",
    sub_quadratic=False,  # pure full attention -> long_500k skipped
    notes="M-RoPE; vision patch frontend stubbed (precomputed embeddings)",
)
