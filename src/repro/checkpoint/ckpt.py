"""Sharded, atomic, async checkpointing (msgpack + raw shard payloads).

Layout:  <dir>/step_<N>/          (atomic: written as .tmp then renamed)
             manifest.msgpack     tree structure, shapes, dtypes
             arrays.npz           leaf payloads (host-gathered)

For multi-host fleets each host would write only its addressable shards;
in this single-process container the full array is written.  Restore takes
target NamedShardings so the same checkpoint restores onto ANY mesh
(elastic remesh — distributed/fault_tolerance.py).
"""

from __future__ import annotations

import os
import shutil
import threading
import uuid
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, *, async_: bool = False) -> threading.Thread | None:
    """Write checkpoint for `step`.  async_=True returns the writer thread."""
    leaves, treedef = _flatten(tree)
    host = [np.asarray(l) for l in leaves]

    def write():
        final = os.path.join(path, f"step_{step:08d}")
        # unique tmp dir: concurrent writers of the same step (async + final
        # sync save) must not collide; first rename wins, the rest discard.
        tmp = final + f".tmp-{uuid.uuid4().hex[:8]}"
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host),
            "dtypes": [str(h.dtype) for h in host],
            "shapes": [list(h.shape) for h in host],
        }
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        # store raw bytes: numpy's npz cannot round-trip ml_dtypes (bf16
        # degrades to void); the manifest carries dtype/shape for restore.
        payload = [
            np.ascontiguousarray(h).reshape(-1).view(np.uint8) for h in host
        ]
        np.savez(os.path.join(tmp, "arrays.npz"), *payload)
        try:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except OSError:
            if os.path.isdir(final):  # another writer won the race
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                raise

    if async_:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_") and ".tmp" not in d
    ]
    return max(steps) if steps else None


def restore(path: str, step: int, tree_like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of `tree_like`; device_put onto
    `shardings` when given (any mesh — elastic)."""
    final = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with np.load(os.path.join(final, "arrays.npz")) as z:
        raw = [z[k] for k in z.files]
    host = [
        r.view(np.dtype(jnp.dtype(dt))).reshape(shape)
        for r, dt, shape in zip(raw, manifest["dtypes"], manifest["shapes"])
    ]
    leaves, treedef = _flatten(tree_like)
    if len(host) != len(leaves):
        raise ValueError(f"checkpoint has {len(host)} leaves, expected {len(leaves)}")
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "mesh")
        )
        host = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        host = [jnp.asarray(h) for h in host]
    return jax.tree_util.tree_unflatten(treedef, host)


def prune_old(path: str, keep: int = 3) -> None:
    if not os.path.isdir(path):
        return
    steps = sorted(
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_") and ".tmp" not in d
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"))
