"""Sharded atomic checkpointing."""

from .ckpt import latest_step, prune_old, restore, save  # noqa: F401
