"""Synthetic sharded data pipelines with prefetch."""

from .pipeline import (  # noqa: F401
    Prefetcher,
    SyntheticTokenPipeline,
    SyntheticVolumePipeline,
    TokenPipelineConfig,
    VolumePipelineConfig,
)
