"""Data pipelines: synthetic token LM stream + 3D volume stream, with
host-sharded loading, deterministic resume, and background prefetch.

The token pipeline is seeded per (host, step) so any worker can recompute
any step's shard — that determinism is what makes the elastic rebalance in
fault_tolerance.py safe (a resharded worker regenerates exactly its slice).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional

import numpy as np


@dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    n_hosts: int = 1
    host_id: int = 0
    seed: int = 0


class SyntheticTokenPipeline:
    """Markov-ish synthetic tokens (deterministic per (seed, step, host)).

    A light structure (token t+1 correlated with t) gives training losses
    that actually decrease, so the e2e example shows learning.
    """

    def __init__(self, cfg: TokenPipelineConfig, shard_sizes: Optional[List[int]] = None):
        self.cfg = cfg
        self.shard_sizes = shard_sizes

    def host_batch_size(self) -> int:
        c = self.cfg
        if self.shard_sizes is not None:
            return self.shard_sizes[c.host_id]
        assert c.global_batch % c.n_hosts == 0
        return c.global_batch // c.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, c.host_id])
        )
        B = self.host_batch_size()
        base = rng.integers(0, c.vocab, size=(B, 1), dtype=np.int32)
        steps = rng.integers(0, 17, size=(B, c.seq_len), dtype=np.int32) - 8
        toks = (np.cumsum(steps, axis=1) + base) % c.vocab
        toks = toks.astype(np.int32)
        return {"tokens": toks, "labels": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass(frozen=True)
class VolumePipelineConfig:
    patch: int  # input patch size (n_in per axis)
    channels: int = 1
    batch: int = 1
    seed: int = 0


class SyntheticVolumePipeline:
    """3D EM-like volumes: smoothed noise (membrane-ish structure)."""

    def __init__(self, cfg: VolumePipelineConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> np.ndarray:
        c = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([c.seed, step]))
        x = rng.normal(size=(c.batch, c.channels, c.patch, c.patch, c.patch))
        # cheap separable smoothing for spatial correlation
        for ax in (2, 3, 4):
            x = 0.5 * x + 0.25 * (np.roll(x, 1, axis=ax) + np.roll(x, -1, axis=ax))
        return x.astype(np.float32)

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for x in self._it:
                self._q.put(x)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x
