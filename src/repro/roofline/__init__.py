"""Roofline-term extraction from compiled artifacts."""

from .analysis import RooflineTerms, analyze_compiled, collective_bytes, roofline  # noqa: F401
