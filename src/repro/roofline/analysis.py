"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §7).

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

`cost_analysis()` supplies per-device FLOPs/bytes for the SPMD-partitioned
program; collective bytes are parsed from the optimized HLO (result-shape
bytes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute; async `-done` ops are skipped to avoid double counting).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Dict

from ..core.hw import HardwareSpec, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b"
)
_DONE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)-done\b"
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-category result-shape bytes of collectives in optimized HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        if _DONE_RE.search(line):
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(m.group(0))[0]
        # result decl is everything before the op name
        b = _shape_bytes(lhs)
        key = m.group(1)
        out[key] = out.get(key, 0.0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclass
class RooflineTerms:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0  # MODEL_FLOPS / (HLO_FLOPs * chips)

    def to_dict(self):
        return asdict(self)


def roofline(
    flops_per_device: float,
    bytes_per_device: float,
    coll_bytes_per_device: float,
    *,
    hw: HardwareSpec = TPU_V5E,
    chips: int = 256,
    model_flops: float = 0.0,
) -> RooflineTerms:
    c = flops_per_device / hw.peak_flops
    m = bytes_per_device / hw.hbm_bw
    l = coll_bytes_per_device / hw.ici_bw
    dom = max(("compute", c), ("memory", m), ("collective", l), key=lambda t: t[1])[0]
    ratio = model_flops / (flops_per_device * chips) if flops_per_device else 0.0
    return RooflineTerms(
        flops_per_device, bytes_per_device, coll_bytes_per_device,
        c, m, l, dom, model_flops, ratio,
    )


def analyze_compiled(
    compiled,
    *,
    hw: HardwareSpec = TPU_V5E,
    chips: int = 256,
    model_flops: float = 0.0,
) -> RooflineTerms:
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())["total"]
    return roofline(
        flops, byts, coll, hw=hw, chips=chips, model_flops=model_flops
    )
