"""Batched inference engines (continuous batching).

``engine``         — LM serving: token-level continuous batching over slots.
``volume_engine``  — 3D volume serving: patch-level continuous batching
                     across queued volumes, driven by a planner Plan.
``sharded_engine`` — the N-worker fleet: each sweep's planes (along its
                     sweep axis) partitioned across workers with boundary
                     halo handoff, heartbeat-driven re-dispatch on worker
                     failure.
"""

from .engine import EngineConfig, Request, ServingEngine  # noqa: F401
from .sharded_engine import ShardedVolumeEngine  # noqa: F401
from .volume_engine import VolumeEngine, VolumeRequest  # noqa: F401
