"""Batched inference engine (continuous batching)."""

from .engine import EngineConfig, Request, ServingEngine  # noqa: F401
