"""Batched serving engine: continuous batching over prefill/decode steps.

The ZNNi planner logic applied to LM serving (DESIGN.md §5): the engine
picks the largest decode batch whose KV cache fits the memory budget
(slots), admits requests into free slots (continuous batching), and runs
one fused decode step per tick for all active slots.  Prefill runs
per-request (chunked) and its KV is packed into the slot.

Single-host reference implementation; the batch tensors it produces are
exactly the decode-shape inputs the dry-run shards over the mesh.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S_prompt,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineConfig:
    slots: int  # max concurrent sequences (the "batch" the planner sized)
    max_seq: int  # KV capacity per slot
    eos_id: int = -1  # -1: never stop early


class ServingEngine:
    """Slot-based continuous batching."""

    def __init__(self, model: Model, params: Any, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.caches = model.make_caches(cfg.slots, cfg.max_seq)
        self.slot_req: List[Optional[Request]] = [None] * cfg.slots
        self.queue: List[Request] = []
        self._next_tok = jnp.zeros((cfg.slots, 1), jnp.int32)

    # -- admission ----------------------------------------------------------

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.cfg.slots):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into_slot(slot, req)
                self.slot_req[slot] = req

    def _prefill_into_slot(self, slot: int, req: Request) -> None:
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self.model.prefill(
            self.params, {"tokens": toks}, cache_len=self.cfg.max_seq
        )
        first = int(jnp.argmax(logits[0, -1]))
        req.out.append(first)
        self._next_tok = self._next_tok.at[slot, 0].set(first)
        # pack the single-sequence cache into the slot of the batched cache
        def pack(big, small):
            if big.ndim == 1:  # lengths
                return big.at[slot].set(small[0])
            # batch dim is axis 1 for stacked caches (R/L, B, ...)
            return jax.lax.dynamic_update_index_in_dim(big, small[:, 0], slot, 1)

        self.caches = jax.tree.map(pack, self.caches, cache1)

    # -- decode tick ---------------------------------------------------------

    def step(self) -> int:
        """One engine tick: admit, fused decode for all slots; returns the
        number of active sequences."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        logits, self.caches = self.model.decode_step(
            self.params, self._next_tok, self.caches
        )
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        self._next_tok = nxt[:, None]
        for slot in active:
            req = self.slot_req[slot]
            tok = int(nxt[slot])
            req.out.append(tok)
            if len(req.out) >= req.max_new or tok == self.cfg.eos_id:
                req.done = True
                self.slot_req[slot] = None
        return len(active)

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and not self.queue:
                break
        return finished
