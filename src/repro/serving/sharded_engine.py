"""Sharded volume serving fleet: one sweep partitioned across N workers.

The scale step past the single-device ``VolumeEngine``: each request's
sweep is partitioned into contiguous runs of sweep planes — working-frame
axis 0, whatever volume axis the plan or request sweeps (``tiler.
plane_shards``) — one run per worker of an N-worker mesh.  A shard is
exactly a window of the single-device sweep schedule — same plane-capped
chunks (``tiler.chunk_patches``), same strip/full path decisions — because
the only cross-shard state, the executor's boundary caches, is shipped
between workers as a ``distributed.collectives.HaloPackage``: when worker
w finishes its run, every layer-0 segment spectrum and activation-halo
entry whose absolute-x key is at or past the successor's first plane is
staged out to host and imported into worker w+1's sweep scope (keys are
the tiler's ``HaloSpec`` absolute coordinates, so entries land exactly
where a single-device sweep would hold them).  That makes the fleet's
output **bitwise equal** to the single-device engine for any worker count
— the acceptance property ``tests/test_sharded_serving.py`` pins — while
each worker's device working set covers only its own slab of the volume.

Within one request the shards form a wavefront (worker w+1's strip path
needs w's boundary halos), so fleet throughput comes from pipelining:
while worker 1 runs request A's second shard, worker 0 already runs
request B's first.  Admission follows the saxml servable-model contract:

* **sorted batch-size buckets** — chunk sizes are rounded up to a static
  ascending bucket list (powers of two up to the executor batch), so the
  fleet dispatches O(log batch) jit specializations per worker;
* **``max_live_batches`` admission** — at most that many requests hold
  runtime state (tasks, sweeps scopes) at once; the rest wait in a FIFO
  pending queue;
* **explicit staging** — inputs reach a worker's device per-shard (the
  streaming executor stages one x-slab per plane from the shared host
  volume), outputs return to host per-chunk (``run_patch_batch`` returns
  host arrays), and boundary packages cross workers through host RAM.

Fault tolerance (``distributed.fault_tolerance.HeartbeatMonitor``): every
tick each live worker runs one chunk and heartbeats a synthetic clock (no
wall-clock anywhere — the fault drills in ``tests/_fault_harness.py``
script death/slowdown per tick, deterministically).  The monitor's policy
is applied with its own precedence — EVICT for failed workers first,
REBALANCE for stragglers otherwise:

* **EVICT / re-dispatch** — a failed worker's unfinished shard tasks are
  re-queued onto survivors as replacement tasks that replay the shard
  from its retained start package.  Replay is bitwise-identical (same
  package, same schedule), so any patches the dead worker already wrote
  are re-written with identical values — and counted, not double-applied:
  per-request done-sets drop duplicate completions idempotently, which
  also covers a *revived* worker finishing its zombie task later.
* **REBALANCE** — a straggler keeps its shard but its trailing unstarted
  planes are split off into a new chained task for another worker (the
  boundary handoff generalizes to any contiguous partition, so parity is
  unaffected); its plane share shrinks before any eviction.

``last_stats`` reports the fleet counters the tests and the benchmark's
``sharded`` row pin: per-worker halo-exchange bytes (measured ==
``tiler.predict_shard_handoff`` x ``executor.handoff_entry_nbytes``,
exactly), re-dispatches, rebalances, duplicates dropped.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from ..configs.base import ConvNetConfig
from ..core.planner import Plan
from ..distributed.collectives import HaloPackage, empty_halo_package, halo_exchange
from ..distributed.fault_tolerance import HeartbeatMonitor
from ..volume.executor import PlanExecutor
from ..volume.tiler import pad_volume, plane_shards, predict_shard_handoff
from .volume_engine import VolumeRequest, finish_patch, init_plane_accounting


@dataclass(eq=False)
class _ShardTask:
    """One worker's contiguous run of a request's sweep planes."""

    req: VolumeRequest
    shard: int  # shard index within the request (stable, for stats)
    planes: Tuple[int, ...]  # plane x-starts, ascending
    boundary_x: Optional[int]  # successor's first plane (None for the last shard)
    successor: Optional["_ShardTask"] = None
    start_pkg: Optional[HaloPackage] = None  # None until the predecessor exports
    ready: bool = False  # start package delivered (first shard: at dispatch)
    zombie: bool = False  # original copy kept by an evicted worker
    rebalanced: bool = False  # trailing planes already split off once
    # runtime
    queue: Deque[int] = field(default_factory=deque)  # patch indices, tiler order
    token: Optional[int] = None  # sweep scope on the owning worker's executor
    started: bool = False
    done: bool = False


@dataclass(eq=False)
class _Worker:
    wid: int
    executor: PlanExecutor
    alive: bool = True
    steps: int = 0  # chunks run (the heartbeat step counter)
    tasks: Deque[_ShardTask] = field(default_factory=deque)
    halo_bytes_in: int = 0
    halo_bytes_out: int = 0
    patches_done: int = 0

    def unfinished(self) -> List[_ShardTask]:
        return [t for t in self.tasks if not t.done]


class ShardedVolumeEngine:
    """Serve volume requests across an N-worker device mesh.

    Same request API as ``VolumeEngine`` (``submit`` + ``step`` /
    ``run_until_drained``; ``VolumeRequest`` with priorities ignored in
    favour of FIFO admission, ``on_strip`` streaming completion preserved
    in single-device order).  Every worker owns a full ``PlanExecutor``
    over the same plan — one CompiledPlan per worker, shared across all
    requests that worker serves.
    """

    def __init__(
        self,
        params,
        net: ConvNetConfig,
        plan: Optional[Plan] = None,
        *,
        n_workers: int = 2,
        max_live_batches: Optional[int] = None,
        bucket_shapes: bool = True,
        fault_hooks=None,
        straggler_factor: float = 3.0,
        patience: int = 2,
        prims=None,
        m: Optional[int] = None,
        batch: Optional[int] = None,
        use_pallas: Optional[bool] = None,
        fuse_pairs: Optional[bool] = None,
        fprime_chunk=None,
        fuse_os: Optional[bool] = None,
        tuned="auto",
        deep_reuse: bool = True,
        ram_budget: Optional[float] = None,
        streaming: Optional[bool] = True,
        sweep_axis: Optional[int] = None,
    ):
        if n_workers < 1:
            raise ValueError("n_workers must be >= 1")
        self.workers = [
            _Worker(w, PlanExecutor(
                params, net, plan, prims=prims, m=m, batch=batch,
                use_pallas=use_pallas, fuse_pairs=fuse_pairs,
                fprime_chunk=fprime_chunk, fuse_os=fuse_os, tuned=tuned,
                deep_reuse=deep_reuse, ram_budget=ram_budget,
                streaming=streaming, sweep_axis=sweep_axis,
            ))
            for w in range(n_workers)
        ]
        base = self.workers[0].executor
        if not base._os_reuse:
            raise ValueError(
                "ShardedVolumeEngine needs an overlap-save reuse plan "
                "(prims[0] == 'overlap_save' with MPF pooling): shard "
                "boundaries hand off the sweep caches"
            )
        self.n_workers = n_workers
        self.batch = base.batch
        # saxml contract: static ascending batch-size buckets; every chunk
        # runs at the smallest bucket that fits it
        buckets = {self.batch}
        s = 1
        while s < self.batch:
            buckets.add(s)
            s *= 2
        self.batch_buckets: Tuple[int, ...] = tuple(sorted(buckets))
        self.max_live_batches = max_live_batches
        self.bucket_shapes = bucket_shapes
        self.fault_hooks = fault_hooks
        self.monitor = HeartbeatMonitor(
            n_workers, straggler_factor=straggler_factor, patience=patience
        )
        self.clock = 0.0
        self.ticks = 0
        self.pending: Deque[VolumeRequest] = deque()  # admission queue (FIFO)
        self.live: List[VolumeRequest] = []
        self.finished: List[VolumeRequest] = []
        self.redispatches = 0
        self.rebalances = 0
        self.duplicates_dropped = 0
        self._predicted_halo_in = [0] * n_workers  # bytes, at dispatch time
        self.last_stats: Dict[str, object] = {}

    # -- admission (saxml: max_live_batches) --------------------------------

    def submit(self, req: VolumeRequest) -> None:
        """Queue a request; it gains runtime state only when admitted."""
        self.pending.append(req)
        self._admit()

    def _admit(self) -> None:
        while self.pending and (
            self.max_live_batches is None
            or len(self.live) < self.max_live_batches
        ):
            self._dispatch(self.pending.popleft())

    def _dispatch(self, req: VolumeRequest) -> None:
        """Prepare runtime state and fan the request's shards out."""
        base = self.workers[0].executor
        axis = base.sweep_axis if req.sweep_axis is None else int(req.sweep_axis)
        vol = np.asarray(req.volume, np.float32)
        true_shape = vol.shape[1:]
        if self.bucket_shapes:
            shape = base.bucket_shape(true_shape)
            pad = [(0, 0)] + [(0, b - x) for b, x in zip(shape, true_shape)]
            padded = np.pad(vol, pad) if any(p for _, p in pad) else vol
        else:
            shape, padded = true_shape, vol
        tiling = base.tiling_for(shape, sweep_axis=axis)
        req._tiling = tiling
        # the shared host volume: every worker's sweep scope reads it (the
        # streaming executor keeps it host-side and stages per-plane slabs);
        # it must outlive the request so evicted shards can be replayed
        req._padded = pad_volume(padded, tiling)
        req._remaining = tiling.n_patches
        req.done = False
        init_plane_accounting(req, tiling)
        out_shape = tuple(x - base.fov + 1 for x in true_shape)
        req.out = np.empty((base.out_channels,) + out_shape, np.float32)
        req._done_patches = set()  # idempotent completion guard
        # contiguous plane partition + shard chain
        shards = plane_shards(tiling, self.n_workers)
        # patch indices per plane start, in tiler order
        by_plane: Dict[int, List[int]] = {}
        for idx, p in enumerate(tiling.patches):
            by_plane.setdefault(p.start[0], []).append(idx)
        tasks: List[_ShardTask] = []
        for si, planes in enumerate(shards):
            if not planes:
                continue
            tasks.append(_ShardTask(req, si, tuple(planes), None))
        for t, nxt in zip(tasks, tasks[1:]):
            t.boundary_x = nxt.planes[0]
            t.successor = nxt
        for t in tasks:
            t.queue = deque(i for x0 in t.planes for i in by_plane[x0])
        if tasks:
            tasks[0].start_pkg = empty_halo_package()
            tasks[0].ready = True
        req._tasks = tasks
        self.live.append(req)
        # predicted handoff schedule (dispatch-time assignment): boundary b
        # is received by the worker owning the successor shard
        boundaries = [t.boundary_x for t in tasks if t.boundary_x is not None]
        handoffs = predict_shard_handoff(
            tiling, boundaries, batch=self.batch,
            deep_reuse=base.deep_reuse, strip_segments=base._q_strip,
        )
        seg_b, halo_b = base.handoff_entry_nbytes()
        alive = [w for w in self.workers if w.alive]
        for t, h in zip(tasks[1:], handoffs):
            wid = alive[t.shard % len(alive)].wid
            self._predicted_halo_in[wid] += h.seg_keys * seg_b + h.halo_entries * halo_b
        # stable shard→worker assignment (shard index round-robin over the
        # workers alive at dispatch) — with a full fleet, shard w lands on
        # worker w, which is what pipelines consecutive requests
        for t in tasks:
            alive[t.shard % len(alive)].tasks.append(t)

    # -- tick ----------------------------------------------------------------

    def _bucket(self, n: int) -> int:
        for b in self.batch_buckets:
            if b >= n:
                return b
        return self.batch_buckets[-1]

    def _next_task(self, w: _Worker) -> Optional[_ShardTask]:
        """The worker's first runnable task (ready, not done, FIFO)."""
        for t in w.tasks:
            if t.done:
                continue
            if t.req.done and not t.started:
                # a replay already finished this request; nothing to do
                t.done = True
                continue
            if t.ready:
                return t
        return None

    def _run_chunk(self, w: _Worker, task: _ShardTask) -> int:
        """One plane-capped chunk of ``task`` on worker ``w``."""
        ex = w.executor
        req = task.req
        tiling = req._tiling
        if not task.started:
            # input staging is per shard: the scope shares the request's
            # host volume; only this shard's slabs ever reach w's device
            task.token = ex.begin_sweep(
                req._padded, sweep_axis=tiling.sweep_axis
            )
            if task.start_pkg is not None and not task.start_pkg.is_empty():
                ex.import_handoff(task.token, task.start_pkg)
                w.halo_bytes_in += task.start_pkg.nbytes
            task.started = True
        items: List[int] = []
        plane = None
        while task.queue and len(items) < self.batch:
            x0 = tiling.patches[task.queue[0]].start[0]
            if plane is None:
                plane = x0
            elif x0 != plane:
                break  # plane cap: chunks match tiler.chunk_patches exactly
            items.append(task.queue.popleft())
        if not items:
            self._maybe_finish_task(w, task)
            return 0
        S_run = self._bucket(len(items))
        meta = [
            (task.token, tiling.segment_keys(tiling.patches[i]),
             tiling.patches[i].start)
            for i in items
        ]
        meta += [meta[-1]] * (S_run - len(items))
        ys = ex.run_patch_batch(None, meta=meta)  # output-to-host staging
        for idx, y in zip(items, ys):
            self._complete_patch(w, req, idx, y)
        w.patches_done += len(items)
        if not task.queue:
            self._maybe_finish_task(w, task)
        return len(items)

    def _maybe_finish_task(self, w: _Worker, task: _ShardTask) -> None:
        if task.done:
            return
        task.done = True
        if task.started:
            if (
                task.successor is not None
                and not task.zombie
                and not task.successor.ready
            ):
                # boundary handoff: stage every cache entry at or past the
                # successor's first plane out to host.  Import happens when
                # the successor's worker opens the shard (its executor may
                # not even have a scope yet), so the exchange is split: the
                # export half here, recorded on the package.
                pkg = w.executor.export_handoff(task.token, task.boundary_x)
                w.halo_bytes_out += pkg.nbytes
                task.successor.start_pkg = pkg
                task.successor.ready = True
            w.executor.end_sweep(task.token)
            task.token = None

    def _complete_patch(self, w: _Worker, req: VolumeRequest, idx: int, y) -> None:
        """Write one patch core — idempotently.

        Re-dispatch replays and revived zombies re-complete patches the
        done-set already holds; they are dropped (and counted) so request
        accounting never double-fires strips or completion.
        """
        if idx in req._done_patches:
            self.duplicates_dropped += 1
            return
        req._done_patches.add(idx)
        tiling = req._tiling
        w.executor.write_core(req.out, tiling, tiling.patches[idx], y)
        if finish_patch(req, tiling.patches[idx].start[0]):
            self._finish_request(req)

    def _finish_request(self, req: VolumeRequest) -> None:
        self.live = [r for r in self.live if r is not req]
        self.finished.append(req)
        self._admit()

    def step(self) -> int:
        """One fleet tick: every live worker runs one chunk, heartbeats a
        synthetic clock, then the monitor's policy is applied.  Returns
        the number of (non-duplicate-counted) patches processed."""
        hooks = self.fault_hooks
        ran = 0
        times: List[float] = []
        for w in self.workers:
            if not w.alive:
                continue
            if hooks is not None and hooks.down(w.wid, self.ticks):
                continue  # scripted death/hang: no work, no heartbeat
            task = self._next_task(w)
            worked = task is not None
            if worked:
                ran += self._run_chunk(w, task)
            # idle/blocked workers still heartbeat — the process is alive;
            # their steps keep advancing max_step so a genuinely dead peer
            # falls behind and gets classified even when the rest of the
            # fleet is blocked waiting on ITS handoff.  But only a worker
            # that actually ran a chunk reports a step-time sample: an
            # idle keepalive must not skew the fleet's rolling median.
            t = 1.0 if hooks is None else float(hooks.step_time(w.wid, self.ticks))
            w.steps += 1
            if worked:
                times.append(t)
            self.monitor.heartbeat(
                w.wid, w.steps, t if worked else None,
                now=self.clock + (t if worked else 0.0),
            )
        self.clock += max(times) if times else 1.0
        self._apply_fault_plan()
        self.ticks += 1
        self._refresh_stats()
        return ran

    # -- fault policy --------------------------------------------------------

    def _busy_workers(self) -> set:
        """Workers the fault policy may act on: alive with a RUNNABLE task.

        A live worker with runnable work heartbeats every tick, so a stale
        heartbeat here really means death/hang.  Workers that are merely
        idle (shard finished) or blocked on a predecessor's handoff are
        excused — they have nothing to run, so silence is not failure.
        """
        return {
            w.wid for w in self.workers
            if w.alive and self._next_task(w) is not None
        }

    def _apply_fault_plan(self) -> None:
        plan = self.monitor.plan(now=self.clock)
        busy = self._busy_workers()
        targets = [wid for wid in plan["workers"] if wid in busy]
        if plan["action"] == "evict_and_restore":
            for wid in targets:
                self._evict_worker(self.workers[wid])
        elif plan["action"] == "rebalance":
            for wid in targets:
                self._rebalance_worker(self.workers[wid])

    def _evict_worker(self, w: _Worker) -> None:
        """EVICT: re-dispatch the failed worker's unfinished shards.

        Each unfinished task is re-queued onto a survivor as a *fresh
        replay* from its retained start package — bitwise-identical to the
        original run, so partial progress by the dead worker needs no
        merging: overlapping completions are duplicate-dropped.  The dead
        worker keeps its originals as zombies; if it is later revived it
        finishes them into the done-set (idempotent), never the chain.
        """
        w.alive = False
        self.monitor.evict(w.wid)
        survivors = [s for s in self.workers if s.alive]
        if not survivors:
            raise RuntimeError("sharded fleet lost every worker")
        for task in list(w.unfinished()):
            task.zombie = True
            repl = _ShardTask(
                task.req, task.shard, task.planes, task.boundary_x,
                successor=task.successor, start_pkg=task.start_pkg,
                ready=task.ready,
            )
            task.successor = None
            by_plane: Dict[int, List[int]] = {}
            for idx, p in enumerate(task.req._tiling.patches):
                by_plane.setdefault(p.start[0], []).append(idx)
            repl.queue = deque(i for x0 in repl.planes for i in by_plane[x0])
            # repoint the predecessor (if it hasn't exported yet) at the
            # replacement, so the boundary package reaches the live chain
            for t in task.req._tasks:
                if t.successor is task:
                    t.successor = repl
            task.req._tasks.append(repl)
            target = min(survivors, key=lambda s: (len(s.unfinished()), s.wid))
            target.tasks.append(repl)
            self.redispatches += 1

    def _rebalance_worker(self, w: _Worker) -> None:
        """REBALANCE: split a straggler's trailing unstarted planes off
        into a new chained task for the least-loaded other worker.  Any
        contiguous partition is parity-exact (the handoff generalizes), so
        shrinking the share changes wall-clock, never values."""
        task = self._next_task(w)
        if task is None or task.rebalanced:
            return
        tiling = task.req._tiling
        queued = set(task.queue)
        by_plane: Dict[int, List[int]] = {}
        for idx, p in enumerate(tiling.patches):
            by_plane.setdefault(p.start[0], []).append(idx)
        untouched = [
            x0 for x0 in task.planes
            if all(i in queued for i in by_plane[x0])
        ]
        if len(untouched) < 2:
            return  # nothing meaningful to shed
        moved = tuple(untouched[len(untouched) // 2:])
        others = [s for s in self.workers if s.alive and s.wid != w.wid]
        if not others:
            return
        split = _ShardTask(
            task.req, task.shard, moved, task.boundary_x,
            successor=task.successor,
        )
        split.queue = deque(i for x0 in moved for i in by_plane[x0])
        moved_set = set(split.queue)
        task.planes = tuple(x0 for x0 in task.planes if x0 not in moved)
        task.queue = deque(i for i in task.queue if i not in moved_set)
        task.boundary_x = moved[0]
        task.successor = split
        task.rebalanced = True
        task.req._tasks.append(split)
        target = min(others, key=lambda s: (len(s.unfinished()), s.wid))
        target.tasks.append(split)
        self.rebalances += 1

    def revive_worker(self, wid: int) -> None:
        """Re-admit an evicted worker (the revival drill).

        The worker resumes whatever zombie tasks it still holds — their
        sweep scopes were deliberately left open at eviction — and every
        patch it completes that a replay already wrote is dropped by the
        request's done-set (``duplicates_dropped`` counts them).  It also
        becomes eligible for new shard assignments."""
        w = self.workers[wid]
        w.alive = True
        self.monitor.revive(wid, now=self.clock)

    # -- stats / drain -------------------------------------------------------

    def _refresh_stats(self) -> None:
        self.last_stats = {
            "workers": self.n_workers,
            "alive_workers": sum(1 for w in self.workers if w.alive),
            "ticks": self.ticks,
            "clock": self.clock,
            "batch_buckets": list(self.batch_buckets),
            "patches": sum(w.patches_done for w in self.workers),
            "redispatches": self.redispatches,
            "rebalances": self.rebalances,
            "duplicates_dropped": self.duplicates_dropped,
            "halo_bytes_in": [w.halo_bytes_in for w in self.workers],
            "halo_bytes_out": [w.halo_bytes_out for w in self.workers],
            "halo_exchange_bytes": sum(w.halo_bytes_in for w in self.workers),
            "predicted_halo_bytes_in": list(self._predicted_halo_in),
            "predicted_halo_exchange_bytes": sum(self._predicted_halo_in),
            "peak_device_bytes": max(
                w.executor._ledger.peak for w in self.workers
            ),
            "retraces": sum(
                len(w.executor._trace_keys) for w in self.workers
            ),
        }

    def run_until_drained(self, max_ticks: int = 100_000) -> List[VolumeRequest]:
        """Tick until every submitted request finished.

        Unlike the single-device drain loop, a zero-work tick does NOT
        stop the fleet: the synthetic clock must keep advancing for the
        monitor to detect a dead worker and re-dispatch its shards.
        """
        for _ in range(max_ticks):
            if not self.live and not self.pending:
                return self.finished
            self.step()
        if self.live or self.pending:
            raise RuntimeError(
                f"fleet did not drain within {max_ticks} ticks "
                f"({len(self.live)} live, {len(self.pending)} pending)"
            )
        return self.finished


# re-exported for callers that pair export/import manually
__all__ = ["ShardedVolumeEngine", "halo_exchange"]
