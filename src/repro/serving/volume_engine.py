"""Volume serving engine: continuous batching of patches across requests.

The 3D-inference analogue of ``serving/engine.py``: requests are whole
volumes, work items are patches.  Each tick drains up to ``batch`` patches
from the *front of the global patch queue* — patches of different queued
volumes share one fused executor step whenever a request doesn't fill the
batch (all patches of one plan have identical shape, so cross-request
batching is free).  A request completes when its last patch's core has
been written into its dense output buffer.

The engine drives ``PlanExecutor.run_patch_batch`` (single fused step per
tick).  pipeline2 plans are accepted — their primitives are identical; the
two-stage scan schedule is an executor-level optimization used by
``PlanExecutor.run`` for offline sweeps, not by the tick loop.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import numpy as np

from ..configs.base import ConvNetConfig
from ..core.planner import Plan
from ..volume.executor import PlanExecutor
from ..volume.tiler import VolumeTiling, extract_patch, pad_volume


@dataclass
class VolumeRequest:
    rid: int
    volume: np.ndarray  # (f, X, Y, Z)
    out: Optional[np.ndarray] = None  # (out_ch, X-FOV+1, ...) when done
    done: bool = False
    # internal runtime state
    _tiling: Optional[VolumeTiling] = field(default=None, repr=False)
    _padded: Optional[np.ndarray] = field(default=None, repr=False)
    _remaining: int = field(default=0, repr=False)
    _sweep: Optional[int] = field(default=None, repr=False)  # spectra scope


class VolumeEngine:
    """Queue volume requests; stream their patches through one executor."""

    def __init__(
        self,
        params,
        net: ConvNetConfig,
        plan: Optional[Plan] = None,
        *,
        prims=None,
        m: Optional[int] = None,
        batch: Optional[int] = None,
        use_pallas: bool = False,
    ):
        self.executor = PlanExecutor(
            params, net, plan, prims=prims, m=m, batch=batch,
            use_pallas=use_pallas,
        )
        self.batch = self.executor.batch
        self.queue: Deque[Tuple[VolumeRequest, int]] = deque()
        self.finished: List[VolumeRequest] = []
        self.ticks = 0

    # -- admission ----------------------------------------------------------

    def submit(self, req: VolumeRequest) -> None:
        ex = self.executor
        tiling = ex.tiling_for(np.asarray(req.volume).shape[1:])
        req._tiling = tiling
        req._padded = pad_volume(np.asarray(req.volume, np.float32), tiling)
        req._remaining = tiling.n_patches
        req._sweep = None  # resubmission must not revive a freed scope
        req.out = np.empty((ex.out_channels,) + tiling.out_shape, np.float32)
        # overlap-save reuse: one spectra scope per request — patches of one
        # volume share boundary spectra, requests never do (their segment
        # coordinates name different data).  The scope (and its device-
        # resident volume) is opened lazily at the first tick that touches
        # the request, so device residency scales with in-flight sweeps,
        # not with the queue.
        for idx in range(tiling.n_patches):
            self.queue.append((req, idx))

    # -- tick ---------------------------------------------------------------

    def step(self) -> int:
        """One fused batch over the head of the patch queue; returns the
        number of real (non-padding) patches processed."""
        if not self.queue:
            return 0
        items = [self.queue.popleft() for _ in range(min(self.batch, len(self.queue)))]
        # a drained-queue tail runs at the executor's bucketed batch size
        # (next power of two, or exactly len(items) if already compiled):
        # continuous serving can see arbitrary ready-counts per tick, so
        # bucketing bounds XLA compiles at O(log batch) while avoiding most
        # padded-and-discarded work; the prepared states are shared anyway.
        S_run = self.executor.padded_batch_size(len(items))
        if self.executor._os_reuse:
            # per-patch (sweep, segment keys): cross-request batches mix
            # scopes safely; bucketing's repeated tail patch re-presents
            # the same keys and is served from the cache it just filled.
            for req, _ in items:
                if req._sweep is None:
                    req._sweep = self.executor.begin_sweep(req._padded)
                    # the sweep owns a device-resident copy now and this
                    # mode never extracts host-side patches: the host
                    # padded copy is dead — free it early
                    req._padded = None
            meta = [
                (req._sweep, req._tiling.segment_keys(req._tiling.patches[idx]))
                for req, idx in items
            ]
            meta += [meta[-1]] * (S_run - len(items))
            ys = self.executor.run_patch_batch(None, meta=meta)
        else:
            xs = np.stack(
                [
                    extract_patch(req._padded, req._tiling.patches[idx], req._tiling.extent)
                    for req, idx in items
                ]
            )
            if S_run > len(items):
                xs = np.concatenate(
                    [xs, np.repeat(xs[-1:], S_run - len(items), axis=0)]
                )
            ys = self.executor.run_patch_batch(xs)
        for (req, idx), y in zip(items, ys):
            self.executor.write_core(req.out, req._tiling, req._tiling.patches[idx], y)
            req._remaining -= 1
            if req._remaining == 0:
                req.done = True
                req._padded = None  # drop the padded copy early
                self.executor.end_sweep(req._sweep)  # free boundary spectra
                self.finished.append(req)
        self.ticks += 1
        return len(items)

    def run_until_drained(self, max_ticks: int = 100_000) -> List[VolumeRequest]:
        for _ in range(max_ticks):
            if self.step() == 0:
                break
        return self.finished
