"""Volume serving engine: continuous batching of patches across requests.

The 3D-inference analogue of ``serving/engine.py``: requests are whole
volumes, work items are patches.  Each tick drains up to ``batch`` patches
from a *priority-ordered* patch queue — patches of different queued
volumes share one fused executor step whenever a request doesn't fill the
batch (all patches of one plan have identical shape, so cross-request
batching is free).  A request completes when its last patch's core has
been written into its dense output buffer.

Scheduling: requests carry a ``priority`` (higher first); within a
priority level, submission order (FIFO).  Starvation is bounded by aging —
a waiting request gains one effective priority level every ``age_ticks``
ticks, so any request eventually outranks a steady stream of
higher-priority arrivals.  Patches of the currently highest-ranked
request drain in tiler order (the executor's reuse caches depend on it).

Shape bucketing: request volumes are zero-padded up to the executor's
patch-grid buckets (``PlanExecutor.bucket_shape``) before tiling, so the
fused per-batch jit step — keyed on the device-resident volume shape —
does not retrace for every distinct request size, and every patch start
is core-aligned (no shifted edge patches, maximum cross-patch reuse).
Outputs are written only over the true dense range, so bucketing is exact
(the pad-and-crop argument in ``volume/tiler.py``).  Watch
``executor.last_stats["retraces"]`` to see the distinct jit
specializations stay flat as differently-sized requests stream through.

Streaming completion (ISSUE 5): a dense output x-row is FINAL once every
patch that writes it has run — with the x-major patch order that is
plane-by-plane.  ``VolumeRequest.final_rows`` advances as planes
complete and ``on_strip(lo, hi, strip)`` fires per finalized strip, so
callers consume early partial results while the tail of the volume is
still queued.

Shared device budget (ISSUE 5): ``device_budget`` bounds the combined
device working set of concurrent sweeps.  A tick defers *opening* a new
sweep scope (slabs + spectra/halo caches, estimated by
``PlanExecutor.sweep_bytes_estimate``) that would push the executor's
ledger past the budget; open sweeps drain first, and one sweep is always
admitted so the queue cannot stall.  Pass ``ram_budget`` to run the
executor host-staged (see ``volume/executor.py``).

The engine drives ``PlanExecutor.run_patch_batch`` (single fused step per
tick).  pipeline2 and hetero plans are accepted — their per-layer
primitives are identical to a single-device plan's; the split-point
schedules (the two-stage pod scan, the two-backend host-RAM pipeline)
are executor-level optimizations used by ``PlanExecutor.run`` for
offline sweeps, not by the tick loop, which serves every plan through
the one fused step.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from ..configs.base import ConvNetConfig
from ..core.planner import Plan
from ..volume.executor import PlanExecutor
from ..volume.tiler import (
    VolumeTiling,
    extract_patch,
    final_rows_after_plane,
    pad_volume,
    plane_starts,
)


# eq=False: requests are identities, not values.  Generated dataclass
# equality would compare the ndarray fields — ambiguous-truth-value
# errors on any membership test (``req in engine.active``) as soon as two
# requests carry the same payload (the same-payload duplicate regression
# in tests/test_volume_engine_sched.py).
@dataclass(eq=False)
class VolumeRequest:
    rid: int
    volume: np.ndarray  # (f, X, Y, Z)
    priority: int = 0  # higher = served first (ages up while waiting)
    out: Optional[np.ndarray] = None  # (out_ch, X-FOV+1, ...) when done
    done: bool = False
    # sweep_axis: VOLUME axis this request's sweep advances on.  None uses
    # the engine executor's default axis; an explicit non-default axis
    # needs an overlap-save reuse plan (per-axis prepared states are built
    # lazily and sweep scopes of different axes never share cache keys, so
    # mixed-axis requests batch safely in one tick).
    sweep_axis: Optional[int] = None
    # streaming completion: dense output rows [0, final_rows) ALONG THE
    # SWEEP AXIS are FINAL (every contributing patch done — no later patch
    # can rewrite them).  ``on_strip(lo, hi, strip)`` fires as each new
    # strip finalizes, with ``strip`` a VIEW of the out slab covering
    # sweep-axis rows [lo, hi) — early partial results while the rest of
    # the volume is still queued.
    final_rows: int = 0
    on_strip: Optional[Callable[[int, int, np.ndarray], None]] = None
    # internal runtime state
    _tiling: Optional[VolumeTiling] = field(default=None, repr=False)
    _padded: Optional[np.ndarray] = field(default=None, repr=False)
    _patches: Optional[Deque[int]] = field(default=None, repr=False)
    _remaining: int = field(default=0, repr=False)
    _sweep: Optional[int] = field(default=None, repr=False)  # spectra scope
    _seq: int = field(default=0, repr=False)  # submission order
    _submit_tick: int = field(default=0, repr=False)  # aging anchor
    _plane_remaining: Optional[Dict[int, int]] = field(default=None, repr=False)
    _plane_order: Tuple[int, ...] = field(default=(), repr=False)
    _next_plane: int = field(default=0, repr=False)
    _sweep_bytes_est: float = field(default=0.0, repr=False)


# -- request lifecycle helpers shared with serving.sharded_engine ----------
#
# Both engines drive the same per-request bookkeeping: plane counters at
# submit, per-patch completion accounting, in-order strip finalization.
# Keeping them module-level (not methods) is what lets the sharded fleet
# reuse the exact single-device semantics — identical strip order is an
# acceptance property, not a coincidence.


def init_plane_accounting(req: VolumeRequest, tiling: VolumeTiling) -> None:
    """Reset the request's per-plane completion counters for ``tiling``."""
    req._plane_order = plane_starts(tiling)
    req._plane_remaining = {x0: 0 for x0 in req._plane_order}
    for p in tiling.patches:
        req._plane_remaining[p.start[0]] += 1
    req._next_plane = 0
    req.final_rows = 0


def advance_strips(req: VolumeRequest, plane_x0: int) -> None:
    """Finalize output strips whose contributing planes all completed.

    Bucket padding is handled by clipping to the TRUE dense extent:
    planes living entirely in the padding finalize zero new rows (no
    callback fires for an empty strip).  Planes finalize strictly in
    sweep order (``_next_plane`` never skips), so ``on_strip`` callbacks
    fire identically however patch completions interleave — the property
    that makes sharded out-of-order completion invisible to callers.
    """
    req._plane_remaining[plane_x0] -= 1
    ax = 1 + req._tiling.sweep_axis  # volume axis the planes advance on
    while req._next_plane < len(req._plane_order):
        x0 = req._plane_order[req._next_plane]
        if req._plane_remaining[x0] > 0:
            return
        req._next_plane += 1
        hi = min(final_rows_after_plane(req._tiling, x0), req.out.shape[ax])
        lo = req.final_rows
        if hi > lo:
            req.final_rows = hi
            if req.on_strip is not None:
                sl = [slice(None)] * req.out.ndim
                sl[ax] = slice(lo, hi)
                req.on_strip(lo, hi, req.out[tuple(sl)])


def finish_patch(req: VolumeRequest, plane_x0: int) -> bool:
    """Account one completed patch write; True when the request finished.

    The caller owns what completion *means* (close sweep scopes, move the
    request to its finished list) — this helper owns the shared counters,
    so the two engines cannot drift on when a request is done.
    """
    req._remaining -= 1
    advance_strips(req, plane_x0)
    if req._remaining == 0:
        req.done = True
        req._padded = None  # drop the padded copy early
        return True
    return False


class VolumeEngine:
    """Queue volume requests; stream their patches through one executor."""

    def __init__(
        self,
        params,
        net: ConvNetConfig,
        plan: Optional[Plan] = None,
        *,
        prims=None,
        m: Optional[int] = None,
        batch: Optional[int] = None,
        use_pallas: Optional[bool] = None,
        fuse_pairs: Optional[bool] = None,
        fprime_chunk=None,
        fuse_os: Optional[bool] = None,
        tuned="auto",
        deep_reuse: bool = True,
        bucket_shapes: bool = True,
        age_ticks: int = 8,
        ram_budget: Optional[float] = None,
        streaming: Optional[bool] = None,
        device_budget: Optional[float] = None,
    ):
        self.executor = PlanExecutor(
            params, net, plan, prims=prims, m=m, batch=batch,
            use_pallas=use_pallas, fuse_pairs=fuse_pairs,
            fprime_chunk=fprime_chunk, fuse_os=fuse_os, tuned=tuned,
            deep_reuse=deep_reuse, ram_budget=ram_budget, streaming=streaming,
        )
        self.batch = self.executor.batch
        self.bucket_shapes = bucket_shapes
        self.age_ticks = max(1, age_ticks)
        # shared device budget across concurrent sweeps: a tick defers
        # OPENING new sweep scopes (device slabs + caches) that would push
        # the executor's ledger past the budget; already-open sweeps drain
        # first.  Defaults to ram_budget when only that is given.
        self.device_budget = (
            device_budget if device_budget is not None else ram_budget
        )
        self.active: List[VolumeRequest] = []
        self.finished: List[VolumeRequest] = []
        self.ticks = 0
        self._seq = 0

    # -- admission ----------------------------------------------------------

    def submit(self, req: VolumeRequest) -> None:
        ex = self.executor
        axis = ex.sweep_axis if req.sweep_axis is None else int(req.sweep_axis)
        if axis != ex.sweep_axis and not ex._os_reuse:
            raise ValueError(
                "per-request sweep_axis needs an overlap-save reuse plan"
            )
        vol = np.asarray(req.volume, np.float32)
        true_shape = vol.shape[1:]
        if self.bucket_shapes:
            shape = ex.bucket_shape(true_shape)
            pad = [(0, 0)] + [(0, b - x) for b, x in zip(shape, true_shape)]
            padded = np.pad(vol, pad) if any(p for _, p in pad) else vol
        else:
            shape, padded = true_shape, vol
        tiling = ex.tiling_for(shape, sweep_axis=axis)
        req._tiling = tiling
        req._padded = pad_volume(padded, tiling)
        req._patches = deque(range(tiling.n_patches))
        req._remaining = tiling.n_patches
        req._sweep = None  # resubmission must not revive a freed scope
        self._seq += 1
        req._seq = self._seq
        req._submit_tick = self.ticks
        req.done = False
        # streaming completion bookkeeping: patches per x-plane; a plane's
        # last write finalizes every output row no later plane can touch
        init_plane_accounting(req, tiling)
        if self.device_budget is not None and ex._os_reuse:
            req._sweep_bytes_est = ex.sweep_bytes_estimate(
                shape, sweep_axis=axis
            )
        # the output buffer has the TRUE dense shape; patches over the
        # bucket padding write only their in-range columns (write_core
        # crops), so bucketing never leaks padded voxels into the result
        out_shape = tuple(x - ex.fov + 1 for x in true_shape)
        req.out = np.empty((ex.out_channels,) + out_shape, np.float32)
        # overlap-save reuse: one spectra scope per request — patches of one
        # volume share boundary spectra, requests never do (their segment
        # coordinates name different data).  The scope (and its device-
        # resident volume) is opened lazily at the first tick that touches
        # the request, so device residency scales with in-flight sweeps,
        # not with the queue.
        self.active.append(req)

    # -- scheduling ---------------------------------------------------------

    def _effective_priority(self, req: VolumeRequest) -> int:
        """Static priority plus aging: +1 level per ``age_ticks`` waited."""
        return req.priority + (self.ticks - req._submit_tick) // self.age_ticks

    def _ranked(self) -> List[VolumeRequest]:
        """Active requests, highest effective priority first, FIFO within."""
        return sorted(
            (r for r in self.active if r._patches),
            key=lambda r: (-self._effective_priority(r), r._seq),
        )

    @property
    def queue(self) -> List[Tuple[VolumeRequest, int]]:
        """Pending (request, patch index) pairs in current pop order."""
        return [(r, idx) for r in self._ranked() for idx in r._patches]

    # -- tick ---------------------------------------------------------------

    def _over_budget(self, req: VolumeRequest, pending_est: float) -> bool:
        """Would serving ``req`` now open a sweep the device budget can't
        absorb?  Already-open sweeps always proceed (they only shrink).
        ``pending_est`` counts sweeps admitted EARLIER THIS TICK whose
        ``begin_sweep`` has not run yet — without it two fresh requests
        could each pass against the same ledger reading and jointly blow
        the budget in one tick."""
        if self.device_budget is None or not self.executor._os_reuse:
            return False
        if req._sweep is not None:
            return False
        return (
            self.executor._ledger.current + pending_est + req._sweep_bytes_est
            > self.device_budget
        )

    def _pop_plane_capped(
        self, req: VolumeRequest, items: List[Tuple[VolumeRequest, int]]
    ) -> None:
        """Pop ``req``'s patches into ``items`` up to the batch, never past
        an x-plane boundary.  The cap makes a single request's chunk
        sequence exactly ``tiler.chunk_patches`` — the canonical schedule
        the reuse simulations and the sharded fleet both reproduce — and
        keeps a serving chunk from degrading its later-plane patches to
        the full path (strip eligibility is frozen at chunk start)."""
        plane = None
        while req._patches and len(items) < self.batch:
            x0 = req._tiling.patches[req._patches[0]].start[0]
            if plane is None:
                plane = x0
            elif x0 != plane:
                break
            items.append((req, req._patches.popleft()))

    def step(self) -> int:
        """One fused batch over the priority-ordered patch queue; returns
        the number of real (non-padding) patches processed."""
        items: List[Tuple[VolumeRequest, int]] = []
        deferred: List[VolumeRequest] = []
        pending_est = 0.0
        for req in self._ranked():
            if self._over_budget(req, pending_est):
                deferred.append(req)
                continue
            took = len(items)
            self._pop_plane_capped(req, items)
            if len(items) > took and req._sweep is None:
                pending_est += req._sweep_bytes_est
            if len(items) >= self.batch:
                break
            if req._patches:
                # the plane cap (not exhaustion) stopped the pop: leave the
                # leftover slots empty rather than mixing lower-ranked
                # requests in — strict priority draining is preserved, and
                # the ragged chunk runs through a smaller compiled batch
                # anyway.  Mixing still happens when this request is fully
                # drained mid-batch.
                break
        if not items and deferred:
            # progress guarantee: when every runnable request is waiting on
            # the budget, admit the highest-ranked one anyway (one sweep at
            # a time always fits by construction of the estimate)
            self._pop_plane_capped(deferred[0], items)
        if not items:
            return 0
        ex = self.executor
        # a drained-queue tail runs at the executor's bucketed batch size
        # (next power of two, or exactly len(items) if already compiled):
        # continuous serving can see arbitrary ready-counts per tick, so
        # bucketing bounds XLA compiles at O(log batch) while avoiding most
        # padded-and-discarded work; the prepared states are shared anyway.
        S_run = ex.padded_batch_size(len(items))
        if ex._os_reuse:
            # per-patch (sweep, segment keys, start): cross-request batches
            # mix scopes safely; bucketing's repeated tail patch re-presents
            # the same keys and is served from the cache it just filled.
            for req, _ in items:
                if req._sweep is None:
                    req._sweep = ex.begin_sweep(
                        req._padded, sweep_axis=req._tiling.sweep_axis
                    )
                    # the sweep owns a device-resident copy now and this
                    # mode never extracts host-side patches: the host
                    # padded copy is dead — free it early
                    req._padded = None
            meta = [
                (
                    req._sweep,
                    req._tiling.segment_keys(req._tiling.patches[idx]),
                    req._tiling.patches[idx].start,
                )
                for req, idx in items
            ]
            meta += [meta[-1]] * (S_run - len(items))
            ys = ex.run_patch_batch(None, meta=meta)
        else:
            xs = np.stack(
                [
                    extract_patch(req._padded, req._tiling.patches[idx], req._tiling.extent)
                    for req, idx in items
                ]
            )
            if S_run > len(items):
                xs = np.concatenate(
                    [xs, np.repeat(xs[-1:], S_run - len(items), axis=0)]
                )
            ys = ex.run_patch_batch(xs)
        completed: List[VolumeRequest] = []
        for (req, idx), y in zip(items, ys):
            ex.write_core(req.out, req._tiling, req._tiling.patches[idx], y)
            if finish_patch(req, req._tiling.patches[idx].start[0]):
                ex.end_sweep(req._sweep)  # free boundary spectra + halos
                completed.append(req)
        if completed:
            # one identity-keyed removal pass AFTER the write loop — the
            # old per-completion rebuild of ``self.active`` mutated the
            # list mid-iteration of this very loop's item source
            gone = {id(r) for r in completed}
            self.active = [r for r in self.active if id(r) not in gone]
            self.finished.extend(completed)
        self.ticks += 1
        ex.last_stats["retraces"] = len(ex._trace_keys)
        # lifetime peak across all sweeps served so far (the shared budget
        # the scheduler defends)
        ex.last_stats["peak_device_bytes"] = ex._ledger.peak
        return len(items)

    def run_until_drained(self, max_ticks: int = 100_000) -> List[VolumeRequest]:
        for _ in range(max_ticks):
            if self.step() == 0:
                break
        return self.finished
