"""The one bias-broadcast rule shared by every conv primitive.

Each primitive used to inline its own ``b.reshape(1, fp, 1, 1, 1)``, with
``fp`` read from a different tensor per path (``w.shape[0]``,
``W.shape[0]``, the post-crop output) — so a bias of the wrong shape could
fail on one primitive and silently broadcast on another, and the registry
``apply`` and the one-shot ``conv_apply`` path could disagree.  All paths
now add bias through :func:`add_channel_bias`, which validates against the
*output* tensor (the one shape every path agrees on) and broadcasts from
the right so any number of leading batch axes works.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def add_channel_bias(o: jnp.ndarray, b: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Add per-output-channel bias to o (..., f', x, y, z).

    ``b`` may be None (no-op), a scalar (uniform shift), or a 1-D vector of
    length f' == o.shape[-4].  Anything else is rejected loudly instead of
    broadcasting differently per primitive.
    """
    if b is None:
        return o
    b = jnp.asarray(b)
    if b.ndim == 0:
        return o + b
    if b.ndim == 1:
        fp = o.shape[-4]
        if b.shape[0] != fp:
            raise ValueError(
                f"bias has {b.shape[0]} channels, output has {fp}"
            )
        return o + b.reshape((fp, 1, 1, 1))
    raise ValueError(f"bias must be None, scalar, or (f',); got shape {b.shape}")
