"""Distributed sliding-window inference — the paper's outer loop at pod scale.

ZNNi §II: "the input image is divided into smaller input patches ...
assigned to multiple workers", with patches overlapping by FOV-1 so outputs
tile exactly.  Two realizations:

* ``patchwise``: the faithful strategy — each chip gets an independent
  overlapping patch (overlap voxels are *recomputed* on both sides, the
  paper's border waste).  Implemented as vmap/shard over pre-extracted
  patches.

* ``halo_sharded`` (beyond paper): the volume is sharded over chips along
  x; before each conv layer, each chip exchanges a (k-1)-deep halo with its
  axis neighbours via ``ppermute`` instead of recomputing the overlap.
  Border waste becomes ICI bytes (surface × depth), which the roofline
  shows is far cheaper than the recompute for large patches.

Both produce outputs identical to the single-worker run (tests assert it).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ConvNetConfig
from .convnet import apply_plan


# ---------------------------------------------------------------------------
# Patch bookkeeping (overlap-save)
# ---------------------------------------------------------------------------


def patch_grid(
    vol_shape: Tuple[int, int, int], net: ConvNetConfig, m: int, workers_x: int
) -> List[Tuple[int, int]]:
    """Start offsets (x-axis split) of overlapping patches of core size
    m·P (dense voxels) + FOV-1 overlap.  1D split for clarity; y/z splits
    compose identically."""
    n_in = net.valid_input_size(m)
    core = net.output_size(n_in) * net.total_pooling()
    starts = [i * core for i in range(workers_x)]
    return [(s, n_in) for s in starts]


def extract_patches(vol: jnp.ndarray, starts_sizes: Sequence[Tuple[int, int]]) -> jnp.ndarray:
    """vol (f, X, Y, Z) -> (W, f, n_in, Y, Z) overlapping x-patches."""
    return jnp.stack(
        [lax.dynamic_slice_in_dim(vol, s, n, axis=1) for s, n in starts_sizes]
    )


def patchwise_infer(
    params, net: ConvNetConfig, vol: jnp.ndarray, prims: Sequence[str], m: int, workers: int
) -> jnp.ndarray:
    """Faithful §II strategy: independent overlapping patches along x.

    vol (f, X, Y, Z) where X = workers·core + FOV-1 and (Y, Z) already
    valid patch extents.  Returns the dense output (out_ch, workers·core·…).
    """
    grid = patch_grid(vol.shape[1:], net, m, workers)
    patches = extract_patches(vol, grid)  # (W, f, n_in, Y, Z)
    outs = jax.vmap(lambda p: apply_plan(params, net, p[None], prims))(patches)
    # outs (W, 1, out_ch, cx, cy, cz) -> concat along x
    outs = outs[:, 0]
    return jnp.concatenate([o for o in outs], axis=1)


# ---------------------------------------------------------------------------
# Halo exchange (beyond paper)
# ---------------------------------------------------------------------------


def halo_exchange_x(x: jnp.ndarray, halo: int, axis_name: str) -> jnp.ndarray:
    """Append the next x-neighbour's first `halo` x-planes to our shard.

    x (S, f, nx, ny, nz) local shard; returns (S, f, nx+halo, ny, nz).
    Chips are a 1D ring along `axis_name`; the last chip pads with zeros
    (its halo region is outside the volume; callers arrange sizes so the
    padded tail is never part of a valid output).
    """
    if halo == 0:
        return x
    if halo > x.shape[2]:
        # a single-hop exchange can only supply up to one shard extent of
        # halo; deeper halos need either a larger per-shard patch (bigger m)
        # or multi-hop exchange (not implemented).
        raise ValueError(
            f"halo depth {halo} exceeds local x extent {x.shape[2]}; "
            "increase the per-shard fragment size m"
        )
    n = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    head = x[:, :, :halo]
    perm = [(i, (i - 1) % n) for i in range(n)]  # send head to left neighbour
    recv = lax.ppermute(head, axis_name, perm)
    recv = jnp.where(idx == n - 1, jnp.zeros_like(recv), recv)
    return jnp.concatenate([x, recv], axis=2)


def halo_sharded_apply(
    params,
    net: ConvNetConfig,
    x_local: jnp.ndarray,
    prims: Sequence[str],
    *,
    axis_name: str,
) -> jnp.ndarray:
    """Run the net on an x-sharded volume with per-conv halo exchange.

    Inside shard_map.  x_local (S, f, nx_local, ny, nz); every chip's
    nx_local must satisfy the same layer-validity constraints (the planner
    guarantees it by construction of m).  Pool layers consume exact
    multiples so no halo is needed there when nx_local ≡ per-chip fragments.
    """
    from .mpf import max_pool3d, mpf, recombine_fragments
    from .primitives import conv_apply

    S = x_local.shape[0]
    pools: List[int] = []
    last_conv = max(i for i, l in enumerate(net.layers) if l.kind == "conv")

    for i, layer in enumerate(net.layers):
        if layer.kind == "conv":
            w, b = params[i]
            x_local = halo_exchange_x(x_local, layer.size - 1, axis_name)
            x_local = conv_apply(prims[i], x_local, w, b)
            if i != last_conv:
                x_local = jax.nn.relu(x_local)
        else:
            if prims[i] == "mpf":
                # fragment-count bookkeeping needs (n+1)%p==0 *globally*;
                # locally each shard pools its exact multiple then the
                # boundary column is exchanged.
                x_local = halo_exchange_x(x_local, layer.size - 1, axis_name)
                x_local = mpf(x_local, layer.size)
                pools.append(layer.size)
            else:
                x_local = max_pool3d(x_local, layer.size)
    if pools:
        x_local = recombine_fragments(x_local, pools, S)
    return x_local
