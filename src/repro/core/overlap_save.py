"""Overlap-save FFT convolution with reusable input segment spectra.

ZNNi's FFT primitives transform each patch's *entire* input, even though
adjacent patches overlap by FOV-1 voxels — the overlap region's spectra are
recomputed for every patch (the paper's border waste, §II, paid again in
the transform).  Overlap-save is the classical fix: segment the input along
the sweep axis into windows of ``seg_core + k - 1`` voxels stepping by
``seg_core``, transform each window with a *small* pruned FFT (shape sized
to the segment, not the patch), multiply with the cached kernel spectra,
inverse-transform, and keep each window's ``seg_core`` valid outputs.

Two wins, both on the memory side the paper says decides FFT dominance:

* the per-segment FFT shape is ``seg_core + k - 1`` instead of the full
  patch extent ``core + FOV - 1`` — spectra live memory shrinks by about
  the same ratio, so larger patches fit the budget (less border waste);
* segments are addressed by *absolute* input coordinates, so the windows
  an adjacent patch shares (the FOV halo) have identical spectra — the
  volume executor caches them across patches within a sweep and only
  transforms each aligned segment once (``volume/executor.py``).

The segmentation is fixed at setup time (``plan_overlap_save``) and carried
on the prepared layer as a frozen ``OverlapSaveSpec`` — a static jit
argument, like the pruned-FFT shape of the other FFT primitives.

Correctness: a circular transform of size >= seg_extent has no wrap-around
for output offsets [0, seg_core) of the window (same argument as
``pruned_fft.fft_correlate_valid``), and a trailing segment shifted flush
to the input end recomputes outputs the previous segment already produced
— value-identical, so the overlapping write is exact (the same shifted-
edge-patch argument as ``volume/tiler.py``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.cmul_mad import ops as cmul_ops
from ..kernels.dispatch import resolve_use_pallas
from ..kernels.os_segment import ops as seg_ops
from .bias import add_channel_bias
from .pruned_fft import fft_optimal_shape, pruned_irfftn, pruned_rfftn


@dataclass(frozen=True)
class OverlapSaveSpec:
    """Static overlap-save segmentation for one conv layer.

    The segment grid is *aligned*: segment j produces outputs
    ``[j·seg_core, (j+1)·seg_core)`` from inputs
    ``[j·seg_core, j·seg_core + seg_extent)``.  The last segment's input
    window may extend up to ``input_pad`` voxels past ``n`` — the volume
    executor reads those voxels from the padded volume (the x-neighbour's
    data, which is exactly what makes the grid patch-independent and its
    spectra shareable); the self-contained path zero-pads instead, which is
    exact because outputs past ``out`` are cropped (``tail_len``) and
    output v only reads input [v, v+k).

    Frozen + tuple-valued so it is hashable: jitted appliers take it as a
    static argument, and ``functools.lru_cache`` memoizes planning.
    """

    n: Tuple[int, int, int]  # layer input extent
    k: Tuple[int, int, int]  # kernel extent
    out: Tuple[int, int, int]  # valid-conv output extent (n - k + 1)
    seg_core: int  # output voxels per segment along axis 0
    seg_extent: int  # input voxels per segment (= seg_core + k0 - 1)
    starts: Tuple[int, ...]  # aligned segment starts (input == output)
    tail_len: int  # valid outputs of the last segment (<= seg_core)
    input_pad: int  # axis-0 voxels the grid reads past n
    fft_shape: Tuple[int, int, int]  # per-segment pruned-FFT shape

    @property
    def n_segments(self) -> int:
        return len(self.starts)

    @property
    def span(self) -> int:
        """Axis-0 input voxels the whole grid reads (= n + input_pad)."""
        return self.starts[-1] + self.seg_extent


@functools.lru_cache(maxsize=None)
def plan_overlap_save(
    n: Tuple[int, int, int],
    k: Tuple[int, int, int],
    seg_core: Optional[int] = None,
) -> OverlapSaveSpec:
    """Choose the segment grid for input ``n`` and kernel ``k``.

    ``seg_core`` is the output voxels per segment along axis 0; the volume
    executor passes the plan's patch core so the layer-0 segment grid of
    adjacent patches lands on the same absolute coordinates (cache hits).
    Callers without a grid to align to get a small default (short segments
    amortize best but pay more MAD overhead per voxel).  ``seg_core`` is
    clamped to the output extent, so undersized inputs degrade to a single
    segment.
    """
    n = tuple(int(s) for s in n)
    k = tuple(int(s) for s in k)
    out = tuple(x - ki + 1 for x, ki in zip(n, k))
    if min(out) < 1:
        raise ValueError(f"kernel {k} larger than input {n}")
    n_out = out[0]
    if seg_core is None:
        seg_core = max(2 * (k[0] - 1), 4)
    seg_core = max(1, min(int(seg_core), n_out))
    n_seg = -(-n_out // seg_core)
    starts = tuple(j * seg_core for j in range(n_seg))
    tail_len = n_out - (n_seg - 1) * seg_core
    seg_extent = seg_core + k[0] - 1
    input_pad = starts[-1] + seg_extent - n[0]
    fft_shape = fft_optimal_shape((seg_extent, n[1], n[2]))
    return OverlapSaveSpec(
        n, k, out, seg_core, seg_extent, starts, tail_len, input_pad, fft_shape
    )


# ---------------------------------------------------------------------------
# The segmented transform - multiply - accumulate - inverse pipeline
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("spec",))
def segment_spectrum(seg: jnp.ndarray, spec: OverlapSaveSpec) -> jnp.ndarray:
    """Pruned rfftn of input segments (..., f, seg_extent, ny, nz)."""
    return pruned_rfftn(seg, spec.fft_shape)


def slice_segment_spectra(
    vol: jnp.ndarray,
    starts: jnp.ndarray,
    spec: OverlapSaveSpec,
    extent: int,
) -> jnp.ndarray:
    """Slice + transform segments of a device-resident volume (traceable).

    ``vol`` (f, X', Y', Z') is the padded volume (pre-extended so every
    slice is in bounds); ``starts`` (M, 3) are absolute (x, y, z) segment
    origins.  Returns (M, f, ña, ñb, ñc).  The executor's unit of
    cross-patch reuse: each sweep-cache miss passes through here exactly
    once (tests count the segments to assert the reuse actually happens),
    and keeping slice + FFT on device means a miss costs no host copies.
    """
    def one(st):
        seg = jax.lax.dynamic_slice(
            vol, (0, st[0], st[1], st[2]),
            (vol.shape[0], spec.seg_extent, extent, extent),
        )
        return pruned_rfftn(seg, spec.fft_shape)

    return jax.vmap(one)(starts)


segment_spectra_at = jax.jit(
    slice_segment_spectra, static_argnames=("spec", "extent")
)


def os_input_spectra(x: jnp.ndarray, spec: OverlapSaveSpec) -> jnp.ndarray:
    """All segment spectra of ``x`` (..., f, nx, ny, nz).

    Returns (..., n_seg, f, na, nb, nc//2+1) — the segment axis is inserted
    in front of the channel axis so batched and unbatched inputs stack the
    same way.  The tail segment's out-of-range voxels are zero-padded;
    exact, because its outputs past ``spec.out`` are cropped at reassembly.
    """
    if spec.input_pad:
        pad = [(0, 0)] * (x.ndim - 3) + [(0, spec.input_pad), (0, 0), (0, 0)]
        x = jnp.pad(x, pad)
    segs = jnp.stack(
        [x[..., st : st + spec.seg_extent, :, :] for st in spec.starts],
        axis=x.ndim - 4,
    )
    return segment_spectrum(segs, spec)  # leading dims pass through rfftn


def _mad_inverse_segment(
    Fj: jnp.ndarray,
    W: jnp.ndarray,
    spec: OverlapSaveSpec,
    crop: Tuple[int, ...],
    use_pallas: Optional[bool],
    fprime_chunk: Optional[int],
) -> jnp.ndarray:
    """One segment's MAD + pruned inverse, optionally f'-chunked.

    Chunking the OUTPUT channels bounds the live output-spectra column to
    ``fprime_chunk`` channels (the same staged-memory knob as
    ``fft_conv._chunked_mad_inverse``); each channel's reduction is
    untouched, so the result is value-identical to the unchunked form.
    """
    fp = W.shape[0]
    if not fprime_chunk or int(fprime_chunk) >= fp:
        O = cmul_ops.cmul_mad(Fj, W, use_pallas=use_pallas)
        return pruned_irfftn(O, spec.fft_shape, (0, 0, 0), crop)
    fc = int(fprime_chunk)
    parts = []
    for i in range(0, fp, fc):
        O = cmul_ops.cmul_mad(Fj, W[i : i + fc], use_pallas=use_pallas)
        parts.append(pruned_irfftn(O, spec.fft_shape, (0, 0, 0), crop))
    return jnp.concatenate(parts, axis=1)


def os_apply_from_spectra(
    F: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec: OverlapSaveSpec,
    *,
    use_pallas: Optional[bool] = None,
    fprime_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """MAD + inverse + reassembly from precomputed input segment spectra.

    F (S, n_seg, f, na, nb, nc''), W (f', f, na, nb, nc'') cached conjugate
    kernel spectra (``fft_conv.precompute_kernel_fft`` at the segment FFT
    shape) -> (S, f', *spec.out).  The MAD + inverse run as an unrolled
    per-segment chain: each segment's output spectra are consumed by its
    own inverse transform, so XLA's in-order scheduling and buffer
    liveness keep ~ONE output-spectra column live at a time — the paper's
    staged-memory discipline, the same graph-staging argument
    ``fft_conv``'s module docstring records, and what
    ``cost_model.conv_overlap_save_cost`` charges to peak (a scheduler
    that overlapped segments could hold more; see the cost docstring's
    known approximations).  The input segment spectra F are all live by
    design: they are the executor's reuse currency.

    When the Pallas path is on (``kernels.resolve_use_pallas``), the whole
    per-segment chain runs as ONE fused kernel over the segment grid
    (``kernels.os_segment``) — MAD, DC-bin bias, inverse, and crop never
    leave VMEM; ``fprime_chunk`` becomes the kernel's output-channel block.
    """
    if resolve_use_pallas(use_pallas):
        return seg_ops.os_segment_fused(
            F, W, b, spec, fprime_chunk=fprime_chunk, use_pallas=True
        )
    n_seg = F.shape[1]
    s = spec.seg_core
    crop = (s,) + spec.out[1:]
    # Per-segment MAD -> inverse -> crop, unrolled: each segment's output
    # spectra are consumed by its own inverse transform before the next
    # segment's MAD runs, so buffer liveness keeps ONE output-spectra
    # column live at a time (the same staged-memory argument as
    # ``fft_conv_data_parallel``'s output-channel chunking; what the crop
    # keeps is the small spatial core).
    parts = []
    for j in range(n_seg):
        seg = _mad_inverse_segment(F[:, j], W, spec, crop, use_pallas, fprime_chunk)
        # aligned grid: segment j owns outputs [j·s, (j+1)·s); the tail's
        # outputs past the true extent came from padding and are dropped.
        parts.append(seg if j < n_seg - 1 else seg[:, :, : spec.tail_len])
    return add_channel_bias(jnp.concatenate(parts, axis=2), b)


def tail_segments(spec: OverlapSaveSpec, out_cols: int) -> int:
    """How many TRAILING segments cover the last ``out_cols`` output columns.

    The volume executor's deep-reuse path runs MAD + inverse only on these
    segments for an interior patch (its leading output columns are served
    from the activation cache), and ``cost_model.conv_overlap_save_cost``
    prices exactly this count under a deep-reuse ``PlanGeometry``.
    """
    if out_cols >= spec.out[0]:
        return spec.n_segments
    j0 = (spec.out[0] - out_cols) // spec.seg_core
    return spec.n_segments - min(j0, spec.n_segments - 1)


def os_apply_tail_from_spectra(
    F: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec: OverlapSaveSpec,
    out_cols: int,
    *,
    use_pallas: Optional[bool] = None,
    fprime_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """MAD + inverse + reassembly of the TRAILING ``out_cols`` output columns.

    F (S, q, f, na, nb, nc'') holds spectra of the last
    ``q = tail_segments(spec, out_cols)`` segments only (same order as
    ``spec.starts[-q:]``); returns (S, f', out_cols, *spec.out[1:]).  The
    executor's strip path uses this for interior patches: their leading
    output columns are assembled from the deep activation cache, so only
    the trailing segments' MAD + inverse work is paid per patch.  The
    Pallas path runs the same fused segment kernel as
    ``os_apply_from_spectra`` with the lead crop folded in.
    """
    if resolve_use_pallas(use_pallas):
        return seg_ops.os_segment_fused_tail(
            F, W, b, spec, out_cols, fprime_chunk=fprime_chunk, use_pallas=True
        )
    n_seg = spec.n_segments
    q = tail_segments(spec, out_cols)
    j0 = n_seg - q
    s = spec.seg_core
    crop = (s,) + spec.out[1:]
    parts = []
    for jj in range(q):
        j = j0 + jj
        seg = _mad_inverse_segment(F[:, jj], W, spec, crop, use_pallas, fprime_chunk)
        parts.append(seg if j < n_seg - 1 else seg[:, :, : spec.tail_len])
    x = jnp.concatenate(parts, axis=2)
    lead = (spec.out[0] - out_cols) - j0 * s
    if lead > 0:
        x = x[:, :, lead:]
    return add_channel_bias(x, b)


def overlap_save_conv(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec: OverlapSaveSpec,
    *,
    use_pallas: Optional[bool] = None,
    fprime_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Self-contained segmented 'valid' cross-correlation (no spectra reuse).

    The registry ``apply`` for layers the executor cannot amortize (deeper
    layers, one-shot ``conv_apply`` callers, the plain-pool subsampling
    sweep).  x (S, f, *spec.n) -> (S, f', *spec.out).  On the Pallas path
    the miss-segment FFT itself moves into the fused kernel
    (``os_segment_conv``: forward matmul DFT + MAD + bias + inverse in one
    ``pallas_call`` over the segment grid).
    """
    if resolve_use_pallas(use_pallas):
        return seg_ops.os_segment_conv(
            x, W, b, spec, fprime_chunk=fprime_chunk, use_pallas=True
        )
    return os_apply_from_spectra(
        os_input_spectra(x, spec), W, b, spec,
        use_pallas=use_pallas, fprime_chunk=fprime_chunk,
    )


def shared_segments(spec: OverlapSaveSpec, core: int) -> int:
    """How many segments two x-adjacent patches (stride ``core``) share.

    A segment at relative start r of patch x0 coincides with a segment of
    patch x0+core iff r - core is also a relative start.  This is the
    amortization the cost model prices and the executor cache realizes.
    """
    s = set(spec.starts)
    return sum(1 for r in spec.starts if r - core in s)


def new_segments(spec: OverlapSaveSpec, core: int) -> int:
    """Segments an x-interior patch must transform itself (grid minus the
    segments its left neighbour at stride ``core`` already owns)."""
    return spec.n_segments - shared_segments(spec, core)
