"""FFT-based convolutional layer primitives (ZNNi §IV, Algorithms 2–3).

Layout: images I (S, f, nx, ny, nz) f32, kernels w (f', f, kx, ky, kz), bias
(f',).  Output (S, f', n'x, n'y, n'z) with n' = n - k + 1 ('valid').

Two variants, mirroring the paper's CPU algorithms:

* ``data_parallel``  (Algorithm 2): all image FFTs up front, then for each
  output channel j: transform the f kernels, multiply-accumulate across
  input channels, inverse-transform.  Peak live spectra: all S*f input
  spectra + one output-channel column.  Parallelism lives *inside* each
  transform / MAD (on TPU: the XLA ops themselves are data-parallel).

* ``task_parallel``  (Algorithm task-graph, Fig. 3): the (f', f) kernel grid
  and the MADs are independent tasks.  On TPU the grid is materialized as a
  single batched einsum over all channels at once — the scheduler's "tasks"
  become the MXU/VPU grid of one fused contraction, and the paper's
  "primary thread owns one kernel-FFT buffer" becomes "all kernel spectra
  live at once".  Fastest, largest memory — the same trade the paper reports.

The pointwise multiply-accumulate is the hot spot: it is dispatched through
``repro.kernels.cmul_mad`` (Pallas kernel on TPU, einsum oracle elsewhere).

Staged memory discipline (the paper frees I before allocating O-spectra):
XLA's buffer liveness does this automatically once the graph is staged the
same way; the chunked `lax.map` in ``data_parallel`` bounds the live kernel
spectra exactly like the paper's sub-batched cuFFT calls.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.cmul_mad import ops as cmul_ops
from ..kernels.dispatch import resolve_use_pallas
from ..kernels.mpf_pool import ops as mpf_ops
from .bias import add_channel_bias
from .pruned_fft import (
    fft_optimal_shape,
    kernel_rfftn,
    pruned_irfftn,
    pruned_rfftn,
)


def _out_shape(n: Sequence[int], k: Sequence[int]) -> Tuple[int, ...]:
    return tuple(int(ni - ki + 1) for ni, ki in zip(n, k))


def precompute_kernel_fft(w: jnp.ndarray, fft_shape: Sequence[int]) -> jnp.ndarray:
    """Kernel spectra (f', f, na, nb, nc''), reusable across patches/batches.

    ZNNi reuses kernel transforms across the batch; a sliding-window service
    reuses them across *patches* — compute once per layer per FFT size.
    """
    return kernel_rfftn(w, fft_shape)


@partial(jax.jit, static_argnames=("fft_shape", "use_pallas", "fprime_chunk"))
def fft_conv_data_parallel(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    fft_shape: Optional[Tuple[int, int, int]] = None,
    use_pallas: Optional[bool] = None,
    fprime_chunk: int = 8,
) -> jnp.ndarray:
    """Algorithm 2: image FFTs up front; loop over output-channel chunks."""
    S, f = x.shape[:2]
    fp = w.shape[0]
    n, k = x.shape[2:], w.shape[2:]
    if fft_shape is None:
        fft_shape = fft_optimal_shape(n)
    out = _out_shape(n, k)

    X = pruned_rfftn(x, fft_shape)  # (S, f, na, nb, nc'')

    # chunk output channels like the paper's sub-batched cuFFT calls: bounds
    # live kernel spectra to (chunk, f, ñ).
    fprime_chunk = min(fprime_chunk, fp)
    pad_fp = (-fp) % fprime_chunk
    w_p = jnp.pad(w, ((0, pad_fp), (0, 0), (0, 0), (0, 0), (0, 0)))
    w_chunks = w_p.reshape((fp + pad_fp) // fprime_chunk, fprime_chunk, *w.shape[1:])

    def one_chunk(wc):
        Wc = kernel_rfftn(wc, fft_shape)  # (chunk, f, ñ)
        Oc = cmul_ops.cmul_mad(X, Wc, use_pallas=use_pallas)  # (S, chunk, ñ)
        return pruned_irfftn(Oc, fft_shape, (0, 0, 0), out)

    o = jax.lax.map(one_chunk, w_chunks)  # (n_chunk, S, chunk, out)
    o = jnp.moveaxis(o, 1, 0).reshape(S, fp + pad_fp, *out)[:, :fp]
    return add_channel_bias(o, b)


@partial(jax.jit, static_argnames=("fft_shape", "use_pallas"))
def fft_conv_task_parallel(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    fft_shape: Optional[Tuple[int, int, int]] = None,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """Task-graph variant: all kernel spectra at once, one fused MAD.

    Requires f*S and f'*S large to pay off (paper §IV-A3) — here that means
    the single einsum has enough parallel work to fill the chip; memory is
    the full (f', f, ñ) kernel-spectrum grid, exactly Table II's trade.
    """
    n, k = x.shape[2:], w.shape[2:]
    if fft_shape is None:
        fft_shape = fft_optimal_shape(n)
    out = _out_shape(n, k)

    X = pruned_rfftn(x, fft_shape)  # (S, f, ñ)
    W = precompute_kernel_fft(w, fft_shape)  # (f', f, ñ)
    O = cmul_ops.cmul_mad(X, W, use_pallas=use_pallas)  # (S, f', ñ)
    o = pruned_irfftn(O, fft_shape, (0, 0, 0), out)
    return add_channel_bias(o, b)


def _chunked_mad_inverse(X, W, fft_shape, crop, fprime_chunk, use_pallas, b=None):
    """MAD + inverse over output-channel chunks of the cached spectra ``W``.

    ``lax.map`` over chunks bounds live output spectra to one chunk column
    (the paper's sub-batched cuFFT discipline, now a *tunable*:
    ``fprime_chunk`` is swept by ``repro.tuning``).  When ``b`` is given the
    bias rides the DC bin of each chunk (the fused epilogue); chunk
    zero-padding of W and b is exact — padded channels are dropped.
    """
    S = X.shape[0]
    fp = W.shape[0]
    c = max(1, int(fprime_chunk))
    pad_fp = (-fp) % c
    W_p = jnp.pad(W, ((0, pad_fp),) + ((0, 0),) * (W.ndim - 1))
    W_chunks = W_p.reshape((fp + pad_fp) // c, c, *W.shape[1:])
    if b is None:
        def one_chunk(Wc):
            Oc = cmul_ops.cmul_mad(X, Wc, use_pallas=use_pallas)
            return pruned_irfftn(Oc, fft_shape, (0, 0, 0), crop)

        o = jax.lax.map(one_chunk, W_chunks)
    else:
        b_p = jnp.pad(b.astype(jnp.float32), (0, pad_fp))
        b_chunks = b_p.reshape((fp + pad_fp) // c, c)

        def one_chunk_bias(args):
            Wc, bc = args
            Oc = cmul_ops.cmul_mad_bias(
                X, Wc, bc, fft_shape=fft_shape, use_pallas=use_pallas
            )
            return pruned_irfftn(Oc, fft_shape, (0, 0, 0), crop)

        o = jax.lax.map(one_chunk_bias, (W_chunks, b_chunks))
    return jnp.moveaxis(o, 1, 0).reshape(S, fp + pad_fp, *crop)[:, :fp]


def fft_conv_with_precomputed(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    fft_shape: Tuple[int, int, int],
    k: Tuple[int, int, int],
    *,
    use_pallas: Optional[bool] = None,
    fprime_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Task-parallel forward with cached kernel spectra (inference service path).

    ``fprime_chunk`` (a tuned parameter; ``None`` = all output channels in
    one MAD) bounds live output spectra to a chunk column at the cost of a
    scan — the memory/speed knob ``repro.tuning`` sweeps per hardware.
    """
    n = x.shape[2:]
    out = _out_shape(n, k)
    X = pruned_rfftn(x, fft_shape)
    if fprime_chunk is not None and fprime_chunk < W.shape[0]:
        o = _chunked_mad_inverse(X, W, fft_shape, out, fprime_chunk, use_pallas)
        return add_channel_bias(o, b)
    O = cmul_ops.cmul_mad(X, W, use_pallas=use_pallas)
    o = pruned_irfftn(O, fft_shape, (0, 0, 0), out)
    return add_channel_bias(o, b)


@partial(
    jax.jit,
    static_argnames=("fft_shape", "k", "p", "use_pallas", "relu", "fprime_chunk"),
)
def fft_conv_pool_fused(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    *,
    fft_shape: Tuple[int, int, int],
    k: Tuple[int, int, int],
    p: int,
    use_pallas: Optional[bool] = None,
    relu: bool = True,
    fprime_chunk: Optional[int] = None,
) -> jnp.ndarray:
    """Fused conv + ReLU + MPF pair: the strip-path epilogue as two kernels.

    The unfused walk runs five ops: MAD -> inverse -> crop -> bias -> relu
    -> MPF.  Here the bias rides the MAD's DC bin (``cmul_mad_bias``), the
    inverse leaves the LAST axis uncropped and the windowed pool kernel
    (``mpf_pool_window``) folds that crop into its fragment slices, and
    ReLU moves *after* the pool — exact, because relu(max(a,b)) ==
    max(relu(a), relu(b)) (monotone), so relu work shrinks by ~p³/(p³-…)
    to the pooled extent.  Output: MPF fragment batch (S·p³, f', m³),
    identical (allclose) to the unfused sequence.
    """
    n = x.shape[2:]
    out = _out_shape(n, k)
    X = pruned_rfftn(x, fft_shape)
    # crop axes a,b during the inverse as usual; leave axis c at the full
    # transform length — mpf_pool_window never reads past ``out``.
    win = (out[0], out[1], int(fft_shape[2]))
    if fprime_chunk is not None and fprime_chunk < W.shape[0]:
        bias = jnp.zeros((W.shape[0],), jnp.float32) if b is None else b
        y = _chunked_mad_inverse(
            X, W, fft_shape, win, fprime_chunk, use_pallas, b=bias
        )
    else:
        O = cmul_ops.cmul_mad_bias(X, W, b, fft_shape=fft_shape, use_pallas=use_pallas)
        y = pruned_irfftn(O, fft_shape, (0, 0, 0), win)
    y = mpf_ops.mpf_pool_window(y, p, out, use_pallas=use_pallas)
    return jax.nn.relu(y) if relu else y


@partial(
    jax.jit,
    static_argnames=("fft_shape", "k", "p", "halo_cols", "use_pallas", "fprime_chunk"),
)
def fft_conv_pool_fused_halo(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    *,
    fft_shape: Tuple[int, int, int],
    k: Tuple[int, int, int],
    p: int,
    halo_cols: int,
    lead: Optional[jnp.ndarray] = None,
    use_pallas: Optional[bool] = None,
    fprime_chunk: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Halo-emitting fused conv + ReLU + MPF: ``(pooled, boundary_halo)``.

    The volume executor's halo-capturing and strip walks were unfused by
    design: deep reuse needs the *pool layer's input* materialized so its
    trailing x-columns can seed the next patch's activation cache.  This
    variant returns that boundary slab as a SECOND output — the pool input
    never becomes a separate executor-visible materialization step, so the
    fused pair can run inside the capture/strip walks.

    ``lead`` (strip path) is the cached activation halo prepended to the
    conv's ReLU output before pooling — the halo then comes from the
    *concatenated* tensor, exactly like the unfused strip walk.  ReLU is
    always applied (a pool follows, so the conv is never the net's last).

    Parity contract: off the Pallas path this runs literally the unfused
    op sequence (spatial bias conv, ReLU, concat, slice, MPF) — fused
    strip output and exported halos are BITWISE equal to the unfused walk
    (relu∘max == max∘relu exactly; the slice/concat move no arithmetic).
    On the Pallas path the conv collapses to the DC-bin-bias MAD kernel +
    pruned inverse (allclose, like ``fft_conv_pool_fused``).
    """
    if resolve_use_pallas(use_pallas):
        n = x.shape[2:]
        out = _out_shape(n, k)
        X = pruned_rfftn(x, fft_shape)
        if fprime_chunk is not None and fprime_chunk < W.shape[0]:
            bias = jnp.zeros((W.shape[0],), jnp.float32) if b is None else b
            y = _chunked_mad_inverse(
                X, W, fft_shape, out, fprime_chunk, use_pallas, b=bias
            )
        else:
            O = cmul_ops.cmul_mad_bias(
                X, W, b, fft_shape=fft_shape, use_pallas=use_pallas
            )
            y = pruned_irfftn(O, fft_shape, (0, 0, 0), out)
    else:
        y = fft_conv_with_precomputed(
            x, W, b, fft_shape, k, use_pallas=use_pallas, fprime_chunk=fprime_chunk
        )
    y = jax.nn.relu(y)
    if lead is not None:
        y = jnp.concatenate([lead, y], axis=2)
    halo = y[:, :, -int(halo_cols):]
    return mpf_ops.mpf_pool(y, p, use_pallas=use_pallas), halo
