"""FFT-based convolutional layer primitives (ZNNi §IV, Algorithms 2–3).

Layout: images I (S, f, nx, ny, nz) f32, kernels w (f', f, kx, ky, kz), bias
(f',).  Output (S, f', n'x, n'y, n'z) with n' = n - k + 1 ('valid').

Two variants, mirroring the paper's CPU algorithms:

* ``data_parallel``  (Algorithm 2): all image FFTs up front, then for each
  output channel j: transform the f kernels, multiply-accumulate across
  input channels, inverse-transform.  Peak live spectra: all S*f input
  spectra + one output-channel column.  Parallelism lives *inside* each
  transform / MAD (on TPU: the XLA ops themselves are data-parallel).

* ``task_parallel``  (Algorithm task-graph, Fig. 3): the (f', f) kernel grid
  and the MADs are independent tasks.  On TPU the grid is materialized as a
  single batched einsum over all channels at once — the scheduler's "tasks"
  become the MXU/VPU grid of one fused contraction, and the paper's
  "primary thread owns one kernel-FFT buffer" becomes "all kernel spectra
  live at once".  Fastest, largest memory — the same trade the paper reports.

The pointwise multiply-accumulate is the hot spot: it is dispatched through
``repro.kernels.cmul_mad`` (Pallas kernel on TPU, einsum oracle elsewhere).

Staged memory discipline (the paper frees I before allocating O-spectra):
XLA's buffer liveness does this automatically once the graph is staged the
same way; the chunked `lax.map` in ``data_parallel`` bounds the live kernel
spectra exactly like the paper's sub-batched cuFFT calls.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..kernels.cmul_mad import ops as cmul_ops
from .bias import add_channel_bias
from .pruned_fft import (
    fft_optimal_shape,
    kernel_rfftn,
    pruned_irfftn,
    pruned_rfftn,
)


def _out_shape(n: Sequence[int], k: Sequence[int]) -> Tuple[int, ...]:
    return tuple(int(ni - ki + 1) for ni, ki in zip(n, k))


def precompute_kernel_fft(w: jnp.ndarray, fft_shape: Sequence[int]) -> jnp.ndarray:
    """Kernel spectra (f', f, na, nb, nc''), reusable across patches/batches.

    ZNNi reuses kernel transforms across the batch; a sliding-window service
    reuses them across *patches* — compute once per layer per FFT size.
    """
    return kernel_rfftn(w, fft_shape)


@partial(jax.jit, static_argnames=("fft_shape", "use_pallas", "fprime_chunk"))
def fft_conv_data_parallel(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    fft_shape: Optional[Tuple[int, int, int]] = None,
    use_pallas: bool = False,
    fprime_chunk: int = 8,
) -> jnp.ndarray:
    """Algorithm 2: image FFTs up front; loop over output-channel chunks."""
    S, f = x.shape[:2]
    fp = w.shape[0]
    n, k = x.shape[2:], w.shape[2:]
    if fft_shape is None:
        fft_shape = fft_optimal_shape(n)
    out = _out_shape(n, k)

    X = pruned_rfftn(x, fft_shape)  # (S, f, na, nb, nc'')

    # chunk output channels like the paper's sub-batched cuFFT calls: bounds
    # live kernel spectra to (chunk, f, ñ).
    fprime_chunk = min(fprime_chunk, fp)
    pad_fp = (-fp) % fprime_chunk
    w_p = jnp.pad(w, ((0, pad_fp), (0, 0), (0, 0), (0, 0), (0, 0)))
    w_chunks = w_p.reshape((fp + pad_fp) // fprime_chunk, fprime_chunk, *w.shape[1:])

    def one_chunk(wc):
        Wc = kernel_rfftn(wc, fft_shape)  # (chunk, f, ñ)
        Oc = cmul_ops.cmul_mad(X, Wc, use_pallas=use_pallas)  # (S, chunk, ñ)
        return pruned_irfftn(Oc, fft_shape, (0, 0, 0), out)

    o = jax.lax.map(one_chunk, w_chunks)  # (n_chunk, S, chunk, out)
    o = jnp.moveaxis(o, 1, 0).reshape(S, fp + pad_fp, *out)[:, :fp]
    return add_channel_bias(o, b)


@partial(jax.jit, static_argnames=("fft_shape", "use_pallas"))
def fft_conv_task_parallel(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    fft_shape: Optional[Tuple[int, int, int]] = None,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Task-graph variant: all kernel spectra at once, one fused MAD.

    Requires f*S and f'*S large to pay off (paper §IV-A3) — here that means
    the single einsum has enough parallel work to fill the chip; memory is
    the full (f', f, ñ) kernel-spectrum grid, exactly Table II's trade.
    """
    n, k = x.shape[2:], w.shape[2:]
    if fft_shape is None:
        fft_shape = fft_optimal_shape(n)
    out = _out_shape(n, k)

    X = pruned_rfftn(x, fft_shape)  # (S, f, ñ)
    W = precompute_kernel_fft(w, fft_shape)  # (f', f, ñ)
    O = cmul_ops.cmul_mad(X, W, use_pallas=use_pallas)  # (S, f', ñ)
    o = pruned_irfftn(O, fft_shape, (0, 0, 0), out)
    return add_channel_bias(o, b)


def fft_conv_with_precomputed(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    fft_shape: Tuple[int, int, int],
    k: Tuple[int, int, int],
    *,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Task-parallel forward with cached kernel spectra (inference service path)."""
    n = x.shape[2:]
    out = _out_shape(n, k)
    X = pruned_rfftn(x, fft_shape)
    O = cmul_ops.cmul_mad(X, W, use_pallas=use_pallas)
    o = pruned_irfftn(O, fft_shape, (0, 0, 0), out)
    return add_channel_bias(o, b)
