"""Sub-layer decomposition — ZNNi's "GPU + host RAM" layer (§VII-A, Fig. 6).

The paper splits one convolutional layer's (S, f, f') work grid into
sub-layers sized to fit the GPU's on-board RAM, streaming inputs/outputs
over PCIe.  TPU adaptation (DESIGN.md §3): the scarce memory is per-chip
HBM (more precisely, the live-buffer budget inside one step), the backing
store is the *mesh's aggregate HBM* (weights and spectra sharded across
chips), and the slow link is ICI.

Two single-program building blocks (semantics only depend on chunking, so
they are testable on one device) plus the distributed variant:

* ``streamed_conv_out_channels``  — Fig. 6's f'-split: lax.map over output-
  channel chunks; peak live spectra ∝ chunk instead of f'.
* ``streamed_conv_batch``         — the S-split the paper prefers when S>1
  ("each input transferred exactly once").
* ``gathered_conv``               — weights arrive sharded over the mesh
  axis; each chunk is all-gathered (ICI) and processed while the next
  gather is in flight (double buffering falls out of XLA's async
  collectives once the loop is staged; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from .primitives import conv_apply


def _conv(variant: str, x, w, b, use_pallas: bool):
    # registry lookup ("fft" is an alias for fft_task); setup is inlined
    # because the streamed variants re-chunk weights on every call.
    return conv_apply(variant, x, w, b, use_pallas=use_pallas)


@partial(jax.jit, static_argnames=("chunk", "variant", "use_pallas"))
def streamed_conv_out_channels(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    chunk: int,
    variant: str = "fft",
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Split f' into chunks (paper Fig. 6 with S_i=S, f_i=f, f'_i=chunk)."""
    fp = w.shape[0]
    pad = (-fp) % chunk
    w_p = jnp.pad(w, ((0, pad),) + ((0, 0),) * (w.ndim - 1))
    b_p = jnp.pad(b, (0, pad)) if b is not None else None
    wc = w_p.reshape(-1, chunk, *w.shape[1:])
    bc = b_p.reshape(-1, chunk) if b_p is not None else None

    def body(args):
        wi, bi = args
        return _conv(variant, x, wi, bi, use_pallas)

    o = lax.map(body, (wc, bc if bc is not None else jnp.zeros((wc.shape[0], chunk), x.dtype)))
    o = jnp.moveaxis(o, 1, 0).reshape(x.shape[0], fp + pad, *o.shape[3:])
    return o[:, :fp]


@partial(jax.jit, static_argnames=("chunk", "variant", "use_pallas"))
def streamed_conv_batch(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    chunk: int,
    variant: str = "fft",
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Split S into sub-batches (paper's preferred split when S > 1)."""
    S = x.shape[0]
    if S % chunk:
        raise ValueError(f"batch {S} not divisible by sub-batch {chunk}")
    xc = x.reshape(S // chunk, chunk, *x.shape[1:])
    o = lax.map(lambda xi: _conv(variant, xi, w, b, use_pallas), xc)
    return o.reshape(S, *o.shape[2:])


def gathered_conv(
    x: jnp.ndarray,
    w_shard: jnp.ndarray,
    b_shard: Optional[jnp.ndarray],
    *,
    axis_name: str,
    variant: str = "fft",
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Inside shard_map: w_shard (f'/A, f, k³) is this chip's slice of the
    weights along f'.  Each chip computes its output-channel slice locally
    (no gather needed for the compute), then the slices are all-gathered so
    every chip holds the full (S, f', n'³) output — the paper's "results
    transferred back to host exactly once".

    Total ICI bytes: the output tensor once around the axis — the analogue
    of Fig. 6's green arrows.
    """
    o_local = _conv(variant, x, w_shard, b_shard, use_pallas)  # (S, f'/A, n'³)
    return lax.all_gather(o_local, axis_name, axis=1, tiled=True)
