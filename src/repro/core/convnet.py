"""ConvNet assembly: run a ZNNi net under a planner Plan (ZNNi §VI).

Three executors:

* ``apply_plan``           — run the net with the per-layer primitives a
                             Plan chose (MPF fragments multiply the batch).
* ``apply_dense_reference``— the dense sliding-window oracle: dilated convs
                             + dilated max filters ("max filtering" /
                             "strided kernels" — the semantics MPF must
                             reproduce).  Only feasible for tiny inputs.
* ``init_params``          — He-initialized weights/biases.

ReLU after every conv except the last (paper §VI-B: "rectified linear
transfer function applied after each convolutional layer"; the final layer
feeds the loss/decision and is kept linear here).
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ConvNetConfig
from .direct_conv import direct_conv
from .fft_conv import fft_conv_data_parallel, fft_conv_task_parallel
from .mpf import max_pool3d, mpf, recombine_fragments
from .planner import Plan


def init_params(key, net: ConvNetConfig, dtype=jnp.float32) -> List:
    params = []
    f = net.in_channels
    for layer in net.layers:
        if layer.kind == "conv":
            key, kw = jax.random.split(key)
            fan_in = f * layer.size**3
            w = jax.random.normal(
                kw, (layer.out_channels, f, layer.size, layer.size, layer.size), dtype
            ) * np.sqrt(2.0 / fan_in)
            b = jnp.zeros((layer.out_channels,), dtype)
            params.append((w, b))
            f = layer.out_channels
        else:
            params.append(None)
    return params


def _conv_prim(prim: str, x, w, b, use_pallas: bool):
    if prim == "direct":
        return direct_conv(x, w, b, use_pallas=use_pallas)
    if prim == "fft_data":
        return fft_conv_data_parallel(x, w, b, use_pallas=use_pallas)
    if prim in ("fft_task", "fft_cached"):
        return fft_conv_task_parallel(x, w, b, use_pallas=use_pallas)
    raise ValueError(prim)


def plan_pools(net: ConvNetConfig, plan_prims: Sequence[str]) -> List[int]:
    """MPF pool sizes in network order for a primitive assignment."""
    return [
        net.layers[i].size
        for i, prim in enumerate(plan_prims)
        if net.layers[i].kind == "pool" and prim == "mpf"
    ]


def apply_layer_range(
    params,
    net: ConvNetConfig,
    x: jnp.ndarray,
    plan_prims: Sequence[str],
    lo: int = 0,
    hi: Optional[int] = None,
    *,
    use_pallas: bool = False,
) -> jnp.ndarray:
    """Run layers [lo, hi) with the plan's primitives, *without* recombining.

    The building block for staged execution (pipeline2 splits the net at θ
    into two such ranges).  ReLU placement follows the whole-net rule (no
    activation after the net's final conv), so chaining ranges composes to
    ``apply_plan(..., recombine=False)``.
    """
    if hi is None:
        hi = len(net.layers)
    last_conv = max(i for i, l in enumerate(net.layers) if l.kind == "conv")
    for i in range(lo, hi):
        layer = net.layers[i]
        prim = plan_prims[i]
        if layer.kind == "conv":
            w, b = params[i]
            x = _conv_prim(prim, x, w, b, use_pallas)
            if i != last_conv:
                x = jax.nn.relu(x)
        else:
            if prim == "mpf":
                x = mpf(x, layer.size, use_pallas=use_pallas)
            elif prim == "pool":
                x = max_pool3d(x, layer.size)
            else:
                raise ValueError(prim)
    return x


def apply_plan(
    params,
    net: ConvNetConfig,
    x: jnp.ndarray,
    plan_prims: Sequence[str],
    *,
    use_pallas: bool = False,
    recombine: bool = True,
) -> jnp.ndarray:
    """Run the net; plan_prims[i] is the primitive name for layer i.

    x (S, in_ch, n³).  With MPF layers the batch grows by p³ each pool; if
    ``recombine``, fragments are folded back into the dense sliding-window
    output (S, out_ch, dense³).
    """
    S = x.shape[0]
    x = apply_layer_range(params, net, x, plan_prims, use_pallas=use_pallas)
    pools = plan_pools(net, plan_prims)
    if recombine and pools:
        x = recombine_fragments(x, pools, S)
    return x


def apply_with_plan(params, net: ConvNetConfig, x, plan: Plan, **kw):
    return apply_plan(params, net, x, [c.prim for c in plan.choices], **kw)


# ---------------------------------------------------------------------------
# Dense sliding-window oracle (dilated convolution semantics)
# ---------------------------------------------------------------------------


def _dilated_max_filter(x: jnp.ndarray, p: int, d: int) -> jnp.ndarray:
    """max over window of p taps spaced d apart, stride 1, per axis."""
    n = x.shape[-3:]
    out = tuple(ni - (p - 1) * d for ni in n)
    y = jnp.full(x.shape[:-3] + out, -jnp.inf, x.dtype)
    for ox, oy, oz in itertools.product(range(p), repeat=3):
        y = jnp.maximum(
            y,
            x[..., ox * d : ox * d + out[0], oy * d : oy * d + out[1], oz * d : oz * d + out[2]],
        )
    return y


def apply_dense_reference(params, net: ConvNetConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Dense sliding-window output via dilated convs/max filters (oracle)."""
    d = 1
    last_conv = max(i for i, l in enumerate(net.layers) if l.kind == "conv")
    for i, layer in enumerate(net.layers):
        if layer.kind == "conv":
            w, b = params[i]
            x = lax.conv_general_dilated(
                x.astype(jnp.float32),
                w.astype(jnp.float32),
                window_strides=(1, 1, 1),
                padding="VALID",
                rhs_dilation=(d, d, d),
                dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
            ) + b.reshape(1, -1, 1, 1, 1)
            if i != last_conv:
                x = jax.nn.relu(x)
        else:
            x = _dilated_max_filter(x, layer.size, d)
            d *= layer.size
    return x
