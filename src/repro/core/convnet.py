"""ConvNet assembly: run a ZNNi net under a planner Plan (ZNNi §VI).

Three executors:

* ``apply_plan``           — run the net with the per-layer primitives a
                             Plan chose (MPF fragments multiply the batch).
                             A thin walk over the ``core.primitives``
                             registry; long-lived executors should use
                             ``primitives.compile_plan`` to reuse per-layer
                             prepared state (cached kernel spectra) across
                             calls.
* ``apply_dense_reference``— the dense sliding-window oracle: dilated convs
                             + dilated max filters ("max filtering" /
                             "strided kernels" — the semantics MPF must
                             reproduce).  Only feasible for tiny inputs.
* ``init_params``          — He-initialized weights/biases.

ReLU after every conv except the last (paper §VI-B: "rectified linear
transfer function applied after each convolutional layer"; the final layer
feeds the loss/decision and is kept linear here).
"""

from __future__ import annotations

import itertools
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ConvNetConfig
from .mpf import recombine_fragments
from .planner import Plan
from .primitives import apply_prepared_range, prepare_layers


def init_params(key, net: ConvNetConfig, dtype=jnp.float32) -> List:
    params = []
    f = net.in_channels
    for layer in net.layers:
        if layer.kind == "conv":
            key, kw = jax.random.split(key)
            fan_in = f * layer.size**3
            w = jax.random.normal(
                kw, (layer.out_channels, f, layer.size, layer.size, layer.size), dtype
            ) * np.sqrt(2.0 / fan_in)
            b = jnp.zeros((layer.out_channels,), dtype)
            params.append((w, b))
            f = layer.out_channels
        else:
            params.append(None)
    return params


def plan_pools(net: ConvNetConfig, plan_prims: Sequence[str]) -> List[int]:
    """MPF pool sizes in network order for a primitive assignment."""
    return [
        net.layers[i].size
        for i, prim in enumerate(plan_prims)
        if net.layers[i].kind == "pool" and prim == "mpf"
    ]


def apply_layer_range(
    params,
    net: ConvNetConfig,
    x: jnp.ndarray,
    plan_prims: Sequence[str],
    lo: int = 0,
    hi: Optional[int] = None,
    *,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """Run layers [lo, hi) with the plan's primitives, *without* recombining.

    The building block for staged execution (pipeline2 splits the net at θ
    into two such ranges).  ReLU placement follows the whole-net rule (no
    activation after the net's final conv), so chaining ranges composes to
    ``apply_plan(..., recombine=False)``.

    A thin walk over the ``core.primitives`` registry: each layer's one-time
    setup runs here per call (eagerly constant-folded when ``params`` are
    concrete).  Long-lived executors should compile once instead —
    ``primitives.compile_plan`` — so cached kernel spectra persist across
    calls and batch sizes.
    """
    prepared = prepare_layers(params, net, plan_prims, x.shape[-3:], lo, hi)
    return apply_prepared_range(net, prepared, x, use_pallas=use_pallas)


def apply_plan(
    params,
    net: ConvNetConfig,
    x: jnp.ndarray,
    plan_prims: Sequence[str],
    *,
    use_pallas: Optional[bool] = None,
    recombine: bool = True,
) -> jnp.ndarray:
    """Run the net; plan_prims[i] is the primitive name for layer i.

    x (S, in_ch, n³).  With MPF layers the batch grows by p³ each pool; if
    ``recombine``, fragments are folded back into the dense sliding-window
    output (S, out_ch, dense³).
    """
    S = x.shape[0]
    x = apply_layer_range(params, net, x, plan_prims, use_pallas=use_pallas)
    pools = plan_pools(net, plan_prims)
    if recombine and pools:
        x = recombine_fragments(x, pools, S)
    return x


def apply_with_plan(params, net: ConvNetConfig, x, plan: Plan, **kw):
    return apply_plan(params, net, x, [c.prim for c in plan.choices], **kw)


# ---------------------------------------------------------------------------
# Dense sliding-window oracle (dilated convolution semantics)
# ---------------------------------------------------------------------------


def _dilated_max_filter(x: jnp.ndarray, p: int, d: int) -> jnp.ndarray:
    """max over window of p taps spaced d apart, stride 1, per axis."""
    n = x.shape[-3:]
    out = tuple(ni - (p - 1) * d for ni in n)
    y = jnp.full(x.shape[:-3] + out, -jnp.inf, x.dtype)
    for ox, oy, oz in itertools.product(range(p), repeat=3):
        y = jnp.maximum(
            y,
            x[..., ox * d : ox * d + out[0], oy * d : oy * d + out[1], oz * d : oz * d + out[2]],
        )
    return y


def apply_dense_reference(params, net: ConvNetConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Dense sliding-window output via dilated convs/max filters (oracle)."""
    d = 1
    last_conv = max(i for i, l in enumerate(net.layers) if l.kind == "conv")
    for i, layer in enumerate(net.layers):
        if layer.kind == "conv":
            w, b = params[i]
            x = lax.conv_general_dilated(
                x.astype(jnp.float32),
                w.astype(jnp.float32),
                window_strides=(1, 1, 1),
                padding="VALID",
                rhs_dilation=(d, d, d),
                dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
            ) + b.reshape(1, -1, 1, 1, 1)
            if i != last_conv:
                x = jax.nn.relu(x)
        else:
            x = _dilated_max_filter(x, layer.size, d)
            d *= layer.size
    return x
