"""Pruned FFTs (ZNNi §III).

A 3D FFT of a small array zero-padded to a large size wastes most of its 1D
passes on all-zero rows.  The pruned transform performs the per-axis 1D FFT
passes in order of increasing "live" batch size, padding each axis only when
it is transformed:

    naive:   C * n^3 * log n^3
    pruned:  C * n * log n * (k^2 + k*n + n^2)        (paper §III-A)

`jnp.fft.{rfft,fft}(x, n=..., axis=...)` pads the axis internally, so each
pass only runs over the currently-nonzero extent of the *other* axes — that
is exactly the pruning.  The inverse transform prunes on the output side:
after each inverse pass the axis is cropped to the caller's region of
interest, shrinking the batch of the remaining passes (§III-B "reverse
order" + output cropping).

Convolution note: we compute *cross-correlation* (the deep-learning
convention, matching `lax.conv_general_dilated`) by conjugating the kernel
spectrum.  The paper computes true convolution; the two differ by a spatial
flip of the kernel and are otherwise identical in cost and structure.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence, Tuple

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# FFT-friendly sizes
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def fft_optimal_size(n: int, radices: Tuple[int, ...] = (2, 3, 5, 7)) -> int:
    """Smallest m >= n whose prime factors are all in `radices`.

    The paper pads to 2^a 3^b 5^c 7^d on the GPU (cuFFT) and additionally
    allows one factor of 11 or 13 on the CPU (fftw/MKL).  XLA's FFT is
    happiest with the same smooth sizes, so we default to the cuFFT set.
    """
    if n <= 1:
        return 1
    m = n
    while True:
        r = m
        for p in radices:
            while r % p == 0:
                r //= p
        if r == 1:
            return m
        m += 1


def fft_optimal_shape(shape: Sequence[int]) -> Tuple[int, ...]:
    return tuple(fft_optimal_size(int(s)) for s in shape)


# ---------------------------------------------------------------------------
# Forward pruned transform
# ---------------------------------------------------------------------------


def pruned_rfftn(x: jnp.ndarray, fft_shape: Sequence[int]) -> jnp.ndarray:
    """rfftn of `x` zero-padded (at the end of each axis) to `fft_shape`.

    x: (..., a, b, c) real.  fft_shape: (na, nb, nc) with na>=a etc.
    Returns (..., na, nb, nc//2 + 1) complex64 — bit-identical (up to float
    error) to ``jnp.fft.rfftn(pad(x), axes=(-3,-2,-1))`` but with the pruned
    pass structure: c-axis first over an (a, b) batch, then b-axis over an
    (a, nc'') batch, then a-axis over an (nb, nc'') batch.
    """
    na, nb, nc = (int(s) for s in fft_shape)
    a, b, c = x.shape[-3:]
    if not (na >= a and nb >= b and nc >= c):
        raise ValueError(f"fft_shape {fft_shape} smaller than input {x.shape[-3:]}")
    x = x.astype(jnp.float32)
    X = jnp.fft.rfft(x, n=nc, axis=-1)  # batch a*b   (k^2 term)
    X = jnp.fft.fft(X, n=nb, axis=-2)  # batch a*nc'' (k*n term)
    X = jnp.fft.fft(X, n=na, axis=-3)  # batch nb*nc'' (n^2 term)
    return X


def naive_rfftn(x: jnp.ndarray, fft_shape: Sequence[int]) -> jnp.ndarray:
    """Reference: pad-then-rfftn (the unpruned transform)."""
    na, nb, nc = (int(s) for s in fft_shape)
    a, b, c = x.shape[-3:]
    pad = [(0, 0)] * (x.ndim - 3) + [(0, na - a), (0, nb - b), (0, nc - c)]
    return jnp.fft.rfftn(jnp.pad(x, pad), axes=(-3, -2, -1))


# ---------------------------------------------------------------------------
# Inverse pruned transform (with output cropping)
# ---------------------------------------------------------------------------


def pruned_irfftn(
    X: jnp.ndarray,
    fft_shape: Sequence[int],
    crop_start: Sequence[int],
    crop_size: Sequence[int],
) -> jnp.ndarray:
    """Inverse of `pruned_rfftn`, cropped to [start, start+size) per axis.

    The crop is applied *as each axis is inverse-transformed* so later passes
    run over the smaller batch (output-side pruning).  Equivalent to
    ``jnp.fft.irfftn(X)[..., sa:sa+la, sb:sb+lb, sc:sc+lc]``.
    """
    na, nb, nc = (int(s) for s in fft_shape)
    (sa, sb, sc), (la, lb, lc) = crop_start, crop_size
    Y = jnp.fft.ifft(X, axis=-3)
    Y = Y[..., sa : sa + la, :, :]
    Y = jnp.fft.ifft(Y, axis=-2)
    Y = Y[..., :, sb : sb + lb, :]
    Y = jnp.fft.irfft(Y, n=nc, axis=-1)
    Y = Y[..., sc : sc + lc]
    return Y


# ---------------------------------------------------------------------------
# FFT-domain cross-correlation (valid region) — the conv building block
# ---------------------------------------------------------------------------


def kernel_rfftn(w: jnp.ndarray, fft_shape: Sequence[int]) -> jnp.ndarray:
    """Pruned, conjugated kernel spectrum (cross-correlation convention)."""
    return jnp.conj(pruned_rfftn(w, fft_shape))


def fft_correlate_valid(
    x: jnp.ndarray, w: jnp.ndarray, fft_shape: Sequence[int] | None = None
) -> jnp.ndarray:
    """'valid' cross-correlation of x (..., n³) with w (..., k³) via pruned FFT.

    A circular transform of size >= n suffices for the valid region (no
    wrap-around for output indices [0, n-k]); no padding to n+k-1 needed.
    """
    n = x.shape[-3:]
    k = w.shape[-3:]
    if fft_shape is None:
        fft_shape = fft_optimal_shape(n)
    out = tuple(ni - ki + 1 for ni, ki in zip(n, k))
    X = pruned_rfftn(x, fft_shape)
    W = kernel_rfftn(w, fft_shape)
    return pruned_irfftn(X * W, fft_shape, (0, 0, 0), out)


# ---------------------------------------------------------------------------
# Cost model hooks (ZNNi Table I)
# ---------------------------------------------------------------------------


def fft_1d_flops(n: int) -> float:
    """~5 n log2 n real FLOPs for a complex 1D FFT of length n (split-radix C)."""
    return 5.0 * n * math.log2(max(n, 2))


def pruned_fft_flops(in_shape: Sequence[int], fft_shape: Sequence[int]) -> float:
    """FLOPs of one pruned 3D transform: C n log n (k² + k·n + n²) structure."""
    a, b, c = in_shape
    na, nb, nc = fft_shape
    ncc = nc // 2 + 1
    return (
        a * b * fft_1d_flops(nc)  # k^2 passes of length n
        + a * ncc * fft_1d_flops(nb)  # k*n passes
        + nb * ncc * fft_1d_flops(na)  # n^2 passes
    )


def naive_fft_flops(fft_shape: Sequence[int]) -> float:
    na, nb, nc = fft_shape
    ncc = nc // 2 + 1
    return (
        na * nb * fft_1d_flops(nc) + na * ncc * fft_1d_flops(nb) + nb * ncc * fft_1d_flops(na)
    )


def pruned_speedup(in_shape: Sequence[int], fft_shape: Sequence[int]) -> float:
    return naive_fft_flops(fft_shape) / pruned_fft_flops(in_shape, fft_shape)
