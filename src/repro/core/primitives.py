"""Primitive registry + CompiledPlan — the ONE place primitive names mean code.

ZNNi's planner picks a per-layer primitive by *cost*; the runtime must then
execute exactly what was costed.  Before this module, three independent
string-dispatch sites (``cost_model.conv_cost``, ``convnet._conv_prim``,
``sublayer._conv``) could drift — most visibly, ``fft_cached`` was charged
an amortized kernel-FFT cost but silently executed as plain task-parallel
FFT, recomputing every kernel spectrum on every patch.

Each registry entry bundles the three faces of a primitive:

* ``cost``   — the analytic ``LayerCost`` the planner prices it with;
* ``setup``  — one-time per-layer preparation: choose the pruned-FFT shape
  for the bound patch geometry, precompute kernel spectra (``fft_cached``),
  record the pool mode — producing a ``PreparedLayer``;
* ``apply``  — the per-call forward, taking the prepared state.

``CompiledPlan`` binds a ``planner.Plan`` (or explicit prims + patch size)
to per-layer ``PreparedLayer``s ONCE.  The prepared states are a JAX pytree
(``CompiledPlan.states``) that callers pass through ``jax.jit`` as
arguments, so cached kernel spectra are computed once per plan and reused
across every patch, batch size, and pipeline stage — the paper's
cross-batch kernel-transform reuse extended across patches (ROADMAP "FFT
reuse" open item).

Adding a primitive is a small, local change: implement cost/setup/apply,
register it here, and append the name to ``cost_model``'s list; the
planner, ``convnet``, the volume executor, and the serving engine pick it
up by name (recipe: docs/architecture.md — ``overlap_save`` is the worked
example).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ConvNetConfig
from ..kernels import resolve_use_pallas
from .cost_model import (
    LayerCost,
    conv_direct_cost,
    conv_fft_cached_kernels_cost,
    conv_fft_data_parallel_cost,
    conv_fft_task_parallel_cost,
    conv_overlap_save_cost,
    mpf_cost,
    pool_cost,
)
from .direct_conv import direct_conv
from .fft_conv import (
    fft_conv_data_parallel,
    fft_conv_pool_fused,
    fft_conv_task_parallel,
    fft_conv_with_precomputed,
    precompute_kernel_fft,
)
from .mpf import max_pool3d, mpf, recombine_fragments
from .overlap_save import OverlapSaveSpec, overlap_save_conv, plan_overlap_save
from .pruned_fft import fft_optimal_shape


# ---------------------------------------------------------------------------
# PreparedLayer: the product of one-time setup
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PreparedLayer:
    """One layer's prepared execution state.

    Static metadata (prim name, FFT shape, pool size) lives in the frozen
    fields; device arrays (weights, biases, cached kernel spectra) live in
    ``state`` — a dict pytree so jitted callers can pass it as an argument
    instead of baking it into the trace.
    """

    index: int
    kind: str  # conv | pool
    prim: str  # canonical registry name
    pool_size: int = 0
    fft_shape: Optional[Tuple[int, int, int]] = None
    kernel_size: Optional[Tuple[int, int, int]] = None
    os_spec: Optional[OverlapSaveSpec] = None  # overlap_save segmentation
    fprime_chunk: Optional[int] = None  # tuned output-channel MAD chunking
    state: Any = None


@dataclass(frozen=True)
class Primitive:
    """Registry entry: a primitive's cost model, setup, and apply together.

    * conv — ``cost(S, f, fp, n, k, geom=None)``; ``setup(w, b, n, index=...)``;
    * pool — ``cost(S, f, n, p, geom=None)``;     ``setup(p, n, index=...)``;
    * both — ``apply(prepared, x, state, use_pallas=...)``.

    ``geom`` is an optional ``cost_model.PlanGeometry`` — the execution
    geometry (sweep patch mix, pinned layer-0 segment grid, deep
    activation reuse) the cost is evaluated in.  ``None`` means
    ``PlanGeometry.local()``: price the primitive self-contained.
    """

    name: str
    kind: str  # conv | pool
    cost: Callable[..., LayerCost]
    setup: Callable[..., PreparedLayer]
    apply: Callable[..., jnp.ndarray]


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_CONV: Dict[str, Primitive] = {}
_POOL: Dict[str, Primitive] = {}
_CONV_ALIASES: Dict[str, str] = {}


def register_conv_primitive(prim: Primitive, *, aliases: Sequence[str] = ()) -> Primitive:
    if prim.kind != "conv":
        raise ValueError(f"{prim.name}: conv registry got kind {prim.kind!r}")
    _CONV[prim.name] = prim
    for a in aliases:
        _CONV_ALIASES[a] = prim.name
    return prim


def register_pool_primitive(prim: Primitive) -> Primitive:
    if prim.kind != "pool":
        raise ValueError(f"{prim.name}: pool registry got kind {prim.kind!r}")
    _POOL[prim.name] = prim
    return prim


def conv_primitive(name: str) -> Primitive:
    canonical = _CONV_ALIASES.get(name, name)
    try:
        return _CONV[canonical]
    except KeyError:
        raise ValueError(
            f"unknown conv primitive {name!r}; registered: {sorted(_CONV)}"
        ) from None


def pool_primitive(name: str) -> Primitive:
    try:
        return _POOL[name]
    except KeyError:
        raise ValueError(
            f"unknown pool primitive {name!r}; registered: {sorted(_POOL)}"
        ) from None


def get_primitive(name: str) -> Primitive:
    """Resolve a name in either registry (conv aliases included)."""
    canonical = _CONV_ALIASES.get(name, name)
    if canonical in _CONV:
        return _CONV[canonical]
    if canonical in _POOL:
        return _POOL[canonical]
    raise ValueError(
        f"unknown primitive {name!r}; registered: {sorted(_CONV) + sorted(_POOL)}"
    )


def registered_conv_names() -> Tuple[str, ...]:
    """Canonical conv primitive names (aliases excluded)."""
    return tuple(_CONV)


def registered_pool_names() -> Tuple[str, ...]:
    return tuple(_POOL)


def _resolve(prepared: PreparedLayer) -> Primitive:
    return (_CONV if prepared.kind == "conv" else _POOL)[prepared.prim]


def resolve_primitive(prepared: PreparedLayer) -> Primitive:
    """Public resolve: the registry entry a ``PreparedLayer`` executes as
    (used by executors that walk prepared layers with custom interleaving,
    e.g. the volume executor's halo-capturing and strip walks)."""
    return _resolve(prepared)


# ---------------------------------------------------------------------------
# Built-in primitives
# ---------------------------------------------------------------------------


def _ksize(w: jnp.ndarray) -> Tuple[int, int, int]:
    kx, ky, kz = w.shape[2:]
    return (int(kx), int(ky), int(kz))


def _setup_direct(w, b, n, *, index: int = -1) -> PreparedLayer:
    return PreparedLayer(
        index, "conv", "direct", kernel_size=_ksize(w), state={"w": w, "b": b}
    )


def _apply_direct(pl, x, state, *, use_pallas: Optional[bool] = None):
    return direct_conv(x, state["w"], state["b"], use_pallas=use_pallas)


def _setup_fft(name: str):
    def setup(w, b, n, *, index: int = -1) -> PreparedLayer:
        fft_shape = fft_optimal_shape(tuple(int(s) for s in n))
        return PreparedLayer(
            index, "conv", name,
            fft_shape=fft_shape, kernel_size=_ksize(w), state={"w": w, "b": b},
        )

    return setup


def _apply_fft_data(pl, x, state, *, use_pallas: Optional[bool] = None):
    return fft_conv_data_parallel(
        x, state["w"], state["b"], fft_shape=pl.fft_shape, use_pallas=use_pallas
    )


def _apply_fft_task(pl, x, state, *, use_pallas: Optional[bool] = None):
    return fft_conv_task_parallel(
        x, state["w"], state["b"], fft_shape=pl.fft_shape, use_pallas=use_pallas
    )


def _setup_fft_cached(
    w, b, n, *, index: int = -1, fprime_chunk: Optional[int] = None
) -> PreparedLayer:
    fft_shape = fft_optimal_shape(tuple(int(s) for s in n))
    W = precompute_kernel_fft(w, fft_shape)  # the one-time kernel transform
    return PreparedLayer(
        index, "conv", "fft_cached",
        fft_shape=fft_shape, kernel_size=_ksize(w),
        fprime_chunk=fprime_chunk, state={"W": W, "b": b},
    )


def _apply_fft_cached(pl, x, state, *, use_pallas: Optional[bool] = None):
    return fft_conv_with_precomputed(
        x, state["W"], state["b"], pl.fft_shape, pl.kernel_size,
        use_pallas=use_pallas, fprime_chunk=pl.fprime_chunk,
    )


def _setup_overlap_save(
    w, b, n, *, index: int = -1, seg_core=None, fprime_chunk: Optional[int] = None
) -> PreparedLayer:
    """Segment grid + cached kernel spectra at the SEGMENT FFT shape.

    ``seg_core`` aligns the layer's segment grid to an external stride (the
    volume executor passes the plan's patch core so x-adjacent patches
    share segment spectra); default is a small local grid.
    ``fprime_chunk`` (tuned) bounds the live output spectra per segment —
    on the Pallas path it becomes the fused segment kernel's
    output-channel block.
    """
    k = _ksize(w)
    spec = plan_overlap_save(tuple(int(s) for s in n), k, seg_core)
    W = precompute_kernel_fft(w, spec.fft_shape)
    return PreparedLayer(
        index, "conv", "overlap_save",
        fft_shape=spec.fft_shape, kernel_size=k, os_spec=spec,
        fprime_chunk=fprime_chunk, state={"W": W, "b": b},
    )


def _apply_overlap_save(pl, x, state, *, use_pallas: Optional[bool] = None):
    return overlap_save_conv(
        x, state["W"], state["b"], pl.os_spec,
        use_pallas=use_pallas, fprime_chunk=pl.fprime_chunk,
    )


def _setup_mpf(p, n, *, index: int = -1) -> PreparedLayer:
    if any((int(x) + 1) % p for x in n):
        raise ValueError(f"MPF needs (n+1)%p==0, got n={tuple(n)}, p={p}")
    return PreparedLayer(index, "pool", "mpf", pool_size=int(p), state={})


def _apply_mpf(pl, x, state, *, use_pallas: Optional[bool] = None):
    return mpf(x, pl.pool_size, use_pallas=use_pallas)


def _setup_pool(p, n, *, index: int = -1) -> PreparedLayer:
    if any(int(x) % p for x in n):
        raise ValueError(f"plain pool needs n%p==0, got n={tuple(n)}, p={p}")
    return PreparedLayer(index, "pool", "pool", pool_size=int(p), state={})


def _apply_pool(pl, x, state, *, use_pallas: Optional[bool] = None):
    return max_pool3d(x, pl.pool_size)


register_conv_primitive(
    Primitive("direct", "conv", conv_direct_cost, _setup_direct, _apply_direct)
)
register_conv_primitive(
    Primitive("fft_data", "conv", conv_fft_data_parallel_cost,
              _setup_fft("fft_data"), _apply_fft_data)
)
register_conv_primitive(
    Primitive("fft_task", "conv", conv_fft_task_parallel_cost,
              _setup_fft("fft_task"), _apply_fft_task),
    aliases=("fft",),  # sublayer's historical variant name
)
register_conv_primitive(
    Primitive("fft_cached", "conv", conv_fft_cached_kernels_cost,
              _setup_fft_cached, _apply_fft_cached)
)
register_conv_primitive(
    Primitive("overlap_save", "conv", conv_overlap_save_cost,
              _setup_overlap_save, _apply_overlap_save)
)
register_pool_primitive(Primitive("mpf", "pool", mpf_cost, _setup_mpf, _apply_mpf))
register_pool_primitive(Primitive("pool", "pool", pool_cost, _setup_pool, _apply_pool))


# ---------------------------------------------------------------------------
# One-shot apply (setup folded into the call — sublayer / halo paths)
# ---------------------------------------------------------------------------


def conv_apply(name: str, x, w, b=None, *, use_pallas: Optional[bool] = None):
    """Apply a conv primitive without retained state (setup inlined).

    For callers that re-chunk weights per call (``sublayer``'s streamed
    variants, halo-sharded inference) and therefore can't reuse prepared
    state across calls.  ``name`` may be an alias (e.g. ``"fft"``).
    """
    prim = conv_primitive(name)
    pl = prim.setup(w, b, tuple(int(s) for s in x.shape[-3:]))
    return prim.apply(pl, x, pl.state, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Plan compilation: Plan -> PreparedLayers, walked per call
# ---------------------------------------------------------------------------


def plan_input_size(net: ConvNetConfig, prims: Sequence[str], m: int) -> int:
    """Input size per apply call for fragment size ``m``, walked backwards.

    Generalizes ``net.valid_input_size`` / ``planner._n_in_for_m`` to
    per-layer primitive assignments (those assume all pools are MPF or none
    are)."""
    n = m
    for i in reversed(range(len(net.layers))):
        layer = net.layers[i]
        if layer.kind == "conv":
            n = n + layer.size - 1
        elif prims[i] == "mpf":
            n = layer.size * n + layer.size - 1
        else:
            n = layer.size * n
    return n


def layer_fprime_chunk(fprime_chunk, i: int) -> Optional[int]:
    """Resolve a tuned ``fprime_chunk`` for ABSOLUTE layer index ``i``.

    The knob is either one int applied to every eligible conv layer, or a
    per-layer schedule (tuple/list indexed by absolute layer position,
    ``None`` entries — e.g. at pools — meaning unchunked).  Schedules
    shorter than the net apply ``None`` past their end.
    """
    if fprime_chunk is None:
        return None
    if isinstance(fprime_chunk, (tuple, list)):
        v = fprime_chunk[i] if i < len(fprime_chunk) else None
        return None if v is None else int(v)
    return int(fprime_chunk)


def prepare_layers(
    params,
    net: ConvNetConfig,
    prims: Sequence[str],
    n,
    lo: int = 0,
    hi: Optional[int] = None,
    *,
    overlap_seg: Optional[int] = None,
    fprime_chunk=None,
) -> Tuple[PreparedLayer, ...]:
    """Run each layer's one-time setup for layers [lo, hi).

    ``n`` is the spatial input extent at layer ``lo`` — an int (isotropic)
    or a per-axis tuple.  FFT shapes are chosen here, once, from the actual
    per-layer input sizes (no ``fft_shape=None`` re-derivation inside jit).

    ``overlap_seg`` pins the segment core of a FIRST-layer ``overlap_save``
    conv (the volume executor passes the plan's patch core so the layer-0
    segment grid of x-adjacent patches coincides and spectra can be reused
    across patches); deeper overlap_save layers keep their local default —
    only the net's input has a cross-patch identity to exploit.

    ``fprime_chunk`` (tuned) bounds the live output spectra of
    ``fft_cached`` and ``overlap_save`` layers; other primitives ignore
    it.  An int applies globally; a per-layer schedule (see
    ``layer_fprime_chunk``) tunes each conv independently.
    """
    if hi is None:
        hi = len(net.layers)
    n = tuple(int(s) for s in (n if isinstance(n, (tuple, list)) else (n,) * 3))
    prepared = []
    for i in range(lo, hi):
        layer = net.layers[i]
        if layer.kind == "conv":
            prim = conv_primitive(prims[i])
            w, b = params[i]
            fc_i = layer_fprime_chunk(fprime_chunk, i)
            if i == 0 and prim.name == "overlap_save" and overlap_seg:
                prepared.append(
                    prim.setup(
                        w, b, n, index=i, seg_core=overlap_seg, fprime_chunk=fc_i
                    )
                )
            elif prim.name in ("fft_cached", "overlap_save") and fc_i is not None:
                prepared.append(prim.setup(w, b, n, index=i, fprime_chunk=fc_i))
            else:
                prepared.append(prim.setup(w, b, n, index=i))
            n = tuple(x - layer.size + 1 for x in n)
        else:
            prim = pool_primitive(prims[i])
            prepared.append(prim.setup(layer.size, n, index=i))
            n = tuple(x // layer.size for x in n)
    return tuple(prepared)


def apply_prepared_range(
    net: ConvNetConfig,
    prepared: Sequence[PreparedLayer],
    x,
    *,
    states: Optional[Sequence[Any]] = None,
    use_pallas: Optional[bool] = None,
    fuse_pairs: bool = False,
):
    """Walk prepared layers over ``x``: the thin core of plan execution.

    ReLU follows the whole-net rule (no activation after the net's final
    conv), so chaining ranges composes to a full forward pass.  ``states``
    (when given) substitutes each layer's pytree state — the hook jitted
    callers use to pass cached spectra as arguments rather than constants.

    With ``fuse_pairs`` a consecutive ``fft_cached`` conv + ``mpf`` pool
    pair dispatches to ``fft_conv_pool_fused`` (bias on the MAD's DC bin,
    inverse-window crop folded into the pool, ReLU after the pool) instead
    of two separate primitive applies — numerically equivalent, fewer
    materialized intermediates.
    """
    last_conv = max(i for i, l in enumerate(net.layers) if l.kind == "conv")
    if states is None:
        states = [pl.state for pl in prepared]
    else:
        states = list(states)
    prepared = tuple(prepared)
    i = 0
    while i < len(prepared):
        pl = prepared[i]
        st = states[i]
        nxt = prepared[i + 1] if i + 1 < len(prepared) else None
        if (
            fuse_pairs
            and pl.kind == "conv"
            and pl.prim == "fft_cached"
            and pl.index != last_conv  # fused path applies the ReLU
            and nxt is not None
            and nxt.kind == "pool"
            and nxt.prim == "mpf"
            and nxt.index == pl.index + 1
        ):
            x = fft_conv_pool_fused(
                x, st["W"], st["b"],
                fft_shape=pl.fft_shape, k=pl.kernel_size, p=nxt.pool_size,
                use_pallas=use_pallas, fprime_chunk=pl.fprime_chunk,
            )
            i += 2
            continue
        x = _resolve(pl).apply(pl, x, st, use_pallas=use_pallas)
        if pl.kind == "conv" and pl.index != last_conv:
            x = jax.nn.relu(x)
        i += 1
    return x


@dataclass
class CompiledPlan:
    """A Plan bound to per-layer prepared state — setup done exactly once.

    ``layers[i]`` is layer ``i``'s ``PreparedLayer``; ``states`` is the
    matching pytree of device arrays.  ``apply``/``apply_range`` walk the
    prepared layers; pass ``states=...`` inside jit to keep the spectra as
    call arguments (shared across every compiled batch size).
    """

    net: ConvNetConfig
    prims: Tuple[str, ...]
    layers: Tuple[PreparedLayer, ...]
    n_in: int
    use_pallas: bool = False
    fuse_pairs: bool = False  # fused fft_cached+mpf epilogue in apply walks
    plan: Optional[object] = None  # the planner.Plan this was compiled from

    @property
    def states(self):
        return [pl.state for pl in self.layers]

    @property
    def mpf_pools(self) -> Tuple[int, ...]:
        """MPF pool sizes in network order (recombination schedule)."""
        return tuple(
            pl.pool_size for pl in self.layers
            if pl.kind == "pool" and pl.prim == "mpf"
        )

    def apply_range(self, x, lo: int = 0, hi: Optional[int] = None, *, states=None):
        if hi is None:
            hi = len(self.layers)
        if states is not None:
            states = states[lo:hi]
        return apply_prepared_range(
            self.net, self.layers[lo:hi], x,
            states=states, use_pallas=self.use_pallas,
            fuse_pairs=self.fuse_pairs,
        )

    def apply(self, x, *, states=None, recombine: bool = True):
        """Full forward over a patch batch; recombine MPF fragments if asked."""
        S = x.shape[0]
        x = self.apply_range(x, states=states)
        pools = self.mpf_pools
        if recombine and pools:
            x = recombine_fragments(x, pools, S)
        return x


def compile_plan(
    params,
    net: ConvNetConfig,
    *,
    prims: Sequence[str],
    n_in: Optional[int] = None,
    m: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    fuse_pairs: Optional[bool] = None,
    fprime_chunk=None,
    plan: Optional[object] = None,
    overlap_seg: Optional[int] = None,
) -> CompiledPlan:
    """Bind primitives to prepared per-layer state for one patch geometry.

    Give either ``n_in`` (input voxels per axis per apply call) or the
    fragment size ``m`` (``n_in`` is then derived via ``plan_input_size``).
    ``overlap_seg`` (see ``prepare_layers``) aligns a first-layer
    ``overlap_save`` segment grid with the volume patch grid.

    ``use_pallas=None`` backend-detects (``kernels.resolve_use_pallas``);
    ``fuse_pairs=None`` follows the resolved ``use_pallas`` — the fused
    conv+pool epilogue is a Pallas-path optimization, so it switches on
    with the kernels.  ``fprime_chunk`` is the tuned MAD chunk for
    ``fft_cached``/``overlap_save`` layers — one int, or a per-layer
    schedule (``None`` = unchunked).
    """
    prims = tuple(prims)
    if len(prims) != len(net.layers):
        raise ValueError(f"{len(prims)} prims for {len(net.layers)} layers")
    use_pallas = resolve_use_pallas(use_pallas)
    if fuse_pairs is None:
        fuse_pairs = use_pallas
    if n_in is None:
        if m is None:
            raise ValueError("need n_in or m")
        n_in = plan_input_size(net, prims, m)
    layers = prepare_layers(
        params, net, prims, n_in,
        overlap_seg=overlap_seg, fprime_chunk=fprime_chunk,
    )
    return CompiledPlan(
        net, prims, layers, int(n_in), use_pallas, bool(fuse_pairs), plan
    )


def compile_from_plan(
    params,
    net: ConvNetConfig,
    plan,
    *,
    use_pallas: Optional[bool] = None,
    fuse_pairs: Optional[bool] = None,
    fprime_chunk=None,
):
    """CompiledPlan for a ``planner.Plan`` (geometry read off the plan)."""
    return compile_plan(
        params, net, prims=plan.prims, n_in=plan.n_in,
        use_pallas=use_pallas, fuse_pairs=fuse_pairs, fprime_chunk=fprime_chunk,
        plan=plan,
        overlap_seg=plan.core if plan.prims[0] == "overlap_save" else None,
    )
