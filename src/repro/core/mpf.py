"""Max-pooling and Max-Pooling Fragments (ZNNi §V).

MPF computes max pooling at every offset (x,y,z), 0 <= offset < p per axis,
producing p³ fragments per input.  Fragments multiply the *batch* dimension
of subsequent layers (paper: "the most significant dimension"), and the
composed fragments of all MPF layers tile the dense sliding-window output.

Offset composition: an MPF layer applied after earlier poolings of combined
stride s contributes `offset * s` to the dense output coordinate; the first
pooling has unit stride.  `recombine_fragments` inverts the stacking.

Input constraint: (n + 1) % p == 0 per axis so all fragments share the size
floor(n/p) (paper §V).
"""

from __future__ import annotations

import itertools
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from ..kernels.mpf_pool import ops as mpf_ops


def max_pool3d(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """Plain max pooling, window p³, stride p.  x (..., nx, ny, nz)."""
    nx, ny, nz = x.shape[-3:]
    if nx % p or ny % p or nz % p:
        raise ValueError(f"pool {p} does not divide {x.shape[-3:]}")
    y = x.reshape(*x.shape[:-3], nx // p, p, ny // p, p, nz // p, p)
    return y.max(axis=(-5, -3, -1))


@partial(jax.jit, static_argnames=("p", "use_pallas"))
def mpf(x: jnp.ndarray, p: int, *, use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Max-pooling fragments. x (S, f, n³) with (n+1)%p==0 -> (S*p³, f, m³).

    Fragment o=(ox,oy,oz) (row-major) of batch s lands at output batch
    index s*p³ + flat(o).
    """
    return mpf_ops.mpf_pool(x, p, use_pallas=use_pallas)


def mpf_reference(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """Oracle: explicit loop over offsets (also used by tests)."""
    S, f = x.shape[:2]
    n = x.shape[2:]
    if any((ni + 1) % p for ni in n):
        raise ValueError(f"MPF needs (n+1)%p==0, got n={n}, p={p}")
    m = tuple(ni // p for ni in n)
    frags = []
    for ox, oy, oz in itertools.product(range(p), repeat=3):
        v = x[:, :, ox : ox + p * m[0], oy : oy + p * m[1], oz : oz + p * m[2]]
        frags.append(max_pool3d(v, p))
    y = jnp.stack(frags, axis=1)  # (S, p³, f, m³)
    return y.reshape(S * p**3, f, *m)


def naive_sliding_pool(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """The baseline 'compute all subsamplings' primitive (ZNNi baseline):

    dense max-filter with window p, stride 1: out[v] = max(x[v : v+p]) per
    axis; output size n - p + 1.  The MPF fragments, recombined, equal this.
    """
    S, f = x.shape[:2]
    n = x.shape[2:]
    out = tuple(ni - p + 1 for ni in n)
    y = jnp.full((S, f) + out, -jnp.inf, x.dtype)
    for ox, oy, oz in itertools.product(range(p), repeat=3):
        y = jnp.maximum(
            y, x[:, :, ox : ox + out[0], oy : oy + out[1], oz : oz + out[2]]
        )
    return y


def recombine_fragments(
    y: jnp.ndarray, pools: Sequence[int], batch: int
) -> jnp.ndarray:
    """Invert MPF stacking into the dense sliding-window output.

    y: (batch * Π p³, f, m³) where pools = (p1, p2, ...) in network order
    (p1 applied first).  Returns (batch, f, (m*P + Σ(p_l - 1)*s_l)³) — the
    dense output; dense coord = v*P + Σ_l o_l * s_l with s_l = Π_{l'<l} p_l'.
    """
    P = 1
    for p in pools:
        P *= p
    S = batch
    f = y.shape[1]
    m = y.shape[2:]
    k = len(pools)
    # batch layout: s, o1, o2, ..., ok (o1 outermost after s) — each o is (p,p,p)
    dims = [S]
    for p in pools:
        dims += [p, p, p]
    y = y.reshape(*dims, f, *m)
    # axis order target per spatial axis X: (vx, o_k x, ..., o_1 x) — most
    # significant first; o_l x lives at axis index 1 + 3*(l-1) + axis.
    perm = [0, 1 + 3 * k]  # S, f
    for ax in range(3):
        perm.append(1 + 3 * k + 1 + ax)  # v_ax
        for l in range(k - 1, -1, -1):
            perm.append(1 + 3 * l + ax)  # o_{l+1} for this axis
    y = y.transpose(perm)
    out = tuple(mi * P for mi in m)
    return y.reshape(S, f, *out)
