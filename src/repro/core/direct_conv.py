"""Direct 3D convolution primitives (ZNNi §IV-A1 / §IV-B1).

The paper's direct CPU primitive parallelizes over (batch, output channel);
its GPU primitive is cuDNN's implicit GEMM.  The TPU-native formulation is
the same implicit GEMM: for each kernel offset (dx,dy,dz) accumulate
``W[:, :, dx,dy,dz] @ I[:, :, shifted window]`` — k³ MXU matmuls with the
channel dimension as the contraction.  That is what both the XLA path
(`lax.conv_general_dilated` lowers to exactly this on TPU) and the Pallas
kernel (`repro.kernels.direct_conv3d`) compute.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.direct_conv3d import ops as conv3d_ops
from .bias import add_channel_bias


@partial(jax.jit, static_argnames=("use_pallas",))
def direct_conv(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: Optional[jnp.ndarray] = None,
    *,
    use_pallas: Optional[bool] = None,
) -> jnp.ndarray:
    """'valid' cross-correlation. x (S,f,n³) f32, w (f',f,k³) -> (S,f',n'³)."""
    o = conv3d_ops.conv3d(x, w, use_pallas=use_pallas)
    return add_channel_bias(o, b)
