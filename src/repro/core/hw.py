"""Hardware model constants (target: TPU v5e; container runtime is CPU).

A ``HardwareSpec`` is one *device profile*; the heterogeneous planner
(``planner.plan_hetero``) takes a **set** of profiles and prices each
pipeline stage on its own profile.  ``ici_bw`` doubles as the device's
host-link bandwidth (QPI for the Xeon, PCIe for the Titan X, ICI for the
TPU): the split-point activation hand-off travels through host RAM, so it
is priced over the slower of the two devices' links
(``host_link_bw``).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    hbm_bytes: int = 16 * 2**30  # per chip
    ici_bw: float = 50e9  # bytes/s per link; also the host-link bandwidth
    vmem_bytes: int = 128 * 2**20
    # MXU native tile (used by kernel BlockSpec choices and napkin math)
    mxu: int = 128


def host_link_bw(a: "HardwareSpec", b: "HardwareSpec") -> float:
    """Bandwidth of a host-RAM hand-off between two devices.

    The activation crosses producer link → host RAM → consumer link; the
    slower link bounds the steady-state rate (the paper's §VII-C hand-off
    cost, PCIe on its machines).
    """
    return min(a.ici_bw, b.ici_bw)


TPU_V5E = HardwareSpec()

# The paper's two machines, for reproducing its tables analytically.
XEON_E7_8890V3_4WAY = HardwareSpec(
    name="4-way Xeon E7-8890v3",
    peak_flops=72 * 2.5e9 * 16,  # 72 cores * AVX2 fp32 FMA throughput
    hbm_bw=85e9,  # 4-socket aggregate stream bw (approx)
    hbm_bytes=256 * 2**30,
    ici_bw=16e9,  # QPI-ish
    vmem_bytes=45 * 2**20,  # LLC
)

TITAN_X = HardwareSpec(
    name="Titan X (Maxwell)",
    peak_flops=6.1e12,
    hbm_bw=336e9,
    hbm_bytes=12 * 2**30,
    ici_bw=12e9,  # PCIe 3.0 x16 ~ 12 GB/s effective
    vmem_bytes=3 * 2**20,
)

# The paper's CPU+GPU machine as a device set: the canonical argument to
# ``planner.plan_hetero`` / ``plan_all_strategies(devices=...)`` for
# reproducing its CPU-vs-GPU-vs-pipeline tables analytically.
PAPER_MACHINES = (XEON_E7_8890V3_4WAY, TITAN_X)
