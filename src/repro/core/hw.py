"""Hardware model constants (target: TPU v5e; container runtime is CPU)."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    hbm_bytes: int = 16 * 2**30  # per chip
    ici_bw: float = 50e9  # bytes/s per link
    vmem_bytes: int = 128 * 2**20
    # MXU native tile (used by kernel BlockSpec choices and napkin math)
    mxu: int = 128


TPU_V5E = HardwareSpec()

# The paper's two machines, for reproducing its tables analytically.
XEON_E7_8890V3_4WAY = HardwareSpec(
    name="4-way Xeon E7-8890v3",
    peak_flops=72 * 2.5e9 * 16,  # 72 cores * AVX2 fp32 FMA throughput
    hbm_bw=85e9,  # 4-socket aggregate stream bw (approx)
    hbm_bytes=256 * 2**30,
    ici_bw=16e9,  # QPI-ish
    vmem_bytes=45 * 2**20,  # LLC
)

TITAN_X = HardwareSpec(
    name="Titan X (Maxwell)",
    peak_flops=6.1e12,
    hbm_bw=336e9,
    hbm_bytes=12 * 2**30,
    ici_bw=12e9,  # PCIe 3.0 x16 ~ 12 GB/s effective
    vmem_bytes=3 * 2**20,
)
