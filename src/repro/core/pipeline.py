"""Two-stage producer-consumer pipeline — ZNNi's CPU-GPU execution (§VII-C).

The paper splits the net at layer θ: the CPU computes layers [0, θ) for
patch t while the GPU computes layers [θ, L) for patch t-1, with a queue of
depth 1 (the producer stalls until the consumer drains).

TPU adaptation (DESIGN.md §3): the two engines are the two pods of the
multi-pod mesh.  ``pipelined_apply`` stages the steady-state loop as a
lax.scan over patches; each scan step runs stage-0 on its pod, hands the
activation across the ``pod`` axis with ``ppermute`` (the ICI hop standing
in for the paper's host→device transfer), and runs stage-1 on the other
pod.  Both pods execute both stage functions SPMD-style, but each pod's
stage function sees only its own shard of the patch stream — with patches
sharded over the pod axis, pod 0's "stage 1" work and pod 1's "stage 0"
work are each other's bubbles, which is exactly the paper's Fig. 8
interleaving (CPU busy on patch t while GPU busy on patch t-1).

``pipeline_schedule`` exposes the timeline (for tests and the Fig. 8
benchmark) without needing 2 devices: it simulates queue-depth-1 order.
"""

from __future__ import annotations

from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def steady_state_time(t_stage0: float, t_stage1: float, t_xfer: float = 0.0) -> float:
    """Per-patch cadence of the queue-depth-1 pipeline (§VII-C).

    The slower stage bounds the rate; the hand-off is not overlapped with
    compute under queue depth 1, so it adds to every patch's cadence.
    This is the quantity ``planner.plan_hetero`` maximizes voxels over.
    """
    return max(t_stage0, t_stage1) + t_xfer


def hetero_stage_devices() -> Tuple[jax.Device, jax.Device]:
    """The two backends a hetero plan executes on.

    Convention (documented in docs/architecture.md): the plan's
    ``devices[0]`` profile maps to the host CPU backend and ``devices[1]``
    to the default accelerator — ``(jax.devices("cpu")[0],
    jax.devices()[0])``.  On a CPU-only runtime both entries are the same
    physical backend; the executor still routes stage-0/stage-1 arrays
    through explicit ``device_put`` + a host-RAM ndarray hand-off so the
    two-backend contract is exercised end to end.
    """
    return jax.devices("cpu")[0], jax.devices()[0]


def pipeline_schedule(
    n_patches: int, t_stage0: float, t_stage1: float, t_xfer: float = 0.0
) -> Tuple[float, List[Tuple[str, int, float, float]]]:
    """Simulate the paper's queue-depth-1 schedule.

    Returns (makespan, events) with events (stage, patch, start, end).
    Producer may only start patch t+1 once the consumer has *picked up*
    patch t (queue empty), per §VII-C.
    """
    events = []
    prod_free = 0.0
    cons_free = 0.0
    queue_free = 0.0  # time the queue becomes empty again
    for t in range(n_patches):
        s0 = max(prod_free, queue_free)
        e0 = s0 + t_stage0
        events.append(("stage0", t, s0, e0))
        # hand-off: consumer picks up when free; queue empties at pickup
        pickup = max(e0 + t_xfer, cons_free)
        queue_free = pickup
        e1 = pickup + t_stage1
        events.append(("stage1", t, pickup, e1))
        cons_free = e1
        prod_free = e0
    return cons_free, events


def pipelined_apply(
    stage0: Callable,
    stage1: Callable,
    xs: jnp.ndarray,
    *,
    axis_name: str = "pod",
) -> jnp.ndarray:
    """Run stage0 → (pod hand-off) → stage1 over a stream of patches.

    Called inside shard_map with ``xs`` (T, ...) the *local* patch stream of
    this pod.  Stage-0 output for step t is ppermuted to the next pod, which
    applies stage-1 at step t+1; a one-slot carry realizes queue depth 1.
    The returned stream is the stage-1 output aligned to the sender's
    patches (first slot is the pipeline-fill bubble).
    """
    n_pods = lax.psum(1, axis_name)
    perm = [(i, (i + 1) % n_pods) for i in range(n_pods)]

    def step(carry, x):
        prev = carry  # stage-0 activation received at step t-1
        y = stage1(prev)
        a = stage0(x)
        a_next = lax.ppermute(a, axis_name, perm)
        return a_next, y

    a0 = stage0(xs[0])
    a0 = lax.ppermute(a0, axis_name, perm)
    a_final, ys = lax.scan(step, a0, xs[1:])
    y_last = stage1(a_final)
    return jnp.concatenate([ys, y_last[None]], axis=0)


def split_net_at_theta(
    prims: Sequence[str], theta: int
) -> Tuple[Tuple[int, ...], Tuple[int, ...]]:
    """Layer indices for stage 0 ([0, θ)) and stage 1 ([θ, L))."""
    idx = tuple(range(len(prims)))
    return idx[:theta], idx[theta:]


def make_stage_fns(compiled, theta: int, *, states=None) -> Tuple[Callable, Callable]:
    """Stage closures for a pipeline2 plan: layers [0, θ) and [θ, L).

    ``compiled`` is a ``primitives.CompiledPlan`` — both stages walk its
    prepared layers, so per-layer setup (cached kernel spectra, chosen FFT
    shapes) is shared with every other consumer of the plan and runs zero
    times inside the scan.  Pass ``states`` (typically a traced view of
    ``compiled.states``) to keep the prepared arrays jit *arguments*
    instead of baked-in trace constants.  Neither stage recombines MPF
    fragments — the executor folds fragments back after stage 1
    (recombination needs all pools, which may straddle the split).
    ``stage1 ∘ stage0 == compiled.apply(..., recombine=False)``.
    """

    def stage0(x):
        return compiled.apply_range(x, 0, theta, states=states)

    def stage1(x):
        return compiled.apply_range(x, theta, None, states=states)

    return stage0, stage1
