"""The paper's primary contribution (ZNNi) as composable JAX modules.

pruned_fft   — C1: pruned forward/inverse FFTs
fft_conv     — C2: FFT-based conv layer (data- & task-parallel variants)
direct_conv  — C3: direct conv layer
mpf          — C4: max-pooling fragments + recombination + naive baseline
planner      — C5: memory-constrained throughput maximization (+ strategies)
cost_model   — Tables I/II analytics feeding the planner & benchmarks
primitives   — primitive registry (cost+setup+apply) and CompiledPlan
sublayer     — C6: GPU+host-RAM analogue (chunked / mesh-gathered conv)
pipeline     — C7: two-stage producer-consumer pipeline (pod axis)
convnet      — net assembly, plan execution, dense sliding-window oracle
distributed_inference — §II patch distribution + beyond-paper halo sharding
hw           — hardware model constants (TPU v5e target)
"""

from . import (  # noqa: F401
    convnet,
    cost_model,
    direct_conv,
    distributed_inference,
    fft_conv,
    hw,
    mpf,
    pipeline,
    planner,
    primitives,
    pruned_fft,
    sublayer,
)
