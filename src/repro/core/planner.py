"""The ZNNi throughput planner (§VI-A exhaustive search, §VII strategies).

Given a ConvNet, a hardware spec, and a memory budget, enumerate

  1. pooling-layer realization (MPF vs plain pooling — plain pooling forces
     the naive all-subsamplings outer loop, the paper's baseline),
  2. input patch size (parameterized by the final fragment size m, which
     makes every candidate automatically satisfy the MPF divisibility
     constraints),
  3. batch size S,
  4. per-conv-layer primitive (direct / fft_data / fft_task / fft_cached),

and pick the throughput-maximizing combination whose per-layer peak memory
fits the budget.  Primitive names are priced through ``cost_model`` (which
delegates to the ``core.primitives`` registry), and the winning Plan is
made executable by ``primitives.compile_from_plan`` — the same registry
entry supplies the cost model, the one-time setup, and the apply function,
so a plan is always executable exactly as costed.  This is exactly the paper's search; on one chip the budget
is HBM (the "GPU-only" column), and three further *strategies* re-run the
same search under different resource envelopes:

  * ``streamed``  — ZNNi "GPU + host RAM" (Fig. 6): tensors live sharded
    across the mesh (aggregate HBM plays host RAM), sub-layer chunks are
    all-gathered over ICI; collective bytes enter the layer time.
  * ``pipeline2`` — ZNNi "CPU-GPU" (Fig. 8): two pods form a producer-
    consumer pipeline split at layer θ; steady-state time is the max stage
    time; each pod needs only its stage's memory.
  * ``hetero``    — the general form of ``pipeline2`` over a *set* of
    device profiles (``plan_hetero``): stage 0 priced on one profile,
    stage 1 on the other, the hand-off priced over the slower host link,
    memory budgeted per device.  ``plan_pipeline2`` is now the degenerate
    two-identical-profiles case.
  * ``spatial``   — beyond-paper: one big patch sharded spatially over all
    chips with halo exchange instead of overlapped independent patches.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from ..configs.base import ConvNetConfig
from .cost_model import (
    C64,
    CONV_PRIMS,
    F32,
    LayerCost,
    MemoryFootprint,
    PlanGeometry,
    _nt,
    conv_cost,
    mpf_cost,
    pool_cost,
    split_transfer_cost,
)
from .hw import HardwareSpec
from .pipeline import steady_state_time


@dataclass(frozen=True)
class InfeasiblePoint:
    """A (primitive, patch-size) point the RAM budget rejected.

    The search reports these instead of silently omitting them, so
    benchmark tables stay rectangular and the paper's crossover — a
    slower primitive winning because the faster one's patch no longer
    fits — is observable in ``plan_all_strategies`` output.  ``layer``
    is -1 for a plan-level rejection (the combined working set of an
    otherwise per-layer-feasible plan).  ``device`` names the profile
    whose budget rejected the point — the heterogeneous search budgets
    each stage on its own device, so rejections are per
    (device, prim, patch); single-device searches leave it empty.
    """

    strategy: str
    prim: str
    m: int
    batch: int
    layer: int
    reason: str
    needed_bytes: float
    budget_bytes: float
    device: str = ""


@dataclass(frozen=True)
class LayerChoice:
    index: int
    kind: str  # conv | pool
    prim: str
    in_shape: Tuple[int, int, Tuple[int, int, int]]  # (S, f, n)
    out_shape: Tuple[int, int, Tuple[int, int, int]]
    cost: LayerCost
    time_s: float


@dataclass(frozen=True)
class Plan:
    net: str
    strategy: str
    chips: int
    batch: int
    n_in: int
    m_final: int
    choices: Tuple[LayerChoice, ...]
    total_time: float
    out_voxels: float
    peak_bytes: float
    theta: int = -1  # pipeline2 / hetero split point
    # -- heterogeneous (two-backend) pipeline metadata ------------------------
    # devices: per-stage device profile names (stage 0, stage 1); empty for
    #   single-device plans.  stage_times: steady-state per-stage seconds
    #   (compute only; the hand-off is xfer_seconds).  stage_peak_bytes /
    #   stage_memory: each stage's OWN peak and footprint — a stage needs
    #   only its own layers' memory, budgeted against its own device.
    #   xfer_bytes is the per-batch split-point activation (actual per-axis
    #   extents); the executor's measured hand-off bytes must reproduce it.
    devices: Tuple[str, ...] = ()
    stage_times: Tuple[float, ...] = ()
    stage_peak_bytes: Tuple[float, ...] = ()
    stage_memory: Tuple[MemoryFootprint, ...] = ()
    stage_ram_budgets: Tuple[Optional[float], ...] = ()
    xfer_bytes: float = 0.0
    xfer_seconds: float = 0.0
    # -- runtime metadata (volume tiler/executor contract) -------------------
    # fov:  sliding-window field of view of the net (1D extent, isotropic)
    # core: dense output voxels per axis each patch contributes (m · P)
    # sweep_axis: VOLUME axis the executor's sweep advances on (the tiler's
    #   working axis 0).  Chosen by the per-axis sweep-count argmax when the
    #   search runs sweep-aware with ``sweep_axis="auto"``; 0 otherwise.
    fov: int = 0
    core: int = 0
    sweep_axis: int = 0
    # -- sweep-aware pricing metadata ----------------------------------------
    # geometry: the PlanGeometry the layer costs were evaluated in (None:
    #   context-free local costing); sweep: the exact predicted sweep-level
    #   reuse counters (segment FFTs/hits, MAD segments, strip patches) the
    #   executor's last_stats must reproduce 1:1 for the target volume.
    geometry: Optional[PlanGeometry] = None
    sweep: Optional[object] = None  # volume.tiler.SweepCounts
    # -- memory model ---------------------------------------------------------
    # memory: predicted peak device working set (cost_model.MemoryFootprint).
    #   Sweep-aware plans under a ram_budget carry the exact streaming-
    #   schedule simulation (components at the peak step); other plans carry
    #   the analytic per-patch model.  ram_budget: the budget the plan was
    #   solved under (None = unconstrained); the executor switches to
    #   host-staged streaming when a plan carries one.
    memory: Optional[MemoryFootprint] = None
    ram_budget: Optional[float] = None

    @property
    def throughput(self) -> float:
        return self.out_voxels / self.total_time

    @property
    def prims(self) -> Tuple[str, ...]:
        """Per-layer primitive names, the executor's input."""
        return tuple(c.prim for c in self.choices)

    @property
    def uses_mpf(self) -> bool:
        return "mpf" in self.prims

    @property
    def overlap(self) -> int:
        """Input voxels shared between adjacent patches (FOV - 1)."""
        return self.fov - 1

    @property
    def patch_extent(self) -> int:
        """Input voxels per axis a patch must span to emit ``core`` dense
        outputs.  Equals ``n_in`` for MPF plans; plain-pool (baseline) plans
        need ``n_in + P - 1`` because the executor sweeps all P³ shifted
        subsamplings of the patch (the paper's naive outer loop)."""
        return self.core + self.fov - 1

    def summary(self) -> str:
        lines = [
            f"plan[{self.net}] strategy={self.strategy} chips={self.chips} "
            f"S={self.batch} n_in={self.n_in}^3 -> {self.throughput:,.0f} vox/s "
            f"peak={self.peak_bytes/2**30:.2f} GiB"
            + (f" theta={self.theta}" if self.theta >= 0 else "")
            + (
                f" devices=({self.devices[0]} | {self.devices[1]})"
                if len(self.devices) == 2
                else ""
            )
        ]
        for c in self.choices:
            S, f, n = c.in_shape
            lines.append(
                f"  L{c.index:<2d} {c.kind:<4s} {c.prim:<10s} "
                f"in=({S},{f},{n[0]}^3) t={c.time_s*1e3:8.3f} ms "
                f"mem={c.cost.peak_bytes/2**30:6.3f} GiB"
            )
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Sweep geometry: the execution context costs are evaluated in
# ---------------------------------------------------------------------------


def sweep_geometry(
    net: ConvNetConfig,
    m: int,
    volume_shape: Sequence[int],
    *,
    batch: int = 1,
    deep_reuse: bool = True,
    sweep_axis: int = 0,
):
    """``(PlanGeometry, SweepCounts)`` for sweeping ``volume_shape``.

    Builds the exact tiling the executor will run (core-pinned layer-0
    segment grid, sweep-major patch stream in chunks of ``batch``, the
    sweep advancing on VOLUME axis ``sweep_axis``) and simulates its
    caches, so the geometry carries the true sweep-average segment-FFT
    count per patch and the interior/edge patch mix — the context
    ``cost_model`` prices primitives in, and the predicted counters the
    executor's ``last_stats`` must match exactly.
    """
    from ..volume.tiler import (  # lazy: keep core importable without volume
        HaloSpec,
        predict_sweep_counts,
        tile_volume,
    )
    from .overlap_save import plan_overlap_save, tail_segments

    P = net.total_pooling()
    fov = net.field_of_view()
    core = m * P
    extent = core + fov - 1
    k0 = next(l.size for l in net.layers if l.kind == "conv")
    spec = plan_overlap_save((extent, extent, extent), (k0,) * 3, core)
    halo = HaloSpec(spec.seg_core, spec.seg_extent, spec.starts)
    tiling = tile_volume(
        tuple(volume_shape), core=core, fov=fov, halo=halo,
        sweep_axis=sweep_axis,
    )
    counts = predict_sweep_counts(
        tiling, batch=batch, deep_reuse=deep_reuse,
        strip_segments=tail_segments(spec, core),
    )
    n = tiling.n_patches
    plane = len({(p.start[1], p.start[2]) for p in tiling.patches})
    geom = PlanGeometry(
        core=core, fov=fov, batch=batch, n_patches=n,
        interior_frac=counts.strip_patches / n,
        seg_core=core, deep_reuse=deep_reuse,
        seg_fft_per_patch=counts.seg_fft / n,
        plane_patches=plane,
        sweep_axis=sweep_axis,
    )
    return geom, counts


def _axis_candidates(
    volume_shape: Sequence[int], sweep_axis
) -> Tuple[int, ...]:
    """Candidate sweep axes for the per-axis argmax.

    ``sweep_axis="auto"`` enumerates all three volume axes, deduplicated
    by the WORKING-frame shape they induce (``tiler.sweep_perm``): two
    axes whose permuted shapes coincide run the identical tiling and
    cache simulation, so only the lowest-numbered one is simulated — a
    cubic volume prices one candidate, a thin slab up to three.  An
    integer pins the axis (no search).
    """
    if sweep_axis != "auto":
        return (int(sweep_axis),)
    seen = {}
    for ax in range(3):
        work = tuple(
            volume_shape[a]
            for a in ((ax,) + tuple(b for b in range(3) if b != ax))
        )
        seen.setdefault(work, ax)
    return tuple(sorted(seen.values()))


def _layer_geom(
    geom: Optional[PlanGeometry], i: int, P_cur: int
) -> Optional[PlanGeometry]:
    """Per-layer geometry view: new x-columns at this layer = core/P_cur."""
    if geom is None:
        return None
    return geom.at_layer(i, new_x=geom.core // P_cur if P_cur else 0)


# ---------------------------------------------------------------------------
# The memory model: per-plan device working sets
# ---------------------------------------------------------------------------


def stream_unit_bytes(
    net: ConvNetConfig,
    prims: Sequence[str],
    m: int,
    *,
    deep_reuse: bool = True,
) -> dict:
    """Byte weights of the streaming executor's device-resident objects.

    Walks the net at fragment size ``m`` exactly as ``compile_plan`` +
    ``PlanExecutor._build_strip_plan`` do, and prices each object class
    analytically (float32/complex64 element counts — deterministic, no
    params needed):

    * ``state_bytes`` — raw conv params plus every cached kernel spectrum
      (full-walk shapes AND, under ``deep_reuse``, the strip-walk shapes);
    * ``seg_bytes`` — ONE cached layer-0 segment spectrum (the sweep
      cache's unit of account);
    * ``halo_entry_bytes`` — one patch's per-layer activation halos;
    * ``out_patch_bytes`` — one patch's dense core output;
    * ``span`` — axis-0 input voxels a staged slab must cover.

    ``PlanExecutor.predict_memory`` and ``plan_stream_memory`` both feed
    these into ``tiler.predict_stream_peak``, so the planner's prediction
    and the executor's measured ledger count the same objects.
    """
    from .overlap_save import plan_overlap_save  # lazy: imports pruned_fft
    from .primitives import plan_input_size
    from .pruned_fft import fft_optimal_shape

    prims = tuple(prims)
    P = net.total_pooling()
    core = m * P
    n_in = plan_input_size(net, prims, m)
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    out_ch = [l for l in net.layers if l.kind == "conv"][-1].out_channels
    seg_bytes = 0.0
    span = n_in
    state = 0.0
    halo_entry = 0.0
    n, f, P_cur, frag = n_in, net.in_channels, 1, 1
    for i, layer in enumerate(net.layers):
        if i > 0 and deep_reuse:
            # strip-walk geometry at this layer (PlanExecutor._build_strip_plan)
            new_x = core // P_cur
            h = layer.size - 1
            w_in = new_x + h
            halo_entry += frag * f * h * n * n * F32
            if layer.kind == "conv" and w_in <= n:
                fp = layer.out_channels
                if prims[i] == "fft_cached":
                    state += fp * f * _nt(fft_optimal_shape((w_in, n, n))) * C64
                elif prims[i] == "overlap_save":
                    sp = plan_overlap_save((w_in, n, n), (layer.size,) * 3, None)
                    state += fp * f * _nt(sp.fft_shape) * C64
        if layer.kind == "conv":
            fp, k = layer.out_channels, layer.size
            state += fp * f * k**3 * F32 + fp * F32  # raw weights + bias
            if prims[i] == "fft_cached":
                state += fp * f * _nt(fft_optimal_shape((n, n, n))) * C64
            elif prims[i] == "overlap_save":
                seg = core if i == first_conv else None
                sp = plan_overlap_save((n, n, n), (k,) * 3, seg)
                state += fp * f * _nt(sp.fft_shape) * C64
                if i == first_conv:
                    seg_bytes = f * _nt(sp.fft_shape) * C64
                    span = sp.span
            n = n - k + 1
            f = fp
        elif prims[i] == "mpf":
            n //= layer.size
            P_cur *= layer.size
            frag *= layer.size**3
        else:
            n //= layer.size
    return {
        "state_bytes": state,
        "seg_bytes": seg_bytes,
        "halo_entry_bytes": halo_entry if deep_reuse else 0.0,
        "out_patch_bytes": out_ch * float(core) ** 3 * F32,
        "span": span,
        "in_channels": net.in_channels,
        "extent": core + net.field_of_view() - 1,
    }


def plan_stream_memory(
    net: ConvNetConfig,
    prims: Sequence[str],
    m: int,
    volume_shape: Sequence[int],
    *,
    batch: int = 1,
    deep_reuse: bool = True,
    streaming: bool = True,
    sweep_axis: int = 0,
) -> MemoryFootprint:
    """Exact peak device working set for sweeping ``volume_shape``.

    Simulates the streaming executor's schedule over the concrete tiling
    (``tiler.predict_stream_peak``) with the analytic byte weights of
    ``stream_unit_bytes`` — the prediction ``Plan.memory`` carries and
    the executor's measured ``peak_device_bytes`` must land within 10%
    of.  ``streaming=False`` models the dense-materialized path (whole
    padded volume device-resident).  ``sweep_axis`` selects the volume
    axis the slab window advances on; the tiling's working-frame shape
    makes the slab/eviction formulas axis-generic automatically.
    """
    from ..volume.tiler import (  # lazy: keep core importable without volume
        HaloSpec,
        predict_stream_peak,
        tile_volume,
    )
    from .overlap_save import plan_overlap_save, tail_segments

    units = stream_unit_bytes(net, prims, m, deep_reuse=deep_reuse)
    P = net.total_pooling()
    fov = net.field_of_view()
    core = m * P
    extent = core + fov - 1
    k0 = next(l.size for l in net.layers if l.kind == "conv")
    spec = plan_overlap_save((extent, extent, extent), (k0,) * 3, core)
    halo = HaloSpec(spec.seg_core, spec.seg_extent, spec.starts)
    tiling = tile_volume(
        tuple(volume_shape), core=core, fov=fov, halo=halo,
        sweep_axis=sweep_axis,
    )
    padded = [x + p for x, p in zip(tiling.vol_shape, tiling.pad)]
    f0 = units["in_channels"]
    slab_bytes = f0 * spec.span * padded[1] * padded[2] * F32
    max_x0 = max(0, padded[0] - extent)
    x_ext = max(padded[0], max_x0 + spec.span)
    dense_vol_bytes = f0 * x_ext * padded[1] * padded[2] * F32
    peak = predict_stream_peak(
        tiling, batch=batch, deep_reuse=deep_reuse,
        strip_segments=tail_segments(spec, core),
        seg_bytes=units["seg_bytes"],
        halo_entry_bytes=units["halo_entry_bytes"],
        out_patch_bytes=units["out_patch_bytes"],
        slab_bytes=slab_bytes,
        base_bytes=units["state_bytes"],
        streaming=streaming,
        dense_vol_bytes=dense_vol_bytes,
    )
    return MemoryFootprint(
        input_bytes=peak.slab_bytes,
        output_bytes=peak.out_bytes,
        spectra_bytes=peak.base_bytes,
        scratch_bytes=peak.scratch_bytes,
        sweep_cache_bytes=peak.cache_bytes,
    )


def _plan_memory_analytic(
    choices: Sequence[LayerChoice],
) -> MemoryFootprint:
    """Per-patch device working set from the layer costs (no volume).

    Resident state (weights + cached spectra) and sweep caches sum over
    layers; the transient in/out/scratch working set is the worst single
    layer's — layers run one at a time, on top of all resident state.
    """
    mems = [c.cost.memory for c in choices if c.cost.memory is not None]
    if not mems:
        return MemoryFootprint()
    spectra = sum(mm.spectra_bytes for mm in mems)
    sweep = sum(mm.sweep_cache_bytes for mm in mems)
    worst = max(
        mems, key=lambda mm: mm.input_bytes + mm.output_bytes + mm.scratch_bytes
    )
    return MemoryFootprint(
        input_bytes=worst.input_bytes,
        output_bytes=worst.output_bytes,
        spectra_bytes=spectra,
        scratch_bytes=worst.scratch_bytes,
        sweep_cache_bytes=sweep,
    )


# ---------------------------------------------------------------------------
# Single-strategy layer walk
# ---------------------------------------------------------------------------


def _walk(
    net: ConvNetConfig,
    S: int,
    n_in: int,
    use_mpf: bool,
    hw: HardwareSpec,
    mem_budget: float,
    chips: int = 1,
    conv_prims: Sequence[str] = CONV_PRIMS,
    stream_collectives: bool = False,
    geom: Optional[PlanGeometry] = None,
    ram_budget: Optional[float] = None,
    m: int = 0,
    strategy: str = "",
    infeasible: Optional[List[InfeasiblePoint]] = None,
    device: str = "",
    partial: bool = False,
) -> Optional[List[LayerChoice]]:
    """Greedy per-layer fastest-feasible-primitive walk (§VI-A step 3).

    ``geom`` (when given) is the sweep geometry layer costs are evaluated
    in; the executor only realizes sweep reuse behind a first-layer
    ``overlap_save`` conv, so if the first conv chooses another primitive
    the remaining layers fall back to context-free costing.

    ``ram_budget`` adds the paper's RAM constraint: a primitive whose
    device working set (``LayerCost.memory``) does not fit is skipped —
    and recorded in ``infeasible`` instead of silently omitted — so a
    slower primitive can win the layer because the faster one's patch no
    longer fits (ZNNi §1's throughput argument).  ``device`` labels the
    rejections with the profile whose budget was exceeded.

    Returns None if some layer cannot fit the budgets with any primitive —
    unless ``partial``, where an infeasible layer becomes a ``None`` entry
    and the walk continues (the heterogeneous search needs per-layer
    feasibility: a layer too big for one device may run on the other).
    Geometry violations (MPF divisibility) still return None outright —
    they are device-independent.
    """
    if not use_mpf:
        geom = None  # plain-pool plans sweep subsamplings: no reuse grid
    choices: List[LayerChoice] = []
    S_cur, f_cur, n_cur = S, net.in_channels, n_in
    P_cur = 1
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")

    def _ram_ok(c: LayerCost, prim: str, i: int) -> bool:
        if ram_budget is None or c.memory is None:
            return True
        need = c.memory.device_bytes
        if need <= ram_budget:
            return True
        if infeasible is not None:
            infeasible.append(InfeasiblePoint(
                strategy, prim, m, S, i, "exceeds ram_budget",
                need, ram_budget, device,
            ))
        return False

    for i, layer in enumerate(net.layers):
        n3 = (n_cur,) * 3
        g = _layer_geom(geom, i, P_cur)
        if layer.kind == "conv":
            fp = layer.out_channels
            best: Optional[Tuple[float, str, LayerCost]] = None
            for prim in conv_prims:
                c = conv_cost(prim, S_cur, f_cur, fp, n3, layer.size, g)
                if not _ram_ok(c, prim, i):
                    continue
                if stream_collectives:
                    # sub-layer streaming: weights+spectra sharded over the
                    # mesh; each chip gathers its chunk once per layer.
                    coll = c.peak_bytes / chips * (chips - 1) / chips
                    c = dataclasses.replace(
                        c, peak_bytes=c.peak_bytes / chips, coll_bytes=coll
                    )
                if c.peak_bytes > mem_budget:
                    continue
                t = c.time(hw, chips)
                if best is None or t < best[0]:
                    best = (t, prim, c)
            n_next = n_cur - layer.size + 1
            if best is None:
                if not partial:
                    return None
                choices.append(None)  # layer infeasible here; shapes advance
                f_cur, n_cur = fp, n_next
                continue
            t, prim, c = best
            if i == first_conv and prim != "overlap_save":
                geom = None  # executor runs no sweep reuse behind this mix
            choices.append(
                LayerChoice(i, "conv", prim, (S_cur, f_cur, n3), (S_cur, fp, (n_next,) * 3), c, t)
            )
            f_cur, n_cur = fp, n_next
        else:
            p = layer.size
            if use_mpf:
                if (n_cur + 1) % p != 0:
                    return None
                n_next = n_cur // p
                S_next = S_cur * p**3
                c = mpf_cost(S_cur, f_cur, n3, p, g)
                if stream_collectives:
                    c = dataclasses.replace(
                        c, peak_bytes=c.peak_bytes / chips, coll_bytes=0.0
                    )
                if not _ram_ok(c, "mpf", i) or c.peak_bytes > mem_budget:
                    if not partial:
                        return None
                    choices.append(None)
                else:
                    t = c.time(hw, chips)
                    choices.append(
                        LayerChoice(i, "pool", "mpf", (S_cur, f_cur, n3), (S_next, f_cur, (n_next,) * 3), c, t)
                    )
                S_cur, n_cur = S_next, n_next
                P_cur *= p
            else:
                if n_cur % p != 0:
                    return None
                c = pool_cost(S_cur, f_cur, n3, p)
                if not _ram_ok(c, "pool", i) or c.peak_bytes > mem_budget:
                    if not partial:
                        return None
                    choices.append(None)
                else:
                    t = c.time(hw, chips)
                    choices.append(
                        LayerChoice(i, "pool", "pool", (S_cur, f_cur, n3), (S_cur, f_cur, (n_cur // p,) * 3), c, t)
                    )
                n_cur //= p
    return choices


def _n_in_for_m(net: ConvNetConfig, m: int, use_mpf: bool = True) -> int:
    if use_mpf:
        return net.valid_input_size(m)
    # plain pooling: n = p*m at each pool (no fragment offset slack)
    n = m
    for layer in reversed(net.layers):
        n = n + layer.size - 1 if layer.kind == "conv" else n * layer.size
    return n


def _out_voxels(net: ConvNetConfig, S: int, m: int, use_mpf: bool, n_in: int) -> float:
    if use_mpf:
        return S * float(m * net.total_pooling()) ** 3
    # naive baseline: one subsampling per call — the dense output requires
    # P³ independent passes, so the *effective* voxels per pass divide by P³.
    return S * float(m) ** 3


# ---------------------------------------------------------------------------
# Strategy searches
# ---------------------------------------------------------------------------


def plan_single(
    net: ConvNetConfig,
    hw: HardwareSpec,
    *,
    mem_bytes: Optional[float] = None,
    batches: Sequence[int] = (1, 2, 4),
    max_m: int = 48,
    use_mpf: bool = True,
    conv_prims: Sequence[str] = CONV_PRIMS,
    strategy_name: str = "single",
    chips: int = 1,
    stream_collectives: bool = False,
    volume_shape: Optional[Sequence[int]] = None,
    deep_reuse: bool = True,
    ram_budget: Optional[float] = None,
    infeasible: Optional[List[InfeasiblePoint]] = None,
    sweep_axis="auto",
) -> Optional[Plan]:
    """Best single-worker plan (the paper's CPU-only/GPU-only search).

    ``volume_shape`` switches on sweep-aware costing: every (S, m)
    candidate is priced in the ``PlanGeometry`` of actually sweeping that
    volume (core-pinned layer-0 segment grid, exact cache-simulated
    segment-FFT amortization, deep activation reuse when ``deep_reuse``),
    and the winning plan records the predicted sweep counters the
    executor must reproduce.  Without it the search is context-free, as
    before.

    ``sweep_axis`` extends the sweep-aware search across volume axes:
    ``"auto"`` (the default) re-runs the count simulation per candidate
    axis (``_axis_candidates`` — deduped by induced working shape) and
    keeps the throughput argmax, recorded on ``Plan.sweep_axis``; on
    anisotropic volumes the best axis maximizes interior strip patches
    per plane.  An integer pins the axis.

    ``ram_budget`` solves the paper's constrained optimization: each
    candidate's device working set (per-layer ``LayerCost.memory``, plus
    the plan-level combined footprint) must fit the budget; rejected
    (prim, patch) points are appended to ``infeasible`` with a reason
    rather than silently omitted.  The winning plan carries the budget
    and its predicted ``memory`` footprint — the executor runs such
    plans through host-staged streaming and pins its measured
    ``peak_device_bytes`` against the prediction.
    """
    mem = hw.hbm_bytes if mem_bytes is None else mem_bytes
    best: Optional[Plan] = None
    fov = net.field_of_view()
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    # the cache simulation only matters if the walk CAN choose the
    # reuse-capable mix; don't pay it when overlap_save is excluded
    sweep_aware = (
        volume_shape is not None and use_mpf and "overlap_save" in conv_prims
    )
    axes = _axis_candidates(volume_shape, sweep_axis) if sweep_aware else (0,)
    for S in batches:
        for m in range(1, max_m + 1):
            n_in = _n_in_for_m(net, m, use_mpf)
            if sweep_aware and min(volume_shape) < fov:
                continue  # no valid output for this volume at all
            for ax in axes:
                geom = counts = None
                if sweep_aware:
                    geom, counts = sweep_geometry(
                        net, m, volume_shape, batch=S, deep_reuse=deep_reuse,
                        sweep_axis=ax,
                    )
                choices = _walk(
                    net, S, n_in, use_mpf, hw, mem,
                    chips=chips, conv_prims=conv_prims,
                    stream_collectives=stream_collectives, geom=geom,
                    ram_budget=ram_budget, m=m, strategy=strategy_name,
                    infeasible=infeasible,
                )
                if choices is None:
                    continue
                os_mix = choices[first_conv].prim == "overlap_save"
                total = sum(c.time_s for c in choices)
                vox = _out_voxels(net, S, m, use_mpf, n_in)
                peak = max(c.cost.peak_bytes for c in choices)
                if os_mix and volume_shape is not None and ram_budget is not None:
                    # the exact streaming-schedule peak for THIS volume
                    memory = plan_stream_memory(
                        net, tuple(c.prim for c in choices), m, volume_shape,
                        batch=S, deep_reuse=deep_reuse, sweep_axis=ax,
                    )
                else:
                    memory = _plan_memory_analytic(choices)
                if ram_budget is not None and memory.device_bytes > ram_budget:
                    if infeasible is not None:
                        infeasible.append(InfeasiblePoint(
                            strategy_name, choices[first_conv].prim, m, S, -1,
                            "exceeds ram_budget", memory.device_bytes, ram_budget,
                        ))
                    continue
                plan = Plan(
                    net.name, strategy_name, chips, S, n_in, m,
                    tuple(choices), total, vox, peak,
                    fov=fov, core=m * net.total_pooling(),
                    sweep_axis=ax if os_mix else 0,
                    geometry=geom if os_mix else None,
                    sweep=counts if os_mix else None,
                    memory=memory, ram_budget=ram_budget,
                )
                if best is None or plan.throughput > best.throughput:
                    best = plan
                if not sweep_aware:
                    break  # axis cannot matter without a geometry
    return best


def plan_fixed(
    net: ConvNetConfig,
    hw: HardwareSpec,
    prims: Sequence[str],
    *,
    m: int,
    batch: int = 1,
    chips: int = 1,
    mem_bytes: Optional[float] = None,
    strategy_name: str = "fixed",
    volume_shape: Optional[Sequence[int]] = None,
    deep_reuse: bool = True,
    ram_budget: Optional[float] = None,
    infeasible: Optional[List[InfeasiblePoint]] = None,
    sweep_axis="auto",
) -> Optional[Plan]:
    """Price a FIXED per-layer primitive assignment (no search).

    The executor accepts explicit per-layer prims — including mixes the
    enumeration searches cannot express, e.g. ``overlap_save`` at the
    input layer (where the volume sweep can reuse segment spectra across
    patches) with ``fft_cached`` deeper.  This walks the same registry
    cost model over that assignment so such plans carry predicted
    throughput, peak bytes, and the runtime geometry metadata like any
    searched plan.  ``volume_shape`` prices the assignment in the sweep's
    ``PlanGeometry`` (exact cache-simulated amortization; only active for
    the reuse-capable mix — first conv ``overlap_save``, MPF pools) and
    records the predicted counters on ``Plan.sweep``; ``sweep_axis``
    (``"auto"`` = per-axis argmax over ``_axis_candidates``, or a pinned
    int) selects the volume axis the sweep advances on, recorded on
    ``Plan.sweep_axis``.  Raises ValueError on divisibility violations;
    returns None when some layer's peak exceeds the memory budget
    (default: one chip's HBM), the same feasibility rule every search
    applies.
    """
    mem = hw.hbm_bytes if mem_bytes is None else mem_bytes
    from .primitives import plan_input_size  # lazy: primitives imports us

    prims = tuple(prims)
    if len(prims) != len(net.layers):
        raise ValueError(f"{len(prims)} prims for {len(net.layers)} layers")
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    sweep_aware = (
        volume_shape is not None
        and prims[first_conv] == "overlap_save"
        and "mpf" in prims
    )
    axes = _axis_candidates(volume_shape, sweep_axis) if sweep_aware else (0,)
    n_in = plan_input_size(net, prims, m)
    best: Optional[Plan] = None
    for ax in axes:
        geom = counts = None
        if sweep_aware:
            geom, counts = sweep_geometry(
                net, m, volume_shape, batch=batch, deep_reuse=deep_reuse,
                sweep_axis=ax,
            )
        choices: List[LayerChoice] = []
        S_cur, f_cur, n_cur = batch, net.in_channels, n_in
        P_mpf = 1
        for i, layer in enumerate(net.layers):
            n3 = (n_cur,) * 3
            g = _layer_geom(geom, i, P_mpf)
            if layer.kind == "conv":
                fp = layer.out_channels
                c = conv_cost(prims[i], S_cur, f_cur, fp, n3, layer.size, g)
                n_next = n_cur - layer.size + 1
                choices.append(
                    LayerChoice(i, "conv", prims[i], (S_cur, f_cur, n3),
                                (S_cur, fp, (n_next,) * 3), c, c.time(hw, chips))
                )
                f_cur, n_cur = fp, n_next
            elif prims[i] == "mpf":
                if (n_cur + 1) % layer.size:
                    raise ValueError(f"layer {i}: MPF needs (n+1)%p==0, n={n_cur}")
                c = mpf_cost(S_cur, f_cur, n3, layer.size, g)
                n_next, S_next = n_cur // layer.size, S_cur * layer.size**3
                choices.append(
                    LayerChoice(i, "pool", "mpf", (S_cur, f_cur, n3),
                                (S_next, f_cur, (n_next,) * 3), c, c.time(hw, chips))
                )
                S_cur, n_cur = S_next, n_next
                P_mpf *= layer.size
            else:
                if prims[i] != "pool":
                    raise ValueError(
                        f"layer {i}: unknown pool primitive {prims[i]!r} "
                        "(expected 'mpf' or 'pool')"
                    )
                if n_cur % layer.size:
                    raise ValueError(f"layer {i}: plain pool needs n%p==0, n={n_cur}")
                c = pool_cost(S_cur, f_cur, n3, layer.size)
                choices.append(
                    LayerChoice(i, "pool", "pool", (S_cur, f_cur, n3),
                                (S_cur, f_cur, (n_cur // layer.size,) * 3), c,
                                c.time(hw, chips))
                )
                n_cur //= layer.size
        total = sum(c.time_s for c in choices)
        vox = batch * float(m * P_mpf) ** 3
        peak = max(c.cost.peak_bytes for c in choices)
        if peak > mem:
            continue
        if geom is not None and volume_shape is not None:
            # reuse-capable mix priced against a concrete volume: the memory
            # model is the streaming schedule's exact simulated peak (the
            # executor honors a carried ram_budget by streaming)
            memory = plan_stream_memory(
                net, prims, m, volume_shape, batch=batch,
                deep_reuse=deep_reuse, streaming=ram_budget is not None,
                sweep_axis=ax,
            )
        else:
            memory = _plan_memory_analytic(choices)
        if ram_budget is not None and memory.device_bytes > ram_budget:
            if infeasible is not None:
                infeasible.append(InfeasiblePoint(
                    strategy_name, prims[first_conv], m, batch, -1,
                    "exceeds ram_budget", memory.device_bytes, ram_budget,
                ))
            continue
        plan = Plan(
            net.name, strategy_name, chips, batch, n_in, m,
            tuple(choices), total, vox, peak,
            fov=net.field_of_view(), core=m * net.total_pooling(),
            sweep_axis=ax if sweep_aware else 0,
            geometry=geom, sweep=counts,
            memory=memory, ram_budget=ram_budget,
        )
        if best is None or plan.throughput > best.throughput:
            best = plan
    return best


def plan_streamed(
    net: ConvNetConfig,
    hw: HardwareSpec,
    *,
    chips: int,
    batches: Sequence[int] = (1, 2, 4),
    max_m: int = 64,
) -> Optional[Plan]:
    """ZNNi GPU+host-RAM analogue: budget = aggregate HBM, ICI streaming."""
    return plan_single(
        net, hw,
        mem_bytes=hw.hbm_bytes * chips,
        batches=batches, max_m=max_m,
        strategy_name="streamed", chips=chips, stream_collectives=True,
    )


def plan_pipeline2(
    net: ConvNetConfig,
    hw: HardwareSpec,
    *,
    chips_per_stage: int,
    batches: Sequence[int] = (1,),
    max_m: int = 64,
) -> Optional[Plan]:
    """ZNNi CPU-GPU pipeline: split at θ, steady-state time = max stage time.

    Queue depth 1 (paper §VII-C): producer stalls until consumer drains, so
    steady-state throughput is out_voxels / max(stage_time) and each stage
    needs only its own layers' memory.  Degenerate case of ``plan_hetero``
    with two identical profiles (same split search, stage times, and
    hand-off pricing — ``host_link_bw(hw, hw) == hw.ici_bw``).
    """
    return plan_hetero(
        net, (hw, hw), chips_per_stage=chips_per_stage,
        batches=batches, max_m=max_m, strategy_name="pipeline2",
    )


def plan_hetero(
    net: ConvNetConfig,
    devices: Sequence[HardwareSpec],
    *,
    chips_per_stage: int = 1,
    batches: Sequence[int] = (1,),
    max_m: int = 64,
    ram_budgets: Optional[Sequence[Optional[float]]] = None,
    strategy_name: str = "hetero",
    infeasible: Optional[List[InfeasiblePoint]] = None,
) -> Optional[Plan]:
    """ZNNi's headline CPU+GPU split over a *set* of device profiles (§VII).

    Searches layer→device splits θ where stage 0 (layers ``[:θ]``) is
    priced on one profile and stage 1 (layers ``[θ:]``) on the other;
    both stage orders are tried when the profiles differ.  Steady-state
    time = max of the per-stage times + the split-point activation
    hand-off, priced at actual per-axis extents over the slower of the
    two devices' host links (``cost_model.split_transfer_cost``); the
    winning plan records the per-stage predictions the two-backend
    executor must reproduce (``stage_times``, ``xfer_bytes``,
    ``xfer_seconds``).

    Memory is budgeted **per device**: each stage's layer walk runs
    against its own profile's HBM (a layer too big for one device may
    still land on the other), per-stage peaks and analytic footprints
    are recorded on the plan (``stage_peak_bytes``, ``stage_memory``),
    and optional per-device ``ram_budgets`` reject stages whose working
    set does not fit — recorded in ``infeasible`` per (device, prim,
    patch) rather than silently dropped.
    """
    if len(devices) != 2:
        raise ValueError(f"plan_hetero needs exactly 2 device profiles, got {len(devices)}")
    if ram_budgets is None:
        ram_budgets = (None, None)
    best: Optional[Plan] = None
    L = len(net.layers)
    orders = [(0, 1)] if devices[0] == devices[1] else [(0, 1), (1, 0)]
    for S in batches:
        for m in range(1, max_m + 1):
            n_in = _n_in_for_m(net, m)
            walks = []
            for hw_d, ram_d in zip(devices, ram_budgets):
                walks.append(_walk(
                    net, S, n_in, True, hw_d,
                    hw_d.hbm_bytes * chips_per_stage,
                    chips=chips_per_stage, stream_collectives=True,
                    ram_budget=ram_d, m=m, strategy=strategy_name,
                    infeasible=infeasible, device=hw_d.name, partial=True,
                ))
            if any(w is None for w in walks):
                continue  # geometry violation: device-independent
            vox = _out_voxels(net, S, m, True, n_in)
            for a, b in orders:
                hw_a, hw_b = devices[a], devices[b]
                c_a, c_b = walks[a], walks[b]
                for theta in range(1, L):
                    stage0, stage1 = c_a[:theta], c_b[theta:]
                    if any(c is None for c in stage0) or any(c is None for c in stage1):
                        continue  # some layer does not fit its stage's device
                    t0 = sum(c.time_s for c in stage0)
                    t1 = sum(c.time_s for c in stage1)
                    # split-point activation hand-off through host RAM
                    # (shape chain is hardware-independent: c_a == c_b here)
                    S_t, f_t, n_t = c_b[theta].in_shape
                    xfer_bytes, xfer_s = split_transfer_cost(
                        S_t, f_t, n_t, hw_a, hw_b, chips_per_stage
                    )
                    peaks = (
                        max(c.cost.peak_bytes for c in stage0),
                        max(c.cost.peak_bytes for c in stage1),
                    )
                    mems = (
                        _plan_memory_analytic(stage0),
                        _plan_memory_analytic(stage1),
                    )
                    budgets = (ram_budgets[a], ram_budgets[b])
                    ok = True
                    for (hw_d, mem_d, bud_d) in zip((hw_a, hw_b), mems, budgets):
                        if bud_d is not None and mem_d.device_bytes > bud_d:
                            if infeasible is not None:
                                infeasible.append(InfeasiblePoint(
                                    strategy_name, stage0[0].prim, m, S, -1,
                                    "exceeds ram_budget", mem_d.device_bytes,
                                    bud_d, hw_d.name,
                                ))
                            ok = False
                    if not ok:
                        continue
                    stage = steady_state_time(t0, t1, xfer_s)
                    # plan.memory = the worse stage's footprint (each device
                    # holds only its own stage; the old all-layers aggregate
                    # double-counted across the split)
                    worst = max(mems, key=lambda mm: mm.device_bytes)
                    plan = Plan(
                        net.name, strategy_name, 2 * chips_per_stage, S, n_in, m,
                        tuple(stage0) + tuple(stage1), stage, vox,
                        max(peaks), theta=theta,
                        devices=(hw_a.name, hw_b.name),
                        stage_times=(t0, t1),
                        stage_peak_bytes=peaks,
                        stage_memory=mems,
                        stage_ram_budgets=budgets,
                        xfer_bytes=xfer_bytes, xfer_seconds=xfer_s,
                        fov=net.field_of_view(), core=m * net.total_pooling(),
                        memory=worst,
                    )
                    if best is None or plan.throughput > best.throughput:
                        best = plan
    return best


def spatial_halo_bytes(S: int, f: int, n: Sequence[int], k: int) -> float:
    """Halo-exchange bytes for one conv layer of a spatially sharded patch.

    Two faces per axis, each face = product of the OTHER two axes' extents
    (not ``n[0]**2`` — anisotropic patches have three distinct face areas),
    times the halo depth (k-1), channels, and batch.
    """
    faces = 2 * (n[1] * n[2] + n[0] * n[2] + n[0] * n[1])
    return float(faces) * (k - 1) * f * S * F32


def plan_spatial(
    net: ConvNetConfig,
    hw: HardwareSpec,
    *,
    chips: int,
    batches: Sequence[int] = (1,),
    max_m: int = 48,
) -> Optional[Plan]:
    """Beyond-paper: one volume sharded spatially with halo exchange.

    Each chip holds an m-parameterized patch; halos of (FOV-1)/2 are
    exchanged instead of recomputed, so border waste is paid in ICI bytes
    (surface * depth) rather than FLOPs.
    """
    best: Optional[Plan] = None
    for S in batches:
        for m in range(1, max_m + 1):
            n_in = _n_in_for_m(net, m)
            choices = _walk(net, S, n_in, True, hw, hw.hbm_bytes, chips=1)
            if choices is None:
                continue
            total = sum(c.time_s for c in choices)
            # halo bytes per layer: 2 faces per axis * halo depth * f * 4B
            halo_t = 0.0
            for c in choices:
                if c.kind != "conv":
                    continue
                S_c, f_c, n_c = c.in_shape
                k = net.layers[c.index].size
                halo_t += spatial_halo_bytes(S_c, f_c, n_c, k) / hw.ici_bw
            total = total + halo_t
            # all chips advance in lockstep: per-patch time is `total`, and
            # the mesh completes `chips` patches worth of output per step.
            vox = chips * _out_voxels(net, S, m, True, n_in)
            peak = max(c.cost.peak_bytes for c in choices)
            plan = Plan(
                net.name, "spatial", chips, S, n_in, m,
                tuple(choices), total, vox, peak,
                fov=net.field_of_view(), core=m * net.total_pooling(),
                memory=_plan_memory_analytic(choices),
            )
            if best is None or plan.throughput > best.throughput:
                best = plan
    return best


def plan_all_strategies(
    net: ConvNetConfig,
    hw: Optional[HardwareSpec] = None,
    *,
    devices: Optional[Sequence[HardwareSpec]] = None,
    chips: int = 256,
    volume_shape: Optional[Sequence[int]] = None,
    ram_budget: Optional[float] = None,
    sweep_axis="auto",
) -> dict:
    """All strategy searches; ``volume_shape`` makes the single-worker
    search sweep-aware (the multi-chip strategies execute through other
    schedules and keep context-free costing).  ``sweep_axis`` is passed
    through to the sweep-aware ``single`` search (``"auto"`` = per-axis
    argmax; an int pins the sweep axis).

    ``devices`` — a pair of ``HardwareSpec`` profiles, e.g.
    ``hw.PAPER_MACHINES`` — adds a ``"hetero"`` entry: the two-backend
    split search (``plan_hetero``) with stage 0 priced on one profile and
    stage 1 on the other, memory budgeted per device.  When ``hw`` is
    omitted the single-device searches run on ``devices[-1]`` (the
    accelerator of the pair).

    ``ram_budget`` constrains the single-host searches (``single``,
    ``baseline_naive``, ``direct_only``) to the paper's RAM envelope; the
    multi-chip strategies keep their own aggregate-HBM envelopes.  The
    returned dict always contains an extra ``"infeasible"`` key: the
    tuple of (prim, patch-size) points the budget rejected, each with a
    reason and the device whose budget rejected it — benchmark tables
    stay rectangular, and the budget where a faster primitive stops
    fitting (so a slower one wins) is visible.
    """
    if hw is None:
        if devices is None:
            raise ValueError("plan_all_strategies needs `hw`, `devices`, or both")
        hw = devices[-1]
    infeasible: List[InfeasiblePoint] = []
    out = {
        "single": plan_single(
            net, hw, volume_shape=volume_shape,
            ram_budget=ram_budget, infeasible=infeasible,
            sweep_axis=sweep_axis,
        ),
        "streamed": plan_streamed(net, hw, chips=chips),
        "pipeline2": plan_pipeline2(net, hw, chips_per_stage=chips // 2),
        "spatial": plan_spatial(net, hw, chips=chips),
        "baseline_naive": plan_single(
            net, hw, use_mpf=False, strategy_name="baseline_naive",
            ram_budget=ram_budget, infeasible=infeasible,
        ),
        "direct_only": plan_single(
            net, hw, conv_prims=("direct",), strategy_name="direct_only",
            ram_budget=ram_budget, infeasible=infeasible,
        ),
    }
    if devices is not None:
        out["hetero"] = plan_hetero(
            net, tuple(devices), chips_per_stage=1, infeasible=infeasible,
        )
    out["infeasible"] = tuple(infeasible)
    return out
