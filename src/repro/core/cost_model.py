"""Analytic layer cost model (ZNNi Tables I & II, adapted to the TPU model).

Units: FLOPs, bytes.  All formulas are per *layer invocation* on a batch of
S inputs of f images sized n³ (isotropic shorthand; tuples accepted).

The paper's Table I counts one multiply-add as one operation for direct
convolution and uses `C n log n` for FFT passes; we count 2 FLOPs per MAC
and use C≈5 (split-radix), so absolute numbers differ from the paper by a
constant factor while all *ratios* (the paper's actual claims) match.

Table II's memory maxima are reproduced per-primitive as the max live bytes
of each execution stage of OUR implementations (which stage the same way:
input spectra → MAD per output-channel chunk → inverse).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

from .hw import HardwareSpec
from .pruned_fft import fft_optimal_shape, pruned_fft_flops

F32 = 4
C64 = 8


def _vol(n: Sequence[int]) -> int:
    v = 1
    for x in n:
        v *= int(x)
    return v


def _nt(fft_shape: Sequence[int]) -> int:
    """Complex elements in an rfftn spectrum of this FFT shape."""
    na, nb, nc = fft_shape
    return na * nb * (nc // 2 + 1)


@dataclass(frozen=True)
class LayerCost:
    flops: float  # arithmetic work
    hbm_bytes: float  # streamed bytes (roofline memory term)
    peak_bytes: float  # peak live memory (Table II analogue)
    coll_bytes: float = 0.0  # inter-chip bytes (streamed/spatial modes)

    def time(self, hw: HardwareSpec, chips: int = 1) -> float:
        compute = self.flops / (chips * hw.peak_flops)
        memory = self.hbm_bytes / (chips * hw.hbm_bw)
        coll = self.coll_bytes / (chips * hw.ici_bw)
        return max(compute, memory) + coll


# ---------------------------------------------------------------------------
# Convolutional layer primitives
# ---------------------------------------------------------------------------


def conv_direct_cost(S: int, f: int, fp: int, n: Tuple[int, ...], k: int) -> LayerCost:
    npr = tuple(x - k + 1 for x in n)
    flops = 2.0 * S * fp * f * _vol(npr) * k**3  # Table I: S f' f n'³ k³ MACs
    w_bytes = fp * f * k**3 * F32
    io = (S * f * _vol(n) + S * fp * _vol(npr)) * F32
    # each output tile re-reads its input halo once; weights re-read per tile
    hbm = io + w_bytes
    peak = io + w_bytes
    return LayerCost(flops, hbm, peak)


def _fft_common(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> Tuple[Tuple[int, ...], int, int, int, float, float, float]:
    fft_shape = fft_optimal_shape(n)
    nt = _nt(fft_shape)
    vol_n, vol_np = _vol(n), _vol(tuple(x - k + 1 for x in n))
    img_fft = S * f * pruned_fft_flops(n, fft_shape)
    ker_fft = fp * f * pruned_fft_flops((k, k, k), fft_shape)
    inv_fft = S * fp * pruned_fft_flops(tuple(x - k + 1 for x in n), fft_shape)
    # complex MAC = 4 real mult + 4 add = 8 flops per element (3-mult Karatsuba
    # in the Pallas kernel: 3 mult + 5 add); model at 8 (paper Table I: 4 S f' f ñ)
    mad = 8.0 * S * fp * f * nt
    return fft_shape, nt, vol_n, vol_np, img_fft + inv_fft, ker_fft, mad


def conv_fft_data_parallel_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    """Table II "FFT algorithm 1" (data parallel, Alg. 2): one kernel-spectrum
    buffer and one output-channel spectrum column live at a time."""
    fft_shape, nt, vol_n, vol_np, img_fft, ker_fft, mad = _fft_common(S, f, fp, n, k)
    flops = img_fft + ker_fft + mad
    stage_in = S * f * (vol_n * F32 + nt * C64)
    stage_mad = (S * f + S + 1) * nt * C64 + S * fp * vol_np * F32
    peak = max(stage_in, stage_mad)
    # streamed bytes: X spectra re-read once per output channel (the price of
    # the single-buffer discipline), kernels/outputs touched once.
    hbm = (
        S * f * vol_n * F32
        + S * f * nt * C64 * (1 + fp)  # write once, read per output channel
        + fp * f * (k**3) * F32
        + fp * f * nt * C64
        + 2 * S * fp * nt * C64
        + S * fp * vol_np * F32
    )
    return LayerCost(flops, hbm, peak)


# number of concurrently-live kernel-spectrum buffers in the task-parallel
# variant (the paper's T = one per primary thread; ours = spectra chunk).
TASK_T = 8


def conv_fft_task_parallel_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    """Table II "FFT algorithm 2" (task parallel): ALL input and output
    spectra live at once — max{S f (n+ñ), S (f+f') ñ + T ñ, S f' (n'+ñ)} —
    kernel spectra only T at a time.  Every spectrum is touched once: the
    fused MAD reads X once while streaming kernel chunks (the paper's
    "higher cache locality"; on TPU: one pass over HBM)."""
    fft_shape, nt, vol_n, vol_np, img_fft, ker_fft, mad = _fft_common(S, f, fp, n, k)
    flops = img_fft + ker_fft + mad
    peak = max(
        S * f * (vol_n * F32 + nt * C64),
        (S * (f + fp) + TASK_T) * nt * C64,
        S * fp * (vol_np * F32 + nt * C64),
    )
    hbm = (
        S * f * vol_n * F32
        + 2 * S * f * nt * C64
        + fp * f * (k**3) * F32
        + fp * f * nt * C64
        + 2 * S * fp * nt * C64
        + S * fp * vol_np * F32
    )
    return LayerCost(flops, hbm, peak)


def conv_fft_cached_kernels_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    """Task-parallel with kernel spectra precomputed once per *plan*, not
    per patch (beyond-paper: cross-patch kernel-spectrum reuse; executed by
    ``primitives.compile_plan`` setup).  Per-call cost drops both the kernel
    FFT flops and the raw kernel-weights HBM read (spectra are resident,
    the f'·f·k³ weights are never re-read at run time); spectra storage is
    still charged to peak."""
    c = conv_fft_task_parallel_cost(S, f, fp, n, k)
    fft_shape = fft_optimal_shape(n)
    ker_fft = fp * f * pruned_fft_flops((k, k, k), fft_shape)
    w_bytes = fp * f * k**3 * F32
    return LayerCost(c.flops - ker_fft, c.hbm_bytes - w_bytes, c.peak_bytes)


def conv_overlap_save_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    """Overlap-save: segmented small FFTs + cross-patch input-spectra reuse.

    The input is segmented along axis 0 into windows of ``seg_core + k - 1``
    voxels stepping by ``seg_core`` (``core.overlap_save``); kernel spectra
    are cached at setup like ``fft_cached``.  Two departures from the
    task-parallel model:

    * input-FFT work is priced at *core voxels only* — n'/seg_core
      (fractional) segment transforms instead of the ceil'd segment count,
      because segments shared with the adjacent patch come from the
      executor's sweep cache rather than being recomputed;
    * peak memory holds ONE segment's input/output spectra (plus the
      resident kernel-spectra grid and the dense in/out tensors) — the
      paper's Table-II overhead shrinks by ~seg_extent/n, which is what
      lets larger patches fit the budget (ZNNi's condition for FFT
      dominance).

    The MAD and inverse-FFT terms keep the full (ceil'd, overlapped)
    segment count — that recompute is genuinely paid per patch.

    Known approximations (ROADMAP open item: thread plan geometry into
    primitive costs):

    * this prices the primitive's *default* local grid
      (``overlap_save.cost_spec``); the volume executor pins the LAYER-0
      grid to the patch core instead (``compile_plan(overlap_seg=core)``),
      which the ``cost(S, f, fp, n, k)`` signature cannot see;
    * the amortized input-FFT term assumes the executor's sweep cache is
      actually reusing spectra — true for a first-layer assignment under a
      volume sweep, optimistic for deeper layers and one-shot
      ``conv_apply`` calls, which recompute every (ceil'd, overlapped)
      segment per call;
    * the one-live-output-column peak term relies on XLA freeing each
      segment's output spectra after its inverse (in-order per-segment
      chain in ``os_apply_from_spectra``); a scheduler that overlapped
      segments could hold up to n_seg columns.
    """
    from .overlap_save import cost_spec  # lazy: overlap_save imports pruned_fft

    spec = cost_spec(n, k)
    nt = _nt(spec.fft_shape)
    n_seg = spec.n_segments
    npr = tuple(x - k + 1 for x in n)
    vol_n, vol_np = _vol(n), _vol(npr)
    seg_in = (spec.seg_extent, n[1], n[2])
    seg_out = (spec.seg_core, npr[1], npr[2])
    amort_segs = npr[0] / spec.seg_core  # each core voxel transformed once
    img_fft = S * f * amort_segs * pruned_fft_flops(seg_in, spec.fft_shape)
    inv_fft = S * fp * n_seg * pruned_fft_flops(seg_out, spec.fft_shape)
    mad = 8.0 * S * fp * f * nt * n_seg
    flops = img_fft + inv_fft + mad  # kernel FFT amortized at setup
    hbm = (
        S * f * vol_n * F32  # input streamed once
        + S * f * nt * C64 * (amort_segs + n_seg)  # write amortized, read per MAD
        + fp * f * nt * C64  # resident kernel spectra re-read
        + 2 * S * fp * nt * C64 * n_seg  # output spectra write + inverse read
        + S * fp * vol_np * F32
    )
    # Stage maxima matching the implementation's staging: ALL input
    # segment spectra are live (n_seg·ñ — they are the cross-patch reuse
    # currency), while the MAD + inverse form an unrolled per-segment
    # chain whose buffer liveness frees each output-spectra column after
    # its inverse (``os_apply_from_spectra``), so ~ONE column is charged —
    # the paper's staged-memory discipline, by graph staging rather than
    # hard sequencing (third known approximation above).  Output-side
    # spectra shrink by ~seg_extent/n versus the task-parallel model
    # (kernel-spectra residency not charged, per the fft_cached
    # convention; T live kernel buffers are).
    peak = max(
        S * f * (vol_n * F32 + n_seg * nt * C64),  # dense input + all seg spectra
        (S * (n_seg * f + fp) + TASK_T) * nt * C64
        + S * fp * vol_np * F32,  # MAD: one output column + dense accumulator
        S * fp * (vol_np * F32 + nt * C64),  # inverse + dense output
    )
    return LayerCost(flops, hbm, peak)


# ---------------------------------------------------------------------------
# Pooling primitives
# ---------------------------------------------------------------------------


def pool_cost(S: int, f: int, n: Tuple[int, ...], p: int) -> LayerCost:
    vol = _vol(n)
    flops = 1.0 * S * f * vol  # Table I: S f n³ comparisons
    hbm = 2 * S * f * vol * F32
    return LayerCost(flops, hbm, hbm)


def mpf_cost(S: int, f: int, n: Tuple[int, ...], p: int) -> LayerCost:
    vol = _vol(n)
    flops = 1.0 * S * f * vol * p**3  # Table I: S f n³ p³
    m3 = _vol(tuple(x // p for x in n)) * p**3
    hbm = (S * f * vol + S * f * m3) * F32
    return LayerCost(flops, hbm, hbm)


# ---------------------------------------------------------------------------
# Canonical primitive names (the planner's enumeration order)
# ---------------------------------------------------------------------------
#
# Name *interpretation* — mapping a name to cost/setup/apply code — lives in
# one place only: the ``core.primitives`` registry, which must stay in 1:1
# correspondence with these tuples (test_planner_invariants asserts it).

CONV_PRIMS = ("direct", "fft_data", "fft_task", "fft_cached", "overlap_save")
POOL_PRIMS = ("mpf", "pool")


def conv_cost(prim: str, S: int, f: int, fp: int, n: Tuple[int, ...], k: int) -> LayerCost:
    """Cost of a conv primitive by name, via the runtime registry."""
    from .primitives import conv_primitive  # lazy: primitives imports us

    return conv_primitive(prim).cost(S, f, fp, n, k)


def pool_cost_by_name(prim: str, S: int, f: int, n: Tuple[int, ...], p: int) -> LayerCost:
    """Cost of a pool primitive by name, via the runtime registry."""
    from .primitives import pool_primitive

    return pool_primitive(prim).cost(S, f, n, p)
