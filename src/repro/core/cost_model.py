"""Analytic layer cost model (ZNNi Tables I & II, adapted to the TPU model).

Units: FLOPs, bytes.  All formulas are per *layer invocation* on a batch of
S inputs of f images sized n³ (isotropic shorthand; tuples accepted).

The paper's Table I counts one multiply-add as one operation for direct
convolution and uses `C n log n` for FFT passes; we count 2 FLOPs per MAC
and use C≈5 (split-radix), so absolute numbers differ from the paper by a
constant factor while all *ratios* (the paper's actual claims) match.

Table II's memory maxima are reproduced per-primitive as the max live bytes
of each execution stage of OUR implementations (which stage the same way:
input spectra → MAD per output-channel chunk → inverse).

Every cost function takes an optional ``PlanGeometry`` context — the
execution geometry (patch core, sweep patch count, interior/edge mix,
layer-0 segment grid, deep activation reuse) the executor will actually
run.  ``PlanGeometry.local()`` (the default) prices the primitive
self-contained; the planner passes sweep geometries so plans are priced
against sweep-level amortization, ZNNi's actual throughput argument.

Alongside time, every cost carries a ``MemoryFootprint`` on
``LayerCost.memory`` — the decomposed device working set (input/output/
resident-spectra/scratch bytes per patch, plus sweep-cache bytes sized
from the geometry's ``plane_patches``).  This is the RAM axis of the
paper's constrained optimization: the planner's ``ram_budget`` search
rejects (prim, patch) points whose footprint does not fit (see
docs/architecture.md, "Memory model & streaming").
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

from .hw import HardwareSpec
from .pruned_fft import fft_optimal_shape, pruned_fft_flops

F32 = 4
C64 = 8


# ---------------------------------------------------------------------------
# PlanGeometry: the execution-geometry context a cost is evaluated in
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanGeometry:
    """Execution geometry a sweep-aware cost function may price against.

    ZNNi's lesson is that throughput is decided by amortization across the
    whole sweep, not per-patch FLOPs — so a primitive's cost depends on
    *how the executor will run it*: the patch core the layer-0 segment
    grid is pinned to, how many patches the sweep has, what fraction of
    them are interior (and therefore served by the cross-patch caches),
    and whether deep activation reuse shrinks deeper layers to strips.

    ``PlanGeometry.local()`` is the no-context default: standalone costing
    (one-shot ``conv_apply``, Table I/II benchmarks) prices the primitive
    self-contained, with every transform paid per call.  The planner
    builds sweep geometries via ``planner.sweep_geometry`` (which
    simulates the executor's caches over a concrete tiling, so predicted
    sweep counters match measured ones exactly).

    What a cost function may assume (the contract, see
    docs/architecture.md):

    * ``core``/``fov`` describe the patch grid; ``core == 0`` means "no
      sweep context" (``is_sweep`` is False) and every sweep field must be
      ignored.
    * ``seg_core`` (layer 0 only): the executor pins the layer-0
      overlap-save segment grid to this stride — a cost function must
      price THAT grid, not its local default.
    * ``interior_frac`` of the sweep's patches are interior (strip-path)
      patches; per-patch costs are sweep averages over interior and edge
      patches.
    * ``seg_fft_per_patch`` (>= 0 when provided) is the exact
      sweep-average input-segment-FFT count per patch from the cache
      simulation; a cost function must prefer it over re-deriving.
    * ``layer``/``new_x`` are per-layer: ``new_x`` is the newly computed
      x-columns at this layer for an interior patch (0: no strip at this
      layer); deeper layers under ``deep_reuse`` price interior patches
      at extent ``new_x + k - 1`` instead of the full patch extent.
    """

    core: int = 0
    fov: int = 0
    batch: int = 1
    n_patches: int = 1
    interior_frac: float = 0.0
    seg_core: int = 0
    deep_reuse: bool = False
    layer: int = -1
    new_x: int = 0
    seg_fft_per_patch: float = -1.0
    # patches per sweep plane (the two cross-axis start counts multiplied):
    # sizes the sweep-resident caches — each cross-axis patch row keeps its
    # own segment spectra and activation halos alive across plane steps.
    # 0 = unknown (cost functions must then charge no sweep-cache bytes).
    plane_patches: int = 0
    # volume axis the sweep advances on (tiler working axis 0).  Purely
    # descriptive for cost functions — per-patch work is axis-symmetric
    # (cubic patches/kernels) — but the sweep counters above were simulated
    # on THIS axis, and the executor must run the same one to match them.
    sweep_axis: int = 0

    @classmethod
    def local(cls) -> "PlanGeometry":
        """The no-context default: price the primitive self-contained."""
        return _LOCAL_GEOMETRY

    @property
    def is_sweep(self) -> bool:
        return self.core > 0

    def at_layer(self, index: int, *, new_x: int = 0) -> "PlanGeometry":
        """Per-layer view: tag the layer index and its strip width."""
        return dataclasses.replace(self, layer=index, new_x=new_x)


_LOCAL_GEOMETRY = PlanGeometry()


# ---------------------------------------------------------------------------
# MemoryFootprint: the device working set a primitive needs to run
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MemoryFootprint:
    """Peak device working-set estimate, decomposed (bytes).

    ZNNi's constrained optimization is over RAM, not time: "an apparently
    slower algorithm may end up having higher throughput if it can process
    a larger image within the constraint of the available RAM" (§1).  This
    is the RAM side of every ``LayerCost``: what must be device-resident
    for the primitive to run one (batch of) patch(es).

    * ``input_bytes`` / ``output_bytes`` — the dense layer input/output
      tensors for the batch.
    * ``spectra_bytes`` — *resident* prepared state: raw weights/biases
      plus cached kernel spectra (``fft_cached``, ``overlap_save``) that
      live for the whole plan, not per call.
    * ``scratch_bytes`` — transient per-call transform working set (input
      /output spectra stages, Table II's overhead beyond in/out/state).
    * ``sweep_cache_bytes`` — sweep-resident reuse caches priced from the
      ``PlanGeometry``: layer-0 segment spectra kept across x-planes and
      per-layer activation halos under deep reuse.  0 without a sweep
      context (``PlanGeometry.local()``) or when ``plane_patches`` is
      unknown.

    Plan-level footprints (``planner``): components are *at the peak
    step* of the executor's schedule, so ``device_bytes`` is the peak
    itself, not an independent-maxima overestimate.
    """

    input_bytes: float = 0.0
    output_bytes: float = 0.0
    spectra_bytes: float = 0.0
    scratch_bytes: float = 0.0
    sweep_cache_bytes: float = 0.0

    @property
    def device_bytes(self) -> float:
        """The budget axis: total peak device working set."""
        return (
            self.input_bytes
            + self.output_bytes
            + self.spectra_bytes
            + self.scratch_bytes
            + self.sweep_cache_bytes
        )

    def worst(self, other: "MemoryFootprint") -> "MemoryFootprint":
        """Component-wise max: a footprint that fits the worst patch."""
        return MemoryFootprint(
            max(self.input_bytes, other.input_bytes),
            max(self.output_bytes, other.output_bytes),
            max(self.spectra_bytes, other.spectra_bytes),
            max(self.scratch_bytes, other.scratch_bytes),
            max(self.sweep_cache_bytes, other.sweep_cache_bytes),
        )


def _footprint(
    inp: float, out: float, resident: float, peak: float, sweep: float = 0.0
) -> MemoryFootprint:
    """Footprint from a primitive's stage peak: whatever the peak needs
    beyond the dense in/out tensors and the resident state is scratch."""
    return MemoryFootprint(
        inp, out, resident, max(0.0, peak - inp - out - resident), sweep
    )


def _halo_sweep_bytes(
    S: int, f: int, n: Tuple[int, ...], size: int, geom: Optional[PlanGeometry]
) -> float:
    """Sweep-resident activation-halo bytes this layer contributes.

    Under deep reuse every patch caches the trailing ``size - 1``
    x-columns of this layer's input for its x-successor; entries for two
    x-planes are live at once (the plane being consumed — evicted only at
    the next plane's first chunk — plus the freshly stored one).
    ``S / geom.batch`` is the per-patch fragment expansion.
    """
    if (
        geom is None
        or not (geom.is_sweep and geom.deep_reuse)
        or geom.layer <= 0
        or geom.plane_patches <= 0
    ):
        return 0.0
    per_patch = (S / max(1, geom.batch)) * f * (size - 1) * n[1] * n[2] * F32
    return 2.0 * geom.plane_patches * per_patch


def _with_sweep_cache(c: "LayerCost", extra: float) -> "LayerCost":
    if extra <= 0.0:
        return c
    m = c.memory if c.memory is not None else MemoryFootprint()
    return dataclasses.replace(
        c,
        memory=dataclasses.replace(
            m, sweep_cache_bytes=m.sweep_cache_bytes + extra
        ),
    )


def _strip_blend(full: "LayerCost", strip: "LayerCost", frac: float) -> "LayerCost":
    """Sweep-average of interior (strip) and edge (full) patch costs.

    flops/hbm/coll average linearly over the patch mix; peak (and the
    memory footprint) must fit the WORST patch, so they take the max.
    """
    if frac <= 0.0:
        return full
    w = 1.0 - frac
    if full.memory is not None and strip.memory is not None:
        mem = full.memory.worst(strip.memory)
    else:
        mem = full.memory
    return LayerCost(
        w * full.flops + frac * strip.flops,
        w * full.hbm_bytes + frac * strip.hbm_bytes,
        max(full.peak_bytes, strip.peak_bytes),
        w * full.coll_bytes + frac * strip.coll_bytes,
        memory=mem,
    )


def _deep_strip_cost(base_fn, S, f, fp, n, k, geom: Optional[PlanGeometry]):
    """Shared deep-reuse wrapper: blend full-extent and interior-strip cost.

    Under ``deep_reuse`` an interior patch runs this layer on an x-strip
    of ``new_x + k - 1`` input columns (new columns + cached halo) instead
    of the full patch extent; edge patches still pay the full extent.
    """
    full = base_fn(S, f, fp, n, k)
    if (
        geom is None
        or not (geom.is_sweep and geom.deep_reuse)
        or geom.layer <= 0
        or geom.new_x <= 0
        or geom.interior_frac <= 0.0
    ):
        return full
    sx = geom.new_x + k - 1
    if sx >= n[0]:
        return full
    strip = base_fn(S, f, fp, (sx, n[1], n[2]), k)
    return _strip_blend(full, strip, geom.interior_frac)


def _deep_strip_pool_cost(base_fn, S, f, n, p, geom: Optional[PlanGeometry]):
    """Pool-layer analogue of ``_deep_strip_cost`` (halo is p - 1)."""
    full = base_fn(S, f, n, p)
    if (
        geom is None
        or not (geom.is_sweep and geom.deep_reuse)
        or geom.layer <= 0
        or geom.new_x <= 0
        or geom.interior_frac <= 0.0
    ):
        return full
    sx = geom.new_x + p - 1
    if sx >= n[0]:
        return full
    strip = base_fn(S, f, (sx, n[1], n[2]), p)
    return _strip_blend(full, strip, geom.interior_frac)


def _vol(n: Sequence[int]) -> int:
    v = 1
    for x in n:
        v *= int(x)
    return v


def _nt(fft_shape: Sequence[int]) -> int:
    """Complex elements in an rfftn spectrum of this FFT shape."""
    na, nb, nc = fft_shape
    return na * nb * (nc // 2 + 1)


def split_transfer_cost(
    S: int,
    f: int,
    n: Tuple[int, ...],
    hw_a: HardwareSpec,
    hw_b: HardwareSpec,
    chips: int = 1,
) -> Tuple[float, float]:
    """(bytes, seconds) of the split-point activation hand-off (§VII-C).

    The stage-0 output — S batch entries of f channels at the ACTUAL
    per-axis extents ``n`` (anisotropic volumes price correctly; no cubic
    assumption) — crosses producer link → host RAM → consumer link once
    per batch, bounded by the slower of the two devices' host links
    (``hw.host_link_bw``).  ``chips`` scales the link count per stage.
    """
    from .hw import host_link_bw

    nbytes = float(S) * f * _vol(n) * F32
    return nbytes, nbytes / (host_link_bw(hw_a, hw_b) * chips)


@dataclass(frozen=True)
class LayerCost:
    flops: float  # arithmetic work
    hbm_bytes: float  # streamed bytes (roofline memory term)
    peak_bytes: float  # peak live memory (Table II analogue)
    coll_bytes: float = 0.0  # inter-chip bytes (streamed/spatial modes)
    # decomposed device working set (the RAM-budget axis); None only for
    # hand-built costs that never meet a ram_budget check
    memory: Optional[MemoryFootprint] = None

    def time(self, hw: HardwareSpec, chips: int = 1) -> float:
        compute = self.flops / (chips * hw.peak_flops)
        memory = self.hbm_bytes / (chips * hw.hbm_bw)
        coll = self.coll_bytes / (chips * hw.ici_bw)
        return max(compute, memory) + coll


# ---------------------------------------------------------------------------
# Convolutional layer primitives
# ---------------------------------------------------------------------------


def _conv_direct_base(S: int, f: int, fp: int, n: Tuple[int, ...], k: int) -> LayerCost:
    npr = tuple(x - k + 1 for x in n)
    flops = 2.0 * S * fp * f * _vol(npr) * k**3  # Table I: S f' f n'³ k³ MACs
    w_bytes = fp * f * k**3 * F32 + fp * F32
    inp = S * f * _vol(n) * F32
    out = S * fp * _vol(npr) * F32
    # each output tile re-reads its input halo once; weights re-read per tile
    hbm = inp + out + w_bytes
    peak = inp + out + w_bytes
    return LayerCost(flops, hbm, peak, memory=_footprint(inp, out, w_bytes, peak))


def conv_direct_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    c = _deep_strip_cost(_conv_direct_base, S, f, fp, n, k, geom)
    return _with_sweep_cache(c, _halo_sweep_bytes(S, f, n, k, geom))


def _fft_common(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> Tuple[Tuple[int, ...], int, int, int, float, float, float]:
    fft_shape = fft_optimal_shape(n)
    nt = _nt(fft_shape)
    vol_n, vol_np = _vol(n), _vol(tuple(x - k + 1 for x in n))
    img_fft = S * f * pruned_fft_flops(n, fft_shape)
    ker_fft = fp * f * pruned_fft_flops((k, k, k), fft_shape)
    inv_fft = S * fp * pruned_fft_flops(tuple(x - k + 1 for x in n), fft_shape)
    # complex MAC = 4 real mult + 4 add = 8 flops per element (3-mult Karatsuba
    # in the Pallas kernel: 3 mult + 5 add); model at 8 (paper Table I: 4 S f' f ñ)
    mad = 8.0 * S * fp * f * nt
    return fft_shape, nt, vol_n, vol_np, img_fft + inv_fft, ker_fft, mad


def conv_fft_data_parallel_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    """Table II "FFT algorithm 1" (data parallel, Alg. 2): one kernel-spectrum
    buffer and one output-channel spectrum column live at a time."""
    c = _deep_strip_cost(_conv_fft_data_base, S, f, fp, n, k, geom)
    return _with_sweep_cache(c, _halo_sweep_bytes(S, f, n, k, geom))


def _conv_fft_data_base(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    fft_shape, nt, vol_n, vol_np, img_fft, ker_fft, mad = _fft_common(S, f, fp, n, k)
    flops = img_fft + ker_fft + mad
    stage_in = S * f * (vol_n * F32 + nt * C64)
    stage_mad = (S * f + S + 1) * nt * C64 + S * fp * vol_np * F32
    peak = max(stage_in, stage_mad)
    # streamed bytes: X spectra re-read once per output channel (the price of
    # the single-buffer discipline), kernels/outputs touched once.
    hbm = (
        S * f * vol_n * F32
        + S * f * nt * C64 * (1 + fp)  # write once, read per output channel
        + fp * f * (k**3) * F32
        + fp * f * nt * C64
        + 2 * S * fp * nt * C64
        + S * fp * vol_np * F32
    )
    w_bytes = fp * f * k**3 * F32 + fp * F32  # raw weights resident per call
    mem = _footprint(S * f * vol_n * F32, S * fp * vol_np * F32, w_bytes, peak)
    return LayerCost(flops, hbm, peak, memory=mem)


# number of concurrently-live kernel-spectrum buffers in the task-parallel
# variant (the paper's T = one per primary thread; ours = spectra chunk).
TASK_T = 8


def conv_fft_task_parallel_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    """Table II "FFT algorithm 2" (task parallel): ALL input and output
    spectra live at once — max{S f (n+ñ), S (f+f') ñ + T ñ, S f' (n'+ñ)} —
    kernel spectra only T at a time.  Every spectrum is touched once: the
    fused MAD reads X once while streaming kernel chunks (the paper's
    "higher cache locality"; on TPU: one pass over HBM)."""
    c = _deep_strip_cost(_conv_fft_task_base, S, f, fp, n, k, geom)
    return _with_sweep_cache(c, _halo_sweep_bytes(S, f, n, k, geom))


def _conv_fft_task_base(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    fft_shape, nt, vol_n, vol_np, img_fft, ker_fft, mad = _fft_common(S, f, fp, n, k)
    flops = img_fft + ker_fft + mad
    peak = max(
        S * f * (vol_n * F32 + nt * C64),
        (S * (f + fp) + TASK_T) * nt * C64,
        S * fp * (vol_np * F32 + nt * C64),
    )
    hbm = (
        S * f * vol_n * F32
        + 2 * S * f * nt * C64
        + fp * f * (k**3) * F32
        + fp * f * nt * C64
        + 2 * S * fp * nt * C64
        + S * fp * vol_np * F32
    )
    w_bytes = fp * f * k**3 * F32 + fp * F32
    mem = _footprint(S * f * vol_n * F32, S * fp * vol_np * F32, w_bytes, peak)
    return LayerCost(flops, hbm, peak, memory=mem)


def conv_fft_cached_kernels_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    """Task-parallel with kernel spectra precomputed once per *plan*, not
    per patch (beyond-paper: cross-patch kernel-spectrum reuse; executed by
    ``primitives.compile_plan`` setup).  Per-call cost drops both the kernel
    FFT flops and the raw kernel-weights HBM read (spectra are resident,
    the f'·f·k³ weights are never re-read at run time); spectra storage is
    still charged to peak."""
    c = _deep_strip_cost(_conv_fft_cached_base, S, f, fp, n, k, geom)
    return _with_sweep_cache(c, _halo_sweep_bytes(S, f, n, k, geom))


def _conv_fft_cached_base(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int
) -> LayerCost:
    c = _conv_fft_task_base(S, f, fp, n, k)
    fft_shape = fft_optimal_shape(n)
    nt = _nt(fft_shape)
    ker_fft = fp * f * pruned_fft_flops((k, k, k), fft_shape)
    w_bytes = fp * f * k**3 * F32
    # resident state is the cached kernel spectra (computed once per plan),
    # not the raw weights
    resident = fp * f * nt * C64 + fp * F32
    mem = _footprint(
        S * f * _vol(n) * F32,
        S * fp * _vol(tuple(x - k + 1 for x in n)) * F32,
        resident,
        c.peak_bytes,
    )
    return LayerCost(c.flops - ker_fft, c.hbm_bytes - w_bytes, c.peak_bytes, memory=mem)


def conv_overlap_save_cost(
    S: int, f: int, fp: int, n: Tuple[int, ...], k: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    """Overlap-save: segmented small FFTs + cross-patch input-spectra reuse.

    The input is segmented along axis 0 into windows of ``seg_core + k - 1``
    voxels stepping by ``seg_core`` (``core.overlap_save``); kernel spectra
    are cached at setup like ``fft_cached``.  The cost is evaluated in the
    ``PlanGeometry`` context the executor will actually run:

    * under a sweep geometry AT THE INPUT LAYER (``geom.layer <= 0`` — the
      only layer whose input windows have a cross-patch identity for the
      executor's cache; a deeper overlap_save layer is priced
      self-contained regardless of sweep context), the segment grid is the
      executor's core-pinned grid (``geom.seg_core``, i.e.
      ``compile_plan(overlap_seg=core)``), and input-FFT work is the exact
      sweep-average segment-transform count per patch
      (``geom.seg_fft_per_patch``, from the planner's cache simulation;
      falling back to the interior/edge mix ``interior_frac * new +
      (1 - interior_frac) * n_seg``) — interior patches pay only the
      ``new_segments`` their left neighbour doesn't already own
      (``core/seg_core`` on an aligned grid);
    * under ``deep_reuse`` the MAD + inverse terms also shrink for
      interior patches to the ``tail_segments`` covering the patch's new
      core columns — the leading columns are assembled from the deep
      activation cache, not recomputed;
    * with no geometry (``PlanGeometry.local()``), every segment is
      transformed and MAD'd per call — the honest price of the
      self-contained apply (one-shot ``conv_apply``, deeper layers without
      sweep amortization);
    * peak memory holds the per-segment spectra (the reuse currency) plus
      the dense in/out tensors — the paper's Table-II overhead shrinks by
      ~seg_extent/n versus whole-patch FFT, which is what lets larger
      patches fit the budget (ZNNi's condition for FFT dominance).

    Known approximation: the one-live-output-column peak term relies on
    XLA freeing each segment's output spectra after its inverse (in-order
    per-segment chain in ``os_apply_from_spectra``); a scheduler that
    overlapped segments could hold up to n_seg columns.
    """
    from .overlap_save import (  # lazy: overlap_save imports pruned_fft
        new_segments,
        plan_overlap_save,
        tail_segments,
    )

    g = geom if geom is not None else PlanGeometry.local()
    # the sweep's segment cache exists ONLY at the net's input layer (the
    # one layer whose input windows have a cross-patch identity); a deeper
    # overlap_save layer runs self-contained on its default grid, whatever
    # the sweep context.  A geometry with no layer tag (-1) is taken to be
    # pricing the input layer.
    at_input = g.is_sweep and g.layer <= 0
    n3 = tuple(int(x) for x in n)
    seg_core = g.seg_core if (at_input and g.seg_core > 0) else None
    spec = plan_overlap_save(n3, (int(k),) * 3, seg_core)
    nt = _nt(spec.fft_shape)
    n_seg = spec.n_segments
    npr = tuple(x - k + 1 for x in n)
    vol_n, vol_np = _vol(n), _vol(npr)
    seg_in = (spec.seg_extent, n[1], n[2])
    seg_out = (spec.seg_core, npr[1], npr[2])
    # input-segment transforms per patch (sweep-average)
    if at_input:
        if g.seg_fft_per_patch >= 0:
            in_segs = g.seg_fft_per_patch
        else:
            in_segs = (
                g.interior_frac * new_segments(spec, g.core)
                + (1.0 - g.interior_frac) * n_seg
            )
    else:
        in_segs = float(n_seg)
    # MAD + inverse segments per patch: interior patches under deep reuse
    # pay only the trailing segments covering their new core columns
    if at_input and g.deep_reuse:
        q = tail_segments(spec, g.core)
        mad_segs = g.interior_frac * q + (1.0 - g.interior_frac) * n_seg
    else:
        mad_segs = float(n_seg)
    img_fft = S * f * in_segs * pruned_fft_flops(seg_in, spec.fft_shape)
    inv_fft = S * fp * mad_segs * pruned_fft_flops(seg_out, spec.fft_shape)
    mad = 8.0 * S * fp * f * nt * mad_segs
    flops = img_fft + inv_fft + mad  # kernel FFT amortized at setup
    hbm = (
        S * f * vol_n * F32  # input streamed once
        + S * f * nt * C64 * (in_segs + mad_segs)  # write amortized, read per MAD
        + fp * f * nt * C64  # resident kernel spectra re-read
        + 2 * S * fp * nt * C64 * mad_segs  # output spectra write + inverse read
        + S * fp * vol_np * F32
    )
    # Stage maxima matching the implementation's staging: ALL input
    # segment spectra are live (n_seg·ñ — they are the cross-patch reuse
    # currency), while the MAD + inverse form an unrolled per-segment
    # chain whose buffer liveness frees each output-spectra column after
    # its inverse (``os_apply_from_spectra``), so ~ONE column is charged —
    # the paper's staged-memory discipline, by graph staging rather than
    # hard sequencing (third known approximation above).  Output-side
    # spectra shrink by ~seg_extent/n versus the task-parallel model
    # (kernel-spectra residency not charged, per the fft_cached
    # convention; T live kernel buffers are).
    peak = max(
        S * f * (vol_n * F32 + n_seg * nt * C64),  # dense input + all seg spectra
        (S * (n_seg * f + fp) + TASK_T) * nt * C64
        + S * fp * vol_np * F32,  # MAD: one output column + dense accumulator
        S * fp * (vol_np * F32 + nt * C64),  # inverse + dense output
    )
    resident = fp * f * nt * C64 + fp * F32  # cached kernel spectra + bias
    sweep_bytes = 0.0
    if at_input and g.plane_patches > 0:
        # each (y, z) patch row keeps its segment spectra live across
        # plane steps: n_seg per-segment (f, ñ) complex buffers per row
        sweep_bytes = g.plane_patches * n_seg * f * nt * C64
    elif g.is_sweep and g.deep_reuse and g.layer > 0 and g.plane_patches > 0:
        sweep_bytes = _halo_sweep_bytes(S, f, n3, int(k), g)
    mem = _footprint(
        S * f * vol_n * F32, S * fp * vol_np * F32, resident, peak, sweep_bytes
    )
    return LayerCost(flops, hbm, peak, memory=mem)


# ---------------------------------------------------------------------------
# Pooling primitives
# ---------------------------------------------------------------------------


def _pool_base(S: int, f: int, n: Tuple[int, ...], p: int) -> LayerCost:
    vol = _vol(n)
    flops = 1.0 * S * f * vol  # Table I: S f n³ comparisons
    hbm = 2 * S * f * vol * F32
    inp = S * f * vol * F32
    out = S * f * _vol(tuple(x // p for x in n)) * F32
    return LayerCost(flops, hbm, hbm, memory=_footprint(inp, out, 0.0, hbm))


def pool_cost(
    S: int, f: int, n: Tuple[int, ...], p: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    c = _deep_strip_pool_cost(_pool_base, S, f, n, p, geom)
    return _with_sweep_cache(c, _halo_sweep_bytes(S, f, n, p, geom))


def _mpf_base(S: int, f: int, n: Tuple[int, ...], p: int) -> LayerCost:
    vol = _vol(n)
    flops = 1.0 * S * f * vol * p**3  # Table I: S f n³ p³
    m3 = _vol(tuple(x // p for x in n)) * p**3
    hbm = (S * f * vol + S * f * m3) * F32
    inp = S * f * vol * F32
    out = S * f * m3 * F32
    return LayerCost(flops, hbm, hbm, memory=_footprint(inp, out, 0.0, hbm))


def mpf_cost(
    S: int, f: int, n: Tuple[int, ...], p: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    c = _deep_strip_pool_cost(_mpf_base, S, f, n, p, geom)
    return _with_sweep_cache(c, _halo_sweep_bytes(S, f, n, p, geom))


# ---------------------------------------------------------------------------
# Canonical primitive names (the planner's enumeration order)
# ---------------------------------------------------------------------------
#
# Name *interpretation* — mapping a name to cost/setup/apply code — lives in
# one place only: the ``core.primitives`` registry, which must stay in 1:1
# correspondence with these tuples (test_planner_invariants asserts it).

CONV_PRIMS = ("direct", "fft_data", "fft_task", "fft_cached", "overlap_save")
POOL_PRIMS = ("mpf", "pool")


def conv_cost(
    prim: str, S: int, f: int, fp: int, n: Tuple[int, ...], k: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    """Cost of a conv primitive by name, via the runtime registry.

    ``geom`` is the ``PlanGeometry`` context the cost is evaluated in;
    omit it (→ ``PlanGeometry.local()``) for standalone costing.
    """
    from .primitives import conv_primitive  # lazy: primitives imports us

    return conv_primitive(prim).cost(S, f, fp, n, k, geom)


def pool_cost_by_name(
    prim: str, S: int, f: int, n: Tuple[int, ...], p: int,
    geom: Optional[PlanGeometry] = None,
) -> LayerCost:
    """Cost of a pool primitive by name, via the runtime registry."""
    from .primitives import pool_primitive

    return pool_primitive(prim).cost(S, f, n, p, geom)
