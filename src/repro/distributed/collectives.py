"""Collective helpers: overlapped ring all-gather, halo exchange, and the
compressed cross-pod reduction used by the training loop.

These are the shard_map-level building blocks behind DESIGN.md §3's
"aggregate HBM as host RAM" streaming (C6) and the two-stage pipeline (C7).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np
from jax import lax

Coord = Tuple[int, int, int]


# ---------------------------------------------------------------------------
# Plane-boundary halo exchange (sharded volume serving)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HaloPackage:
    """Host-staged boundary state handed from one sweep shard to the next.

    A shard covering x-planes [x_a, x_b) of a sweep owns, when it finishes,
    exactly the executor cache entries the successor shard (starting at
    ``x_lo = x_b``) would have inherited on a single device: layer-0 segment
    spectra and per-layer activation halos whose absolute-x key is >= x_lo
    (everything left of it is evicted on a single device too).  Keys are the
    tiler's ``HaloSpec`` absolute coordinates, so import on any worker files
    entries bit-identically to where a single-device sweep would have them.

    Arrays are host ndarrays (output-to-host staging on export, host-to-
    device on import) — the fleet exchanges bytes through host RAM, never
    device-to-device.
    """

    x_lo: int
    spectra: Mapping[Coord, np.ndarray] = field(default_factory=dict)
    halos: Mapping[Coord, Tuple[np.ndarray, ...]] = field(default_factory=dict)

    @property
    def n_spectra(self) -> int:
        return len(self.spectra)

    @property
    def n_halos(self) -> int:
        return len(self.halos)

    @property
    def nbytes(self) -> int:
        seg = sum(int(a.nbytes) for a in self.spectra.values())
        hal = sum(int(h.nbytes) for entry in self.halos.values() for h in entry)
        return seg + hal

    def is_empty(self) -> bool:
        return not self.spectra and not self.halos


def empty_halo_package(x_lo: int = 0) -> HaloPackage:
    """The package a shard with no predecessor starts from."""
    return HaloPackage(x_lo=x_lo, spectra={}, halos={})


def halo_exchange(src_executor, src_token: int, dst_executor, dst_token: int,
                  x_lo: int) -> HaloPackage:
    """Move boundary caches from one worker's sweep scope to another's.

    Stages ``src_executor``'s segment-spectra / activation-halo entries at
    absolute x >= ``x_lo`` out to host (``export_handoff``), then uploads
    them into ``dst_executor``'s scope (``import_handoff``).  Returns the
    package so callers can account exchanged bytes (`HaloPackage.nbytes`).
    Executors are duck-typed: anything with export_handoff/import_handoff.
    """
    pkg = src_executor.export_handoff(src_token, x_lo)
    dst_executor.import_handoff(dst_token, pkg)
    return pkg


def ring_allgather_matmul(
    x: jnp.ndarray,
    w_shard: jnp.ndarray,
    axis_name: str,
) -> jnp.ndarray:
    """Compute x @ W with W row-sharded over `axis_name`, streaming shards
    around the ring and overlapping each hop with the partial matmul
    (the double-buffered "GPU + host RAM" schedule on ICI).

    x (..., K) with K = A * k_shard; w_shard (k_shard, N) is this chip's
    slice of W's rows.  Returns (..., N) — identical to x @ concat(W).
    """
    A = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    k_shard = w_shard.shape[0]
    perm = [(i, (i + 1) % A) for i in range(A)]

    def body(carry, a):
        acc, w = carry
        # shard currently held originated at chip (idx - a) mod A
        src = (idx - a) % A
        xs = lax.dynamic_slice_in_dim(x, src * k_shard, k_shard, axis=-1)
        acc = acc + jnp.einsum("...k,kn->...n", xs, w)
        w_next = lax.ppermute(w, axis_name, perm)
        return (acc, w_next), None

    acc0 = jnp.zeros(x.shape[:-1] + (w_shard.shape[1],), x.dtype)
    (acc, _), _ = lax.scan(body, (acc0, w_shard), jnp.arange(A))
    return acc


def all_gather_chunked(x_shard: jnp.ndarray, axis_name: str, axis: int = 0) -> jnp.ndarray:
    """Plain tiled all-gather (XLA emits the ring; kept for symmetry)."""
    return lax.all_gather(x_shard, axis_name, axis=axis, tiled=True)


def psum_compressed(
    x: jnp.ndarray,
    axis_name: str,
    *,
    error: Optional[jnp.ndarray] = None,
):
    """int8 absmax-quantized all-reduce with error feedback.

    Used for *cross-pod* gradient reduction where ICI hops are longest
    (DP gradients within a pod stay full precision).  Returns (mean, new
    error).  The error-feedback carry makes the compression unbiased over
    steps (residual is added before the next quantization).
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    absmax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_error = xf - deq
    n = lax.psum(1, axis_name)
    summed = lax.psum(deq, axis_name)
    return summed / n, new_error


def reduce_scatter_mean(x: jnp.ndarray, axis_name: str, axis: int = 0) -> jnp.ndarray:
    n = lax.psum(1, axis_name)
    return lax.psum_scatter(x, axis_name, scatter_dimension=axis, tiled=True) / n
