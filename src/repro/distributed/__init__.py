"""Distribution substrate: sharding rules, collectives, fault tolerance."""

from . import collectives, fault_tolerance, sharding  # noqa: F401
