"""Activation sharding constraints (GSPMD hygiene).

XLA's sharding propagation occasionally replicates large activations when
it cannot see through a scan/checkpoint boundary (observed: whisper train
attention scores materialized with the GLOBAL batch dim).  Production
frameworks pin activations with with_sharding_constraint at block
boundaries; `constrain` does that with *logical* axis names and degrades
to a no-op when no mesh is active (tests, single-device runs) or when the
dim is not divisible by the axis size.

Logical names: 'batch' -> ('pod','data') (whichever exist), 'model',
'seq' -> 'model' (sequence sharding for long-context decode), None.
"""

from __future__ import annotations

import numpy as np

import jax
from jax import lax
from jax.sharding import PartitionSpec as P


def _current_mesh():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    try:
        from jax.interpreters import pxla

        m = pxla.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            return m
    except Exception:
        pass
    return None


def constrain(x, *logical):
    """Pin activation sharding; logical entries per dim (padded with None)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    shape = x.shape
    spec = []
    for i in range(len(shape)):
        l = logical[i] if i < len(logical) else None
        if l == "batch":
            axes = tuple(a for a in ("pod", "data") if a in names)
            n = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
            spec.append(axes if axes and shape[i] % n == 0 else None)
        elif l in ("model", "seq"):
            ok = "model" in names and shape[i] % mesh.shape["model"] == 0
            spec.append("model" if ok else None)
        else:
            spec.append(None)
    if all(s is None for s in spec):
        return x
    return lax.with_sharding_constraint(x, P(*spec))


def constrain_replicated(x):
    """Pin a tensor fully replicated (decode moves activations, not weights)."""
    mesh = _current_mesh()
    if mesh is None:
        return x
    return lax.with_sharding_constraint(x, P(*([None] * x.ndim)))
