"""Name-based sharding rules per (arch × shape-kind) — DESIGN.md §6.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The pod axis is pure data parallelism (and the pipeline axis in the
pipelined executor); "model" carries tensor/expert parallelism; "data"
carries batch + ZeRO-style parameter/optimizer sharding for training.

Rules are matched on parameter *path names* and trailing-dimension shapes,
so they survive the scan-stacked (R, ...) leading dim automatically:

  attention  — shard heads over `model` when divisible; else q-heads only
               (KV replicated); else replicate attention and let the MLP
               carry the model axis (qwen1.5's 20 MHA heads, phi3's 40).
  MLP        — d_ff over `model` (always divisible for the assigned archs).
  MoE        — experts over `model` when divisible (jamba 16e), else
               tensor-parallel d_ff inside each expert (mixtral/grok 8e).
  Mamba2     — d_inner / ssm-head dims over `model` (projections were
               deliberately stored unfused so these shard cleanly).
  embeddings — vocab over `model` when divisible, else d_model.
  ZeRO       — in train mode, every parameter leaf ≥ 2^16 elements gets one
               extra `data`-axis sharding on its largest free divisible dim
               (storage + optimizer state sharding; XLA all-gathers at use).

KV caches (decode): batch over (pod, data) when divisible; the *sequence*
dim shards over `model` (flash-decode across chips — uniform for every
kv-head count, and what makes long_500k fit).  long_500k (batch=1) shards
sequence over every available axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig

ZERO_MIN_ELEMS = 1 << 16


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def _base_param_spec(path: str, shape: Tuple[int, ...], cfg: ModelConfig, tp: int):
    """PartitionSpec entries for the TRAILING dims (caller pads the front)."""
    nd = len(shape)

    def spec(*trailing):
        return [None] * (nd - len(trailing)) + list(trailing)

    leaf = path.rsplit("/", 1)[-1]
    a = cfg.attn

    # --- small / replicated leaves
    if leaf in ("scale", "bias", "A_log", "D", "dt_bias", "conv_bx", "conv_bB",
                "conv_bC", "b_out", "router", "conv_B", "conv_C"):
        return spec()

    # --- embeddings
    if path.endswith("embed/tok"):
        V, d = shape[-2], shape[-1]
        if V % tp == 0:
            return spec("model", None)
        return spec(None, "model") if d % tp == 0 else spec()
    if path.endswith("embed/head"):
        d, V = shape[-2], shape[-1]
        if V % tp == 0:
            return spec(None, "model")
        return spec("model", None) if d % tp == 0 else spec()

    # --- MoE experts (E, d, ff) / (E, ff, d)
    if "ffn" in path and leaf in ("w_in", "w_gate", "w_out") and nd >= 3 and cfg.moe:
        E = cfg.moe.n_experts
        if shape[-3] == E:
            if E % tp == 0:
                return spec("model", None, None)
            if leaf == "w_out":
                return spec(None, "model", None)  # (E, ff, d): shard ff
            return spec(None, None, "model")  # (E, d, ff): shard ff
    # --- dense MLP
    if leaf in ("w_in", "w_gate"):
        return spec(None, "model")
    if leaf == "w_out" and "mixer" not in path:
        return spec("model", None)
    if leaf == "b_in":
        return spec("model")

    # --- attention projections
    if leaf == "wq":
        return spec(None, "model", None) if a and a.n_heads_eff % tp == 0 else spec()
    if leaf in ("wk", "wv"):
        return spec(None, "model", None) if a and a.n_kv_heads % tp == 0 else spec()
    if leaf == "wo":
        return spec("model", None, None) if a and a.n_heads_eff % tp == 0 else spec()
    if leaf == "bq":
        return spec("model", None) if a and a.n_heads_eff % tp == 0 else spec()
    if leaf in ("bk", "bv"):
        return spec("model", None) if a and a.n_kv_heads % tp == 0 else spec()

    # --- Mamba2 projections (stored unfused so they shard cleanly)
    if leaf in ("w_z", "w_x"):
        return spec(None, "model")
    if leaf in ("w_B", "w_C", "w_dt"):
        return spec(None, "model") if shape[-1] % tp == 0 else spec()
    if leaf == "conv_x":
        return spec(None, "model")
    if leaf == "norm_scale":
        return spec("model")
    if leaf == "w_out":  # ssm out proj (di, d)
        return spec("model", None)

    return spec()


def _add_zero(entries, shape, dp: int, tp: int):
    """Add one `data`-axis sharding on the largest free divisible dim.

    (A joint ('model','data') variant on the model-sharded dim was tried in
    EXPERIMENTS.md §Perf H3c and REFUTED: it doubled the memory term and
    tripled collectives on grok decode — the free-dim split lets the
    partitioner psum tiny activation partials instead.)
    """
    best, best_idx = 0, -1
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % dp == 0 and s > best:
            best, best_idx = s, i
    if best_idx >= 0:
        entries = list(entries)
        entries[best_idx] = "data"
    return entries


def param_shardings(
    cfg: ModelConfig,
    params_tree: Any,
    mesh: Mesh,
    *,
    zero: bool = False,
) -> Any:
    """Tree of NamedShardings matching `params_tree` (arrays or SDS)."""
    tp = _axis_size(mesh, "model")
    dp = _axis_size(mesh, "data")

    def one(path, leaf):
        shape = tuple(leaf.shape)
        entries = _base_param_spec(_path_str(path), shape, cfg, tp)
        if zero and int(np.prod(shape)) >= ZERO_MIN_ELEMS:
            entries = _add_zero(entries, shape, dp, tp)
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, batch_tree: Any) -> Any:
    """Shardings for the non-parameter step inputs from input_specs()."""
    baxes = _batch_axes(mesh)
    bsz = int(np.prod([_axis_size(mesh, a) for a in baxes]))
    tp = _axis_size(mesh, "model")
    B = shape.global_batch
    b_shardable = B % bsz == 0

    def cache_spec(path: str, leaf) -> NamedSharding:
        s = tuple(leaf.shape)
        leafname = path.rsplit("/", 1)[-1]
        nd = len(s)
        if leafname == "lengths":
            return NamedSharding(mesh, P())
        if leafname in ("k", "v", "xk", "xv"):
            # (..., B, S, Hkv, hd) — stacked caches have a leading R/L dim,
            # partial-repeat ("rem") caches do not.
            ent = [None] * nd
            iB, iS = nd - 4, nd - 3
            seq = s[iS]
            if b_shardable:
                ent[iB] = baxes
                ent[iS] = "model" if seq % tp == 0 else None
            else:
                rest = baxes + ("model",)
                n_rest = int(np.prod([_axis_size(mesh, a) for a in rest]))
                ent[iS] = rest if seq % n_rest == 0 else None
            return NamedSharding(mesh, P(*ent))
        if leafname == "conv":
            # (..., B, d_conv-1, ch)
            ent = [None] * nd
            if b_shardable:
                ent[nd - 3] = baxes
            return NamedSharding(mesh, P(*ent))
        if leafname == "state":
            # (..., B, h, p, n)
            ent = [None] * nd
            if b_shardable:
                ent[nd - 4] = baxes
            if s[nd - 3] % tp == 0:
                ent[nd - 3] = "model"
            return NamedSharding(mesh, P(*ent))
        return NamedSharding(mesh, P())

    def one(path, leaf):
        pstr = _path_str(path)
        if pstr.startswith("caches"):
            return cache_spec(pstr, leaf)
        s = tuple(leaf.shape)
        ent = [None] * len(s)
        if s and s[0] == B and b_shardable:
            ent[0] = baxes
        return NamedSharding(mesh, P(*ent))

    return jax.tree_util.tree_map_with_path(one, batch_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
