"""Fault tolerance for 1000+-node runs: checkpoint/restart, elastic
remeshing, and straggler mitigation.

What "fault tolerance" means here, concretely:

* **Checkpoint/restart** — `repro.checkpoint` writes sharded, atomic,
  async checkpoints; `restore_with_remesh` below re-shards any checkpoint
  onto a *different* mesh (elastic scale-up/down after losing a pod).
* **Failure detection** — `HeartbeatMonitor` tracks per-step deadlines
  derived from a rolling median step time; a worker missing `patience`
  deadlines is declared failed (on real fleets this feeds the coordinator
  via jax.distributed; here it is the policy object + unit-tested logic).
* **Straggler mitigation** — the same rolling-median machinery flags
  *slow* (not dead) workers; the policy emits REBALANCE (shrink that
  host's data shard via the elastic sampler) before EVICT.
* **Recovery drill** — tests/test_fault_tolerance.py kills a step mid-run,
  restores from the last checkpoint onto a smaller mesh, and verifies
  bit-identical continuation of the loss curve modulo the lost step.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Elastic remesh
# ---------------------------------------------------------------------------


def restore_with_remesh(tree: Any, shardings_new: Any) -> Any:
    """Re-shard a restored pytree onto a new mesh's shardings.

    Works for both scale-down (lost pod) and scale-up: values are device_put
    with the new NamedShardings; XLA moves/reslices the data.
    """
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings_new,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


# ---------------------------------------------------------------------------
# Straggler / failure policy
# ---------------------------------------------------------------------------


@dataclass
class WorkerState:
    last_step: int = -1
    last_seen: float = 0.0
    step_times: List[float] = field(default_factory=list)


@dataclass
class HeartbeatMonitor:
    """Deadline-based failure detection + straggler flagging.

    deadline = straggler_factor * rolling-median step time; a worker
    missing `patience` consecutive deadlines is FAILED; one consistently
    above `straggler_factor` x median (but alive) is a STRAGGLER.
    """

    n_workers: int
    straggler_factor: float = 2.0
    patience: int = 3
    window: int = 32
    workers: Dict[int, WorkerState] = field(default_factory=dict)

    def __post_init__(self):
        for i in range(self.n_workers):
            self.workers[i] = WorkerState()

    def heartbeat(self, worker: int, step: int, step_time: Optional[float] = None,
                  now: Optional[float] = None):
        """Record a heartbeat.  ``step_time=None`` is a *keepalive*: the
        worker is responsive but did no compute this step (idle/blocked),
        so it proves liveness without feeding a sample into the rolling
        median it didn't earn."""
        w = self.workers[worker]
        w.last_step = step
        w.last_seen = time.monotonic() if now is None else now
        if step_time is not None:
            w.step_times.append(step_time)
            if len(w.step_times) > self.window:
                w.step_times.pop(0)

    def median_step_time(self) -> float:
        allt = [t for w in self.workers.values() for t in w.step_times]
        return float(np.median(allt)) if allt else float("inf")

    def classify(self, now: Optional[float] = None) -> Dict[int, str]:
        """worker -> 'ok' | 'straggler' | 'failed'."""
        now = time.monotonic() if now is None else now
        med = self.median_step_time()
        deadline = self.straggler_factor * med * self.patience
        out = {}
        max_step = max((w.last_step for w in self.workers.values()), default=-1)
        for i, w in self.workers.items():
            if med != float("inf") and now - w.last_seen > deadline and w.last_step < max_step:
                out[i] = "failed"
            elif w.step_times and np.median(w.step_times) > self.straggler_factor * med:
                out[i] = "straggler"
            else:
                out[i] = "ok"
        return out

    def evict(self, worker: int) -> None:
        """Remove a worker from monitoring (post-EVICT): its frozen
        heartbeat must stop skewing the rolling median, and it must not be
        re-reported failed every subsequent classify."""
        self.workers.pop(worker, None)

    def revive(self, worker: int, now: Optional[float] = None) -> None:
        """Re-admit a (previously evicted) worker with a fresh state."""
        w = WorkerState()
        w.last_seen = time.monotonic() if now is None else now
        self.workers[worker] = w

    def plan(self, now: Optional[float] = None) -> Dict[str, Any]:
        """Action plan: evict failed workers, rebalance stragglers."""
        cls = self.classify(now)
        failed = [i for i, c in cls.items() if c == "failed"]
        slow = [i for i, c in cls.items() if c == "straggler"]
        if failed:
            return {"action": "evict_and_restore", "workers": failed}
        if slow:
            return {"action": "rebalance", "workers": slow}
        return {"action": "none", "workers": []}


# ---------------------------------------------------------------------------
# Elastic data sharding (straggler rebalance lever)
# ---------------------------------------------------------------------------


def elastic_shard_sizes(global_batch: int, n_workers: int, weights: Optional[List[float]] = None) -> List[int]:
    """Split a global batch over workers proportionally to `weights`
    (1/step_time); used to shrink a straggler's shard.  Sizes sum exactly
    to global_batch."""
    if weights is None:
        weights = [1.0] * n_workers
    w = np.asarray(weights, dtype=np.float64)
    w = w / w.sum()
    sizes = np.floor(w * global_batch).astype(int)
    rem = global_batch - sizes.sum()
    order = np.argsort(-(w * global_batch - sizes))
    for i in range(rem):
        sizes[order[i % n_workers]] += 1
    return sizes.tolist()
