"""Pure-jnp oracle for MPF: all p³ offset poolings, fragments into batch."""

from __future__ import annotations

import itertools

import jax.numpy as jnp


def mpf_pool(x: jnp.ndarray, p: int) -> jnp.ndarray:
    """x (S, f, n³) with (n+1)%p==0 -> (S·p³, f, (n//p)³).

    Output batch index = s·p³ + (ox·p² + oy·p + oz).
    """
    S, f = x.shape[:2]
    n = x.shape[2:]
    m = tuple(ni // p for ni in n)
    frags = []
    for ox, oy, oz in itertools.product(range(p), repeat=3):
        v = x[:, :, ox : ox + p * m[0], oy : oy + p * m[1], oz : oz + p * m[2]]
        v = v.reshape(S, f, m[0], p, m[1], p, m[2], p).max(axis=(3, 5, 7))
        frags.append(v)
    y = jnp.stack(frags, axis=1)
    return y.reshape(S * p**3, f, *m)


def mpf_pool_window(x: jnp.ndarray, p: int, window) -> jnp.ndarray:
    """Windowed-MPF oracle: crop to ``window`` then pool (the fused pair's
    two steps, materialized)."""
    wx, wy, wz = window
    return mpf_pool(x[..., :wx, :wy, :wz], p)
