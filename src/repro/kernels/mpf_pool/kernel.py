"""Pallas TPU kernel for max-pooling fragments (ZNNi §V).

Grid: (batch, p³ fragment offsets, channel blocks).  Each program computes
one fragment of one channel block: a dynamic offset slice of the input
followed by a p-strided window max (reshape-max, all static shapes).  The
input block is revisited across the fragment-offset grid dimension, so it
stays VMEM-resident while all p³ fragments are emitted (this is the reuse
the naive all-subsamplings baseline lacks — each offset re-reads HBM there).

Output batch index s·p³ + o is produced directly by the output index_map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F_BLOCK = 8  # channels per block


def _kernel(x_ref, o_ref, *, p: int):
    o = pl.program_id(1)
    ox = o // (p * p)
    oy = (o // p) % p
    oz = o % p
    f, nx, ny, nz = x_ref.shape[1:]
    m = (nx // p, ny // p, nz // p)
    v = x_ref[0, :, pl.ds(ox, p * m[0]), pl.ds(oy, p * m[1]), pl.ds(oz, p * m[2])]
    v = v.reshape(f, m[0], p, m[1], p, m[2], p)
    o_ref[0] = v.max(axis=(2, 4, 6))


@functools.partial(jax.jit, static_argnames=("p", "interpret"))
def mpf_pool_blocked(x: jnp.ndarray, *, p: int, interpret: bool = True) -> jnp.ndarray:
    """x (S, f, n³) f32 with (n+1)%p==0 and f % F_BLOCK == 0 (ops.py pads)."""
    S, f, nx, ny, nz = x.shape
    m = (nx // p, ny // p, nz // p)
    P = p**3
    grid = (S, P, f // F_BLOCK)
    x_spec = pl.BlockSpec((1, F_BLOCK, nx, ny, nz), lambda s, o, fb: (s, fb, 0, 0, 0))
    o_spec = pl.BlockSpec(
        (1, F_BLOCK, *m), lambda s, o, fb: (s * P + o, fb, 0, 0, 0)
    )
    return pl.pallas_call(
        functools.partial(_kernel, p=p),
        grid=grid,
        in_specs=[x_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((S * P, f, *m), x.dtype),
        interpret=interpret,
    )(x)


def _window_kernel(x_ref, o_ref, *, p: int, window):
    """MPF over the leading ``window`` of an uncropped input block.

    Identical to ``_kernel`` but the fragment extents come from the static
    ``window``, not the input shape: fragment (ox,oy,oz) reads
    ``[o, o + p·(w//p))`` per axis, which stays inside ``[0, w]`` because
    (w+1) % p == 0 — so the crop of the inverse transform's spill region
    (anything past ``window``) happens *inside* the pool's slicing instead
    of as a separate materialized copy.
    """
    o = pl.program_id(1)
    ox = o // (p * p)
    oy = (o // p) % p
    oz = o % p
    f = x_ref.shape[1]
    m = (window[0] // p, window[1] // p, window[2] // p)
    v = x_ref[0, :, pl.ds(ox, p * m[0]), pl.ds(oy, p * m[1]), pl.ds(oz, p * m[2])]
    v = v.reshape(f, m[0], p, m[1], p, m[2], p)
    o_ref[0] = v.max(axis=(2, 4, 6))


@functools.partial(jax.jit, static_argnames=("p", "window", "interpret"))
def mpf_pool_window_blocked(
    x: jnp.ndarray, *, p: int, window, interpret: bool = True
) -> jnp.ndarray:
    """Fused inverse-window + MPF: pool the leading ``window`` of ``x``.

    x (S, f, n³) f32 with n >= window per axis, (window+1) % p == 0, and
    f % F_BLOCK == 0 (ops.py pads).  Equivalent to
    ``mpf_pool_blocked(x[..., :wx, :wy, :wz])`` without materializing the
    crop — the conv+pool fused pair feeds this the uncropped last-axis
    inverse-FFT output.
    """
    S, f = x.shape[:2]
    nx, ny, nz = x.shape[2:]
    m = tuple(w // p for w in window)
    P = p**3
    grid = (S, P, f // F_BLOCK)
    x_spec = pl.BlockSpec((1, F_BLOCK, nx, ny, nz), lambda s, o, fb: (s, fb, 0, 0, 0))
    o_spec = pl.BlockSpec(
        (1, F_BLOCK, *m), lambda s, o, fb: (s * P + o, fb, 0, 0, 0)
    )
    return pl.pallas_call(
        functools.partial(_window_kernel, p=p, window=tuple(window)),
        grid=grid,
        in_specs=[x_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((S * P, f, *m), x.dtype),
        interpret=interpret,
    )(x)
