"""Max-pooling fragments (MPF) kernel."""

from . import kernel, ops, ref  # noqa: F401
