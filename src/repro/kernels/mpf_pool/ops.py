"""Jitted wrapper for MPF pooling: pads channels, dispatches kernel vs ref."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref


@partial(jax.jit, static_argnames=("p", "use_pallas", "interpret"))
def mpf_pool(
    x: jnp.ndarray,
    p: int,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Max-pooling fragments; see ref.py for semantics."""
    n = x.shape[2:]
    if any((ni + 1) % p for ni in n):
        raise ValueError(f"MPF needs (n+1)%p==0, got n={n}, p={p}")
    if not use_pallas:
        return _ref.mpf_pool(x, p)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    f = x.shape[1]
    padF = (-f) % _k.F_BLOCK
    if padF:
        x = jnp.pad(x, ((0, 0), (0, padF), (0, 0), (0, 0), (0, 0)))
    o = _k.mpf_pool_blocked(x.astype(jnp.float32), p=p, interpret=interpret)
    return o[:, :f]
