"""Jitted wrapper for MPF pooling: pads channels, dispatches kernel vs ref."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..dispatch import resolve_use_pallas
from . import kernel as _k
from . import ref as _ref


@partial(jax.jit, static_argnames=("p", "use_pallas", "interpret"))
def mpf_pool(
    x: jnp.ndarray,
    p: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Max-pooling fragments; see ref.py for semantics."""
    n = x.shape[2:]
    if any((ni + 1) % p for ni in n):
        raise ValueError(f"MPF needs (n+1)%p==0, got n={n}, p={p}")
    if not resolve_use_pallas(use_pallas):
        return _ref.mpf_pool(x, p)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    f = x.shape[1]
    padF = (-f) % _k.F_BLOCK
    if padF:
        x = jnp.pad(x, ((0, 0), (0, padF), (0, 0), (0, 0), (0, 0)))
    o = _k.mpf_pool_blocked(x.astype(jnp.float32), p=p, interpret=interpret)
    return o[:, :f]


@partial(jax.jit, static_argnames=("p", "window", "use_pallas", "interpret"))
def mpf_pool_window(
    x: jnp.ndarray,
    p: int,
    window: tuple[int, int, int],
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused inverse-window + MPF: pool the leading ``window`` of ``x``.

    ``x`` (S, f, n³) with n >= window per axis; equivalent to
    ``mpf_pool(x[..., :wx, :wy, :wz], p)``.  The conv+pool fused pair
    passes the inverse transform's output *uncropped on the last axis*, so
    the crop never materializes — the pool's fragment slices stay inside
    the window by the MPF size constraint (window+1) % p == 0.
    """
    window = tuple(int(w) for w in window)
    n = x.shape[2:]
    if any((wi + 1) % p for wi in window):
        raise ValueError(f"MPF needs (window+1)%p==0, got window={window}, p={p}")
    if any(wi > ni for wi, ni in zip(window, n)):
        raise ValueError(f"window {window} larger than input {n}")
    if not resolve_use_pallas(use_pallas):
        return _ref.mpf_pool_window(x, p, window)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    f = x.shape[1]
    padF = (-f) % _k.F_BLOCK
    if padF:
        x = jnp.pad(x, ((0, 0), (0, padF), (0, 0), (0, 0), (0, 0)))
    o = _k.mpf_pool_window_blocked(
        x.astype(jnp.float32), p=p, window=window, interpret=interpret
    )
    return o[:, :f]
