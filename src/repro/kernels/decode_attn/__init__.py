"""Fused GQA flash-decode attention kernel (serving hot spot)."""

from . import kernel, ops, ref  # noqa: F401
