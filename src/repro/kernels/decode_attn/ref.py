"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attn(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
) -> jnp.ndarray:
    """q (B, H, d); k/v (B, S, Hkv, d); lengths (B,) valid KV entries.

    GQA: H = G·Hkv, query head h attends to kv head h // G ... here heads are
    grouped contiguously: q reshaped (B, Hkv, G, d).
    Returns (B, H, d) in q.dtype; softmax in f32.
    """
    B, H, d = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, d)
    # accumulate in f32 WITHOUT materializing f32 copies of the cache —
    # an explicit k.astype(f32) here gets hoisted by XLA outside the
    # layer scan, converting the whole stacked cache at once.
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", qg, k, preferred_element_type=jnp.float32
    ) / jnp.sqrt(d).astype(jnp.float32)
    S = k.shape[1]
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # (B, S)
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum(
        "bhgs,bshd->bhgd", w.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return o.reshape(B, H, d).astype(q.dtype)
