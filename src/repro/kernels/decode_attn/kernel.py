"""Pallas TPU flash-decode kernel: one query token vs a long KV cache.

Grid: (batch, kv-head, S-blocks) with the S dimension innermost so the
online-softmax state (running max m, denominator l, accumulator) lives in
VMEM scratch across S-blocks.  Each program handles the G = H/Hkv query
heads of one kv head — scores are a (G, S_BLOCK) VPU tile and the PV
contraction a (G, S_BLOCK) @ (S_BLOCK, d) MXU matmul.

This is the ZNNi "bigger batch under a memory ceiling" logic applied to
serving: only one S_BLOCK of K/V is resident per step, so the KV cache can
be HBM-resident (or mesh-sharded) at any length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

S_BLOCK = 512
NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *, scale):
    sb = pl.program_id(2)
    n_sb = pl.num_programs(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
    k = k_ref[0, :, 0].astype(jnp.float32)  # (S_BLOCK, d)
    v = v_ref[0, :, 0].astype(jnp.float32)  # (S_BLOCK, d)
    s = jax.lax.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (G, Sb)
    idx = sb * S_BLOCK + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < len_ref[0], s, NEG_INF)

    m_prev = m_ref[...]  # (G, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # (G, Sb)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(sb == n_sb - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def decode_attn_blocked(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    interpret: bool = True,
) -> jnp.ndarray:
    """q (B, Hkv, G, d); k/v (B, S, Hkv, d) with S % S_BLOCK == 0; lengths (B,)."""
    B, Hkv, G, d = q.shape
    S = k.shape[1]
    scale = 1.0 / (d**0.5)
    grid = (B, Hkv, S // S_BLOCK)
    q_spec = pl.BlockSpec((1, 1, G, d), lambda b, h, s: (b, h, 0, 0))
    kv_spec = pl.BlockSpec((1, S_BLOCK, 1, d), lambda b, h, s: (b, s, h, 0))
    len_spec = pl.BlockSpec((1,), lambda b, h, s: (b,))
    o_spec = pl.BlockSpec((1, 1, G, d), lambda b, h, s: (b, h, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=grid,
        in_specs=[len_spec, q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, q, k, v)
