"""Jitted wrapper for flash-decode: pads S, reshapes GQA groups, dispatches."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..dispatch import resolve_use_pallas
from . import kernel as _k
from . import ref as _ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attn(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """q (B,H,d); k/v (B,S,Hkv,d); lengths (B,) -> (B,H,d).  See ref.py."""
    if not resolve_use_pallas(use_pallas):
        return _ref.decode_attn(q, k, v, lengths)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, d = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    padS = (-S) % _k.S_BLOCK
    if padS:
        pad = ((0, 0), (0, padS), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    qg = q.reshape(B, Hkv, G, d)
    o = _k.decode_attn_blocked(qg, k, v, lengths.astype(jnp.int32), interpret=interpret)
    return o.reshape(B, H, d)
