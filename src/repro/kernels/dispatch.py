"""The ONE kernel-dispatch rule: ``use_pallas=None`` -> backend detection.

Every kernel op wrapper (and everything above them: ``core/fft_conv``,
``core/overlap_save``, ``core/mpf``, ``compile_plan``, ``PlanExecutor``,
``VolumeEngine``) takes ``use_pallas: Optional[bool]`` with a ``None``
default meaning "use the compiled Pallas kernels iff the backend can lower
them".  Before this module each call site hard-coded ``use_pallas=False``,
so the kernels never ran in production paths even on TPU; now the default
is resolved in exactly one place and an explicit ``True``/``False`` is
still an override (tests pass ``True`` to exercise interpret mode off-TPU;
the dry-run/roofline paths pass ``False`` to pin the XLA oracle).
"""

from __future__ import annotations

from typing import Optional

import jax


def backend_supports_pallas() -> bool:
    """True iff the default backend lowers our Pallas kernels compiled.

    Mosaic lowering exists for TPU; on CPU/GPU the kernels only run in
    interpret mode, which is a correctness tool, not a fast path — so
    auto-detection enables Pallas on TPU only.
    """
    return jax.default_backend() == "tpu"


def resolve_use_pallas(use_pallas: Optional[bool] = None) -> bool:
    """Resolve a tri-state ``use_pallas`` flag to a concrete bool."""
    if use_pallas is None:
        return backend_supports_pallas()
    return bool(use_pallas)
