"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel is a subpackage with kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with a ``use_pallas`` dispatch), and ref.py (the
pure-jnp oracle the tests sweep against).

Dispatch rule (``dispatch.py``): every wrapper takes
``use_pallas: Optional[bool]`` — ``None`` (the default everywhere) means
``backend_supports_pallas()``, i.e. the compiled kernels are bound
automatically on TPU and the XLA oracle runs elsewhere.  Tests pass
``use_pallas=True`` off-TPU to run the kernels in interpret mode against
the oracles.
"""

from .dispatch import backend_supports_pallas, resolve_use_pallas  # noqa: F401
from . import cmul_mad, decode_attn, direct_conv3d, mpf_pool, os_segment  # noqa: F401, E402
