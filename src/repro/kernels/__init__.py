"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel is a subpackage with kernel.py (pl.pallas_call + BlockSpec),
ops.py (jit'd wrapper with a ``use_pallas`` dispatch), and ref.py (the
pure-jnp oracle the tests sweep against).

The dry-run/roofline paths run the XLA oracle (Pallas cannot lower on the
CPU backend); on TPU, ``use_pallas=True`` selects the kernels.
"""

from . import cmul_mad, decode_attn, direct_conv3d, mpf_pool  # noqa: F401
