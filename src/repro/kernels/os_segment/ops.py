"""Jitted wrappers for the fused overlap-save segment kernel.

Pads/splits complex operands into float32 planes, builds the per-spec
DFT matrices (host-side, memoized — they are trace-time constants of
the frozen ``OverlapSaveSpec``), dispatches kernel vs ref, and
reassembles the per-segment output blocks into the valid output
columns (the ``tail_len`` / ``lead`` crops of the unfused path).

Three entry points mirror ``core/overlap_save.py``:

* ``os_segment_fused``      — full grid from cached spectra
                              (``os_apply_from_spectra``'s fused form);
* ``os_segment_fused_tail`` — trailing segments only
                              (``os_apply_tail_from_spectra``'s form);
* ``os_segment_conv``       — from raw input, segment FFT in-kernel
                              (``overlap_save_conv``'s form).

``fprime_chunk`` maps onto the kernel's output-channel block size, so a
per-layer schedule tunes how much spectral accumulator each grid step
holds in VMEM.
"""

from __future__ import annotations

import functools
from functools import partial
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..dispatch import resolve_use_pallas
from . import kernel as _k
from . import ref as _ref


def _pad_up(n: int, mult: int) -> int:
    return n + (-n) % mult


@functools.lru_cache(maxsize=None)
def _inverse_mats(
    fft_shape: Tuple[int, int, int], crop: Tuple[int, int, int]
) -> Tuple[np.ndarray, ...]:
    """Per-axis inverse matmul-DFT matrices with the crop folded in.

    ea (A, s) complex: e^{+2πi a x/A}/A — only the segment's ``seg_core``
    output rows.  eb (B', oy') complex, zero-filled in the padded rows/
    columns.  m (C'', oz') REAL pair: the hermitian-weighted inverse of
    the rfft bins — w_c·cos(2πcz/C)/C and −w_c·sin(2πcz/C)/C with w_c=1
    at DC and (even C) Nyquist, 2 elsewhere; sin vanishes at those bins,
    so the imaginary residue of the accumulated spectra is ignored there
    exactly like a c2r transform.
    """
    A, B, C = fft_shape
    s, oy, oz = crop
    Cb = C // 2 + 1
    Bp = _pad_up(B, 8)
    Cbp = _pad_up(Cb, 128)
    oyp = _pad_up(oy, 8)
    ozp = _pad_up(oz, 128)

    a = np.arange(A)[:, None]
    x = np.arange(s)[None, :]
    ea = np.exp(2j * np.pi * a * x / A) / A

    eb = np.zeros((Bp, oyp), np.complex128)
    bb = np.arange(B)[:, None]
    y = np.arange(oy)[None, :]
    eb[:B, :oy] = np.exp(2j * np.pi * bb * y / B) / B

    w = np.full(Cb, 2.0)
    w[0] = 1.0
    if C % 2 == 0:
        w[-1] = 1.0
    c = np.arange(Cb)[:, None]
    z = np.arange(oz)[None, :]
    ang = 2.0 * np.pi * c * z / C
    mr = np.zeros((Cbp, ozp), np.float32)
    mi = np.zeros((Cbp, ozp), np.float32)
    mr[:Cb, :oz] = w[:, None] * np.cos(ang) / C
    mi[:Cb, :oz] = -w[:, None] * np.sin(ang) / C

    return (
        ea.real.astype(np.float32), ea.imag.astype(np.float32),
        eb.real.astype(np.float32), eb.imag.astype(np.float32),
        mr, mi,
    )


@functools.lru_cache(maxsize=None)
def _forward_mats(
    fft_shape: Tuple[int, int, int], in_shape: Tuple[int, int, int]
) -> Tuple[np.ndarray, ...]:
    """Per-axis forward matmul-DFT matrices (zero-filled padding).

    fz (nz', C''): e^{-2πi t c/C} over the rfft bins; fy (ny', B'):
    full DFT of length B from ny live rows; fx (E, A): full DFT over
    the segment extent.  Zero rows multiply the (zero) spatial padding
    and zero columns keep the padded spectral bins inert.
    """
    A, B, C = fft_shape
    E, ny, nz = in_shape
    Cb = C // 2 + 1
    Bp = _pad_up(B, 8)
    Cbp = _pad_up(Cb, 128)
    nyp = _pad_up(ny, 8)
    nzp = _pad_up(nz, 128)

    fz = np.zeros((nzp, Cbp), np.complex128)
    t = np.arange(nz)[:, None]
    c = np.arange(Cb)[None, :]
    fz[:nz, :Cb] = np.exp(-2j * np.pi * t * c / C)

    fy = np.zeros((nyp, Bp), np.complex128)
    y = np.arange(ny)[:, None]
    b = np.arange(B)[None, :]
    fy[:ny, :B] = np.exp(-2j * np.pi * y * b / B)

    e = np.arange(E)[:, None]
    a = np.arange(A)[None, :]
    fx = np.exp(-2j * np.pi * e * a / A)

    return (
        fz.real.astype(np.float32), fz.imag.astype(np.float32),
        fy.real.astype(np.float32), fy.imag.astype(np.float32),
        fx.real.astype(np.float32), fx.imag.astype(np.float32),
    )


def _split_pad_W(W, fprime_chunk):
    """Real/imag planes of W (f', f, A, B, C''), padded: bins to the
    lane/sublane tile, f to F_CHUNK, f' to the output-channel block
    (``fprime_chunk`` or the default)."""
    fp, f = W.shape[:2]
    fpb = int(fprime_chunk) if fprime_chunk else _k.FP_BLOCK
    B, Cb = W.shape[3], W.shape[4]
    padB = (-B) % 8
    padC = (-Cb) % 128
    padf = (-f) % _k.F_CHUNK
    padF = (-fp) % fpb
    wr, wi = jnp.real(W).astype(jnp.float32), jnp.imag(W).astype(jnp.float32)
    pad = ((0, padF), (0, padf), (0, 0), (0, padB), (0, padC))
    if padF or padf or padB or padC:
        wr, wi = jnp.pad(wr, pad), jnp.pad(wi, pad)
    return wr, wi, fpb


def _split_pad_F(F):
    """Real/imag planes of F (N, Q, f, A, B, C''), same padding as W."""
    B, Cb = F.shape[4], F.shape[5]
    padB = (-B) % 8
    padC = (-Cb) % 128
    padf = (-F.shape[2]) % _k.F_CHUNK
    fr, fi = jnp.real(F).astype(jnp.float32), jnp.imag(F).astype(jnp.float32)
    pad = ((0, 0), (0, 0), (0, padf), (0, 0), (0, padB), (0, padC))
    if padf or padB or padC:
        fr, fi = jnp.pad(fr, pad), jnp.pad(fi, pad)
    return fr, fi


def _nb_bias(b, fp, fpb, fft_shape):
    """DC-bin bias column ``b·na·nb·nc`` padded to the f' block grid."""
    n_total = 1.0
    for d in fft_shape:
        n_total *= float(d)
    bias = jnp.zeros((fp,), jnp.float32) if b is None else b.astype(jnp.float32)
    padF = (-fp) % fpb
    if padF:
        bias = jnp.pad(bias, (0, padF))
    return (bias * n_total).reshape(-1, 1)


def _reassemble(out, spec, j0, fp, out_cols):
    """(N, Q, f'', s, oy'', oz'') kernel blocks -> trailing ``out_cols``
    valid output columns (the unfused path's tail/lead crops)."""
    s = spec.seg_core
    oy, oz = spec.out[1], spec.out[2]
    N, Q = out.shape[:2]
    o = out[:, :, :fp, :, :oy, :oz]
    o = jnp.transpose(o, (0, 2, 1, 3, 4, 5)).reshape(N, fp, Q * s, oy, oz)
    L = spec.out[0] if out_cols is None else int(out_cols)
    lead = (spec.out[0] - L) - j0 * s
    return o[:, :, lead : lead + L]


@partial(jax.jit, static_argnames=("spec", "out_cols", "fprime_chunk", "use_pallas", "interpret"))
def os_segment_fused(
    F: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec,
    *,
    out_cols: Optional[int] = None,
    fprime_chunk: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Fused segment MAD + DC-bias + inverse + crop from cached spectra.

    F (N, q, f, ña, ñb, ñc'') — spectra of the q TRAILING segments of
    ``spec`` (q = n_segments for the full grid); W (f', f, ...) cached
    conjugate kernel spectra; returns the trailing ``out_cols`` output
    columns (default all of ``spec.out[0]``) as (N, f', L, oy, oz).
    """
    if not resolve_use_pallas(use_pallas):
        return _ref.os_segment_fused(F, W, b, spec, out_cols)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    q = F.shape[1]
    j0 = spec.n_segments - q
    fp = W.shape[0]
    wr, wi, fpb = _split_pad_W(W, fprime_chunk)
    fr, fi = _split_pad_F(F)
    nb = _nb_bias(b, fp, fpb, spec.fft_shape)
    crop = (spec.seg_core,) + tuple(spec.out[1:])
    mats = [jnp.asarray(m) for m in _inverse_mats(tuple(spec.fft_shape), crop)]
    out = _k.os_segment_planes(
        fr, fi, wr, wi, nb, *mats, fp_block=fpb, interpret=interpret
    )
    return _reassemble(out, spec, j0, fp, out_cols)


def os_segment_fused_tail(
    F: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec,
    out_cols: int,
    *,
    fprime_chunk: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Trailing-segments form (the strip path's tail MAD)."""
    return os_segment_fused(
        F, W, b, spec,
        out_cols=int(out_cols), fprime_chunk=fprime_chunk,
        use_pallas=use_pallas, interpret=interpret,
    )


@partial(jax.jit, static_argnames=("spec", "fprime_chunk", "use_pallas", "interpret"))
def os_segment_conv(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec,
    *,
    fprime_chunk: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Self-contained fused segmented conv: miss-segment FFT in-kernel.

    x (N, f, *spec.n) real -> (N, f', *spec.out).  The registry
    ``overlap_save`` apply dispatches here when the Pallas path is on.
    """
    if not resolve_use_pallas(use_pallas):
        return _ref.os_segment_conv(x, W, b, spec)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fp = W.shape[0]
    f = x.shape[1]
    E = spec.seg_extent
    ny, nz = x.shape[3], x.shape[4]
    # aligned segment windows, tail zero-padded past the input extent
    if spec.input_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, spec.input_pad), (0, 0), (0, 0)))
    xs = jnp.stack([x[:, :, st : st + E] for st in spec.starts], axis=1)
    xs = xs.astype(jnp.float32)
    padf = (-f) % _k.F_CHUNK
    pady = (-ny) % 8
    padz = (-nz) % 128
    if padf or pady or padz:
        xs = jnp.pad(
            xs, ((0, 0), (0, 0), (0, padf), (0, 0), (0, pady), (0, padz))
        )
    wr, wi, fpb = _split_pad_W(W, fprime_chunk)
    nb = _nb_bias(b, fp, fpb, spec.fft_shape)
    fwd = [
        jnp.asarray(m)
        for m in _forward_mats(tuple(spec.fft_shape), (E, ny, nz))
    ]
    crop = (spec.seg_core,) + tuple(spec.out[1:])
    inv = [jnp.asarray(m) for m in _inverse_mats(tuple(spec.fft_shape), crop)]
    out = _k.os_segment_conv_planes(
        xs, *fwd, wr, wi, nb, *inv, fp_block=fpb, interpret=interpret
    )
    return _reassemble(out, spec, 0, fp, None)
