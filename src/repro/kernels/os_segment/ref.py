"""Pure-jnp oracle for the fused overlap-save segment pipeline.

The fused kernel runs, per aligned segment of ``core/overlap_save.py``'s
grid: (optional) segment FFT -> cached-kernel complex MAD over input
channels -> channel bias folded into the spectrum DC bin -> inverse
transform -> valid crop.  This module is the same pipeline as plain XLA
ops — jnp.fft transforms, one einsum per segment — deliberately free of
repro.core imports so the kernels package stays a leaf.

The oracle is mathematically identical to the unfused
``os_apply_from_spectra`` + ``add_channel_bias`` chain (the DC-bin bias
of a constant IS the spatial bias after the normalized inverse), so the
interpret-mode kernel is swept against it AND the unfused path in
``tests/test_os_fused.py``.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp


def _irfftn_crop(
    Z: jnp.ndarray,
    fft_shape: Sequence[int],
    crop: Sequence[int],
) -> jnp.ndarray:
    """Inverse 3D transform of pruned spectra, cropped to ``crop`` per axis.

    Same pass order as ``core.pruned_fft.pruned_irfftn`` with zero crop
    starts (all the segment pipeline needs): ifft a, crop; ifft b, crop;
    irfft c, crop.
    """
    nc = int(fft_shape[2])
    la, lb, lc = (int(s) for s in crop)
    Y = jnp.fft.ifft(Z, axis=-3)[..., :la, :, :]
    Y = jnp.fft.ifft(Y, axis=-2)[..., :, :lb, :]
    return jnp.fft.irfft(Y, n=nc, axis=-1)[..., :lc]


def _segment_spectra(x: jnp.ndarray, spec) -> jnp.ndarray:
    """Aligned segment spectra of raw input x (S, f, *spec.n).

    Returns (S, n_seg, f, na, nb, nc'') — the 'miss-segment FFT' stage of
    the fused pipeline, zero-padding the tail window like
    ``os_input_spectra``.
    """
    if spec.input_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, spec.input_pad), (0, 0), (0, 0)))
    segs = jnp.stack(
        [x[:, :, st : st + spec.seg_extent] for st in spec.starts], axis=1
    )
    na, nb, nc = spec.fft_shape
    Z = jnp.fft.rfft(segs.astype(jnp.float32), n=nc, axis=-1)
    Z = jnp.fft.fft(Z, n=nb, axis=-2)
    return jnp.fft.fft(Z, n=na, axis=-3)


def os_segment_fused(
    F: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec,
    out_cols: Optional[int] = None,
) -> jnp.ndarray:
    """Fused MAD + DC-bin bias + inverse + crop over the segment grid.

    F (S, q, f, na, nb, nc'') — spectra of the q TRAILING segments of
    ``spec``'s grid (q = n_segments for the full grid); W (f', f, ...)
    cached conjugate kernel spectra.  Returns the trailing ``out_cols``
    output columns (default: the full ``spec.out``) — (S, f', L, oy, oz).
    """
    q = F.shape[1]
    n_seg = spec.n_segments
    j0 = n_seg - q
    s = spec.seg_core
    crop = (s,) + tuple(spec.out[1:])
    n_total = 1
    for d in spec.fft_shape:
        n_total *= int(d)
    parts = []
    for jj in range(q):
        j = j0 + jj
        O = jnp.einsum("si...,ji...->sj...", F[:, jj], W)
        if b is not None:
            O = O.at[..., 0, 0, 0].add(b.astype(jnp.float32) * float(n_total))
        seg = _irfftn_crop(O, spec.fft_shape, crop)
        parts.append(seg if j < n_seg - 1 else seg[:, :, : spec.tail_len])
    x = jnp.concatenate(parts, axis=2)
    L = spec.out[0] if out_cols is None else int(out_cols)
    lead = (spec.out[0] - L) - j0 * s
    return x[:, :, lead : lead + L]


def os_segment_conv(
    x: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    spec,
) -> jnp.ndarray:
    """Self-contained oracle: segment FFT + fused MAD/bias/inverse/crop.

    x (S, f, *spec.n) real -> (S, f', *spec.out).  The from-raw-input form
    the registry's ``overlap_save`` apply dispatches to when the Pallas
    path is on (miss-segment FFT inside the same pipeline).
    """
    return os_segment_fused(_segment_spectra(x, spec), W, b, spec)
