"""Fused overlap-save segment pipeline (segment FFT→MAD→bias→inverse→crop)."""

from . import kernel, ops, ref  # noqa: F401
