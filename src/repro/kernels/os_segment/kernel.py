"""Pallas TPU kernel for the fused overlap-save segment pipeline.

One ``pallas_call`` over the segment grid runs, per aligned segment:

    [conv mode] segment FFT (matmul DFT, once per input-channel chunk)
    -> cached-kernel complex MAD, accumulated across input-channel chunks
    -> channel bias folded into the spectrum DC bin
    -> inverse transform (matmul DFT per axis, crop folded into the
       inverse matrices)
    -> one valid ``seg_core`` output column block per segment

replacing the unfused path's per-segment chain of 5+ XLA dispatches
(FFT, einsum, three inverse passes, bias) with a single kernel whose
output spectra never leave VMEM.

Transforms are matmul DFTs: per-segment extents are deliberately small
(``seg_core + k - 1``), so an O(n²) dense transform per axis is a few
small MXU GEMMs — and, unlike an in-kernel FFT, lets the *inverse* fold
its valid-crop into the matrix (only ``seg_core`` output rows are ever
computed; the paper's output-side pruning taken to its limit).  The
c-axis inverse bakes the hermitian weighting (w_c = 1 at DC/Nyquist,
2 elsewhere) into a real matrix pair, so only ``nc//2+1`` bins are
stored, exactly like the pruned spectra everywhere else in the repo.

Grid: (N, Q, f'-blocks, f-chunks) — the f-chunk axis LAST so the
per-(segment, f'-block) spectral accumulator lives in VMEM scratch
across consecutive steps (same revisit discipline as
``cmul_mad._bias_kernel``).  In conv mode the forward DFT of chunk kf
runs once at f'-block 0 and is cached in a second scratch buffer for
the remaining f'-blocks.

Layout: complex tensors are separate float32 real/imag planes; complex
multiplies use 3-real-mult Karatsuba.  ops.py pads bins to the lane
width and channels to the block sizes (zero padding is inert in the MAD
and multiplies zero matrix rows/columns in the transforms).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

FP_BLOCK = 8  # output channels per block (fprime_chunk overrides)
F_CHUNK = 8  # input channels accumulated per grid step


def _ein(expr, a, b):
    return jnp.einsum(expr, a, b, preferred_element_type=jnp.float32)


def _mad_accumulate(accr, acci, wr, wi, xr, xi, kf):
    """acc += W·X (complex, Karatsuba) for one input-channel chunk."""
    t1 = _ein("jfabc,fabc->jabc", wr, xr)
    t2 = _ein("jfabc,fabc->jabc", wi, xi)
    t3 = _ein("jfabc,fabc->jabc", wr + wi, xr + xi)

    @pl.when(kf == 0)
    def _init():
        accr[...] = t1 - t2
        acci[...] = t3 - t1 - t2

    @pl.when(kf > 0)
    def _accum():
        accr[...] += t1 - t2
        acci[...] += t3 - t1 - t2


def _emit(accr, acci, nb_ref, ear, eai, ebr, ebi, mr, mi, out_ref):
    """DC-bin bias + per-axis inverse matmul DFT + write the output block.

    The bias lands on spectral bin (0,0,0): the inverse matrices carry
    the 1/(na·nb·nc) normalization, so adding ``b·na·nb·nc`` there adds
    the constant ``b`` to every spatial output (the same identity as
    ``cmul_mad._bias_kernel``).  The a/b inverse matrices have only the
    cropped output rows; the c inverse is the real hermitian-weighted
    pair, so the spatial result appears directly in float32.
    """
    zr = accr[...]
    zi = acci[...]
    fpb = zr.shape[0]
    a_id = jax.lax.broadcasted_iota(jnp.int32, zr.shape, 1)
    b_id = jax.lax.broadcasted_iota(jnp.int32, zr.shape, 2)
    c_id = jax.lax.broadcasted_iota(jnp.int32, zr.shape, 3)
    dc = (a_id == 0) & (b_id == 0) & (c_id == 0)
    zr = zr + jnp.where(dc, nb_ref[...].reshape(fpb, 1, 1, 1), 0.0)
    # inverse axis a (x), output rows = the segment's seg_core columns
    y1r = _ein("jabc,ax->jxbc", zr, ear[...]) - _ein("jabc,ax->jxbc", zi, eai[...])
    y1i = _ein("jabc,ax->jxbc", zr, eai[...]) + _ein("jabc,ax->jxbc", zi, ear[...])
    # inverse axis b (y)
    y2r = _ein("jxbc,by->jxyc", y1r, ebr[...]) - _ein("jxbc,by->jxyc", y1i, ebi[...])
    y2i = _ein("jxbc,by->jxyc", y1r, ebi[...]) + _ein("jxbc,by->jxyc", y1i, ebr[...])
    # inverse axis c (z), real output via the hermitian-weighted pair
    out_ref[0, 0] = _ein("jxyc,cz->jxyz", y2r, mr[...]) + _ein(
        "jxyc,cz->jxyz", y2i, mi[...]
    )


def _fused_kernel(
    fr_ref, fi_ref, wr_ref, wi_ref, nb_ref,
    ear, eai, ebr, ebi, mr, mi,
    out_ref, accr, acci,
):
    """From cached segment spectra: MAD -> bias -> inverse -> crop."""
    kf = pl.program_id(3)
    _mad_accumulate(
        accr, acci, wr_ref[...], wi_ref[...], fr_ref[0, 0], fi_ref[0, 0], kf
    )

    @pl.when(kf == pl.num_programs(3) - 1)
    def _():
        _emit(accr, acci, nb_ref, ear, eai, ebr, ebi, mr, mi, out_ref)


def _conv_kernel(
    xs_ref, fzr, fzi, fyr, fyi, fxr, fxi, wr_ref, wi_ref, nb_ref,
    ear, eai, ebr, ebi, mr, mi,
    out_ref, sr, si, accr, acci,
):
    """From raw segments: forward matmul DFT (cached across f'-blocks)
    -> MAD -> bias -> inverse -> crop."""
    jp = pl.program_id(2)
    kf = pl.program_id(3)
    fc = xs_ref.shape[2]

    @pl.when(jp == 0)
    def _forward():
        x = xs_ref[0, 0]  # (F_CHUNK, E, ny, nz)
        # axis c: real -> complex (rfft bins only)
        xcr = _ein("feyz,zc->feyc", x, fzr[...])
        xci = _ein("feyz,zc->feyc", x, fzi[...])
        # axis b: full complex DFT
        x2r = _ein("feyc,yb->febc", xcr, fyr[...]) - _ein("feyc,yb->febc", xci, fyi[...])
        x2i = _ein("feyc,yb->febc", xcr, fyi[...]) + _ein("feyc,yb->febc", xci, fyr[...])
        # axis a: full complex DFT over the segment extent
        x3r = _ein("febc,ea->fabc", x2r, fxr[...]) - _ein("febc,ea->fabc", x2i, fxi[...])
        x3i = _ein("febc,ea->fabc", x2r, fxi[...]) + _ein("febc,ea->fabc", x2i, fxr[...])
        sr[pl.ds(kf * fc, fc)] = x3r
        si[pl.ds(kf * fc, fc)] = x3i

    _mad_accumulate(
        accr, acci, wr_ref[...], wi_ref[...],
        sr[pl.ds(kf * fc, fc)], si[pl.ds(kf * fc, fc)], kf,
    )

    @pl.when(kf == pl.num_programs(3) - 1)
    def _():
        _emit(accr, acci, nb_ref, ear, eai, ebr, ebi, mr, mi, out_ref)


def _full_spec(shape):
    n = len(shape)
    return pl.BlockSpec(shape, lambda nn, q, jp, kf, _n=n: (0,) * _n)


@functools.partial(jax.jit, static_argnames=("fp_block", "interpret"))
def os_segment_planes(
    fr, fi, wr, wi, nb, ear, eai, ebr, ebi, mr, mi,
    *, fp_block: int = FP_BLOCK, interpret: bool = True,
):
    """fr/fi (N, Q, f, A, B, C''), wr/wi (f', f, A, B, C''), nb (f', 1),
    inverse matrices ea (A, s), eb (B, oy), m (C'', oz) — all float32,
    pre-padded by ops.py — -> out (N, Q, f', s, oy, oz)."""
    N, Q, f, A, B, Cb = fr.shape
    fp = wr.shape[0]
    s = ear.shape[1]
    oy = ebr.shape[1]
    oz = mr.shape[1]
    grid = (N, Q, fp // fp_block, f // F_CHUNK)
    f_spec = pl.BlockSpec(
        (1, 1, F_CHUNK, A, B, Cb), lambda n, q, jp, kf: (n, q, kf, 0, 0, 0)
    )
    w_spec = pl.BlockSpec(
        (fp_block, F_CHUNK, A, B, Cb), lambda n, q, jp, kf: (jp, kf, 0, 0, 0)
    )
    nb_spec = pl.BlockSpec((fp_block, 1), lambda n, q, jp, kf: (jp, 0))
    # out block revisited across kf steps: the accumulator is scratch
    o_spec = pl.BlockSpec(
        (1, 1, fp_block, s, oy, oz), lambda n, q, jp, kf: (n, q, jp, 0, 0, 0)
    )
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[f_spec, f_spec, w_spec, w_spec, nb_spec]
        + [_full_spec(m.shape) for m in (ear, eai, ebr, ebi, mr, mi)],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((N, Q, fp, s, oy, oz), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((fp_block, A, B, Cb), jnp.float32),
            pltpu.VMEM((fp_block, A, B, Cb), jnp.float32),
        ],
        interpret=interpret,
    )(fr, fi, wr, wi, nb, ear, eai, ebr, ebi, mr, mi)


@functools.partial(jax.jit, static_argnames=("fp_block", "interpret"))
def os_segment_conv_planes(
    xs, fzr, fzi, fyr, fyi, fxr, fxi, wr, wi, nb,
    ear, eai, ebr, ebi, mr, mi,
    *, fp_block: int = FP_BLOCK, interpret: bool = True,
):
    """xs (N, Q, f, E, ny, nz) real segments; forward DFT matrices
    fz (nz, C''), fy (ny, B), fx (E, A); the rest as in
    ``os_segment_planes`` -> out (N, Q, f', s, oy, oz)."""
    N, Q, f, E, ny, nz = xs.shape
    fp = wr.shape[0]
    A, B, Cb = wr.shape[2:]
    s = ear.shape[1]
    oy = ebr.shape[1]
    oz = mr.shape[1]
    grid = (N, Q, fp // fp_block, f // F_CHUNK)
    x_spec = pl.BlockSpec(
        (1, 1, F_CHUNK, E, ny, nz), lambda n, q, jp, kf: (n, q, kf, 0, 0, 0)
    )
    w_spec = pl.BlockSpec(
        (fp_block, F_CHUNK, A, B, Cb), lambda n, q, jp, kf: (jp, kf, 0, 0, 0)
    )
    nb_spec = pl.BlockSpec((fp_block, 1), lambda n, q, jp, kf: (jp, 0))
    o_spec = pl.BlockSpec(
        (1, 1, fp_block, s, oy, oz), lambda n, q, jp, kf: (n, q, jp, 0, 0, 0)
    )
    return pl.pallas_call(
        _conv_kernel,
        grid=grid,
        in_specs=[x_spec]
        + [_full_spec(m.shape) for m in (fzr, fzi, fyr, fyi, fxr, fxi)]
        + [w_spec, w_spec, nb_spec]
        + [_full_spec(m.shape) for m in (ear, eai, ebr, ebi, mr, mi)],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((N, Q, fp, s, oy, oz), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((f, A, B, Cb), jnp.float32),
            pltpu.VMEM((f, A, B, Cb), jnp.float32),
            pltpu.VMEM((fp_block, A, B, Cb), jnp.float32),
            pltpu.VMEM((fp_block, A, B, Cb), jnp.float32),
        ],
        interpret=interpret,
    )(xs, fzr, fzi, fyr, fyi, fxr, fxi, wr, wi, nb, ear, eai, ebr, ebi, mr, mi)
