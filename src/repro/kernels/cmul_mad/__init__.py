"""FFT-domain complex multiply-accumulate over channels (ZNNi's MAD stage)."""

from . import kernel, ops, ref  # noqa: F401
