"""Jitted wrapper for the complex MAD: pads/flattens, dispatches kernel vs ref."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cmul_mad(
    X: jnp.ndarray,
    W: jnp.ndarray,
    *,
    use_pallas: bool = False,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """O[s,j] = Σ_i X[s,i] · W[j,i].  X (S,f,*sp), W (f',f,*sp) complex64.

    ``use_pallas=False`` (default; the dry-run/roofline path) uses the XLA
    einsum oracle.  ``use_pallas=True`` runs the Pallas kernel —
    ``interpret`` defaults to True off-TPU.
    """
    if not use_pallas:
        return _ref.cmul_mad(X, W)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, f = X.shape[:2]
    fp = W.shape[0]
    spatial = X.shape[2:]
    B = 1
    for s in spatial:
        B *= int(s)
    xr = jnp.real(X).reshape(S, f, B)
    xi = jnp.imag(X).reshape(S, f, B)
    wr = jnp.real(W).reshape(fp, f, B)
    wi = jnp.imag(W).reshape(fp, f, B)
    padB = (-B) % _k.BIN_BLOCK
    padF = (-fp) % _k.FP_BLOCK
    if padB:
        pad = ((0, 0), (0, 0), (0, padB))
        xr, xi, wr, wi = (jnp.pad(a, pad) for a in (xr, xi, wr, wi))
    if padF:
        pad = ((0, padF), (0, 0), (0, 0))
        wr, wi = jnp.pad(wr, pad), jnp.pad(wi, pad)
    o_r, o_i = _k.cmul_mad_planes(xr, xi, wr, wi, interpret=interpret)
    o = jax.lax.complex(o_r, o_i)[:, :fp, :B]
    return o.reshape(S, fp, *spatial)
