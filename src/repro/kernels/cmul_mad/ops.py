"""Jitted wrapper for the complex MAD: pads/flattens, dispatches kernel vs ref."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..dispatch import resolve_use_pallas
from . import kernel as _k
from . import ref as _ref


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def cmul_mad(
    X: jnp.ndarray,
    W: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """O[s,j] = Σ_i X[s,i] · W[j,i].  X (S,f,*sp), W (f',f,*sp) complex64.

    ``use_pallas=None`` resolves via ``kernels.resolve_use_pallas`` (the
    Pallas kernel on TPU, the XLA einsum oracle elsewhere); an explicit
    bool overrides.  ``interpret`` defaults to True off-TPU.
    """
    if not resolve_use_pallas(use_pallas):
        return _ref.cmul_mad(X, W)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, f = X.shape[:2]
    fp = W.shape[0]
    spatial = X.shape[2:]
    B = 1
    for s in spatial:
        B *= int(s)
    xr = jnp.real(X).reshape(S, f, B)
    xi = jnp.imag(X).reshape(S, f, B)
    wr = jnp.real(W).reshape(fp, f, B)
    wi = jnp.imag(W).reshape(fp, f, B)
    padB = (-B) % _k.BIN_BLOCK
    padF = (-fp) % _k.FP_BLOCK
    if padB:
        pad = ((0, 0), (0, 0), (0, padB))
        xr, xi, wr, wi = (jnp.pad(a, pad) for a in (xr, xi, wr, wi))
    if padF:
        pad = ((0, padF), (0, 0), (0, 0))
        wr, wi = jnp.pad(wr, pad), jnp.pad(wi, pad)
    o_r, o_i = _k.cmul_mad_planes(xr, xi, wr, wi, interpret=interpret)
    o = jax.lax.complex(o_r, o_i)[:, :fp, :B]
    return o.reshape(S, fp, *spatial)


@partial(jax.jit, static_argnames=("fft_shape", "use_pallas", "interpret"))
def cmul_mad_bias(
    X: jnp.ndarray,
    W: jnp.ndarray,
    b: jnp.ndarray | None,
    *,
    fft_shape: tuple[int, int, int],
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused epilogue: MAD + channel bias folded into the spectrum DC bin.

    X (S, f, ña, ñb, ñc'') and W (f', f, ña, ñb, ñc'') are pruned spectra at
    ``fft_shape = (na, nb, nc)`` (the REAL transform extents — needed to
    scale the bias: DC must carry ``b·na·nb·nc``).  Returns output spectra
    whose inverse transform already includes the bias, so the unfused
    path's separate ``add_channel_bias`` pass disappears.  The Pallas path
    runs MAD accumulation over input-channel chunks + the bias add in ONE
    ``pallas_call`` (kernel ``_bias_kernel``); the XLA path is the fused
    oracle in ref.py — same math, checkable against each other.
    """
    if not resolve_use_pallas(use_pallas):
        return _ref.cmul_mad_bias(X, W, b, fft_shape)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    S, f = X.shape[:2]
    fp = W.shape[0]
    spatial = X.shape[2:]
    B = 1
    for s in spatial:
        B *= int(s)
    n_total = 1
    for s in fft_shape:
        n_total *= int(s)
    xr = jnp.real(X).reshape(S, f, B)
    xi = jnp.imag(X).reshape(S, f, B)
    wr = jnp.real(W).reshape(fp, f, B)
    wi = jnp.imag(W).reshape(fp, f, B)
    bias = jnp.zeros((fp,), jnp.float32) if b is None else b.astype(jnp.float32)
    nb = bias * float(n_total)
    padB = (-B) % _k.BIN_BLOCK
    padF = (-fp) % _k.FP_BLOCK
    padf = (-f) % _k.F_CHUNK
    if padB:
        pad = ((0, 0), (0, 0), (0, padB))
        xr, xi, wr, wi = (jnp.pad(a, pad) for a in (xr, xi, wr, wi))
    if padf:
        # zero input-channel padding: contributes nothing to the MAD
        xr = jnp.pad(xr, ((0, 0), (0, padf), (0, 0)))
        xi = jnp.pad(xi, ((0, 0), (0, padf), (0, 0)))
        wr = jnp.pad(wr, ((0, 0), (0, padf), (0, 0)))
        wi = jnp.pad(wi, ((0, 0), (0, padf), (0, 0)))
    if padF:
        pad = ((0, padF), (0, 0), (0, 0))
        wr, wi = jnp.pad(wr, pad), jnp.pad(wi, pad)
        nb = jnp.pad(nb, (0, padF))
    o_r, o_i = _k.cmul_mad_bias_planes(
        xr, xi, wr, wi, nb.reshape(-1, 1), interpret=interpret
    )
    o = jax.lax.complex(o_r, o_i)[:, :fp, :B]
    return o.reshape(S, fp, *spatial)
