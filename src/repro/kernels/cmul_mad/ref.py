"""Pure-jnp oracle for the complex MAD: O[s,j] = Σ_i X[s,i] * W[j,i]."""

from __future__ import annotations

import jax.numpy as jnp


def cmul_mad(X: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """X (S, f, *spatial) complex, W (f', f, *spatial) complex -> (S, f', *spatial)."""
    return jnp.einsum("si...,ji...->sj...", X, W)
