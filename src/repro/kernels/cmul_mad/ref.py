"""Pure-jnp oracle for the complex MAD: O[s,j] = Σ_i X[s,i] * W[j,i]."""

from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp


def cmul_mad(X: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """X (S, f, *spatial) complex, W (f', f, *spatial) complex -> (S, f', *spatial)."""
    return jnp.einsum("si...,ji...->sj...", X, W)


def cmul_mad_bias(
    X: jnp.ndarray,
    W: jnp.ndarray,
    b: Optional[jnp.ndarray],
    fft_shape: Sequence[int],
) -> jnp.ndarray:
    """Fused-epilogue oracle: MAD + bias folded into the DC bin.

    Adding ``b[j] · N_total`` (N_total = prod(fft_shape), the REAL spatial
    transform size — not the pruned spectral extent) to spectral bin
    (0, 0, 0) adds the constant ``b[j]`` to every spatial output of the
    inverse transform, so downstream ``pruned_irfftn`` + crop needs no
    separate bias pass.  This is the XLA form of the fused kernel — the
    interpret-mode Pallas path is checked against it.
    """
    O = cmul_mad(X, W)
    if b is None:
        return O
    n_total = 1
    for s in fft_shape:
        n_total *= int(s)
    return O.at[..., 0, 0, 0].add(b.astype(jnp.float32) * float(n_total))
