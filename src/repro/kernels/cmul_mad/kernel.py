"""Pallas TPU kernel for the FFT-domain channel MAD (ZNNi Alg. 2/3 hot spot).

The operation — for every frequency bin b: O[s, j, b] = Σ_i X[s, i, b] · W[j, i, b]
— is an *elementwise-batched* complex contraction: the weights differ per
bin, so it is VPU work (not an MXU GEMM).  We tile bins to the lane width
and keep a full input-channel column per block so each block does
f · f'_blk complex MACs per bin with one pass over X.

Complex multiply uses 3-real-mult Karatsuba (beyond-paper micro-opt):
    t1 = xr·wr;  t2 = xi·wi;  t3 = (xr+xi)·(wr+wi)
    or = t1 − t2;  oi = t3 − t1 − t2

Layout: complex tensors are passed as separate float32 real/imag planes
(Pallas has no complex dtype).  Bins are padded to BIN_BLOCK lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIN_BLOCK = 512  # lanes per block: multiple of 128 (VPU lane width)
FP_BLOCK = 8  # output channels per block
F_CHUNK = 8  # input channels accumulated per fused-epilogue grid step


def _kernel(xr_ref, xi_ref, wr_ref, wi_ref, or_ref, oi_ref):
    xr = xr_ref[0]  # (f, Bb)
    xi = xi_ref[0]
    wr = wr_ref[...]  # (FP_BLOCK, f, Bb)
    wi = wi_ref[...]
    # Karatsuba per output channel j: contract over f on the sublane axis.
    t1 = jnp.einsum("jfb,fb->jb", wr, xr, preferred_element_type=jnp.float32)
    t2 = jnp.einsum("jfb,fb->jb", wi, xi, preferred_element_type=jnp.float32)
    t3 = jnp.einsum(
        "jfb,fb->jb", wr + wi, xr + xi, preferred_element_type=jnp.float32
    )
    or_ref[0] = t1 - t2
    oi_ref[0] = t3 - t1 - t2


@functools.partial(jax.jit, static_argnames=("interpret",))
def cmul_mad_planes(
    xr: jnp.ndarray,
    xi: jnp.ndarray,
    wr: jnp.ndarray,
    wi: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """xr/xi (S, f, B) f32, wr/wi (f', f, B) f32 -> (or, oi) (S, f', B).

    B must be a multiple of BIN_BLOCK and f' a multiple of FP_BLOCK
    (ops.py pads).
    """
    S, f, B = xr.shape
    fp = wr.shape[0]
    grid = (S, fp // FP_BLOCK, B // BIN_BLOCK)
    x_spec = pl.BlockSpec((1, f, BIN_BLOCK), lambda s, j, b: (s, 0, b))
    w_spec = pl.BlockSpec((FP_BLOCK, f, BIN_BLOCK), lambda s, j, b: (j, 0, b))
    o_spec = pl.BlockSpec((1, FP_BLOCK, BIN_BLOCK), lambda s, j, b: (s, j, b))
    out_shape = [
        jax.ShapeDtypeStruct((S, fp, B), jnp.float32),
        jax.ShapeDtypeStruct((S, fp, B), jnp.float32),
    ]
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec],
        out_specs=[o_spec, o_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi)


def _bias_kernel(xr_ref, xi_ref, wr_ref, wi_ref, nb_ref, or_ref, oi_ref):
    """Fused epilogue: chunked MAD accumulation + DC-bin bias, one program.

    Grid (S, f'-blocks, bin-blocks, f-chunks); the f-chunk axis is LAST so
    the output block is revisited across consecutive steps and the partial
    MAD accumulates in place (VMEM-resident, no HBM round trip per chunk).
    The bias lands on the final accumulation step of bin-block 0: adding
    ``b[j]·N`` to the DC bin of the output spectrum is exactly adding the
    constant ``b[j]`` to every spatial output of the inverse transform
    (irfftn normalizes by 1/N), so the separate post-inverse bias pass of
    the unfused path disappears into the MAD kernel.
    """
    xr = xr_ref[0]  # (F_CHUNK, Bb)
    xi = xi_ref[0]
    wr = wr_ref[...]  # (FP_BLOCK, F_CHUNK, Bb)
    wi = wi_ref[...]
    t1 = jnp.einsum("jfb,fb->jb", wr, xr, preferred_element_type=jnp.float32)
    t2 = jnp.einsum("jfb,fb->jb", wi, xi, preferred_element_type=jnp.float32)
    t3 = jnp.einsum(
        "jfb,fb->jb", wr + wi, xr + xi, preferred_element_type=jnp.float32
    )
    acc_r = t1 - t2
    acc_i = t3 - t1 - t2
    kf = pl.program_id(3)

    @pl.when(kf == 0)
    def _init():
        or_ref[0] = acc_r
        oi_ref[0] = acc_i

    @pl.when(kf > 0)
    def _accumulate():
        or_ref[0] += acc_r
        oi_ref[0] += acc_i

    # bias epilogue: the DC bin is flat bin 0, i.e. lane 0 of bin-block 0
    # (2D broadcasted_iota — 1D iota does not lower on TPU).  The bias
    # spectrum of a constant is purely real, so only the real plane moves.
    @pl.when((kf == pl.num_programs(3) - 1) & (pl.program_id(2) == 0))
    def _bias():
        lane = jax.lax.broadcasted_iota(jnp.int32, (FP_BLOCK, BIN_BLOCK), 1)
        or_ref[0] += jnp.where(lane == 0, nb_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cmul_mad_bias_planes(
    xr: jnp.ndarray,
    xi: jnp.ndarray,
    wr: jnp.ndarray,
    wi: jnp.ndarray,
    nb: jnp.ndarray,
    *,
    interpret: bool = True,
):
    """Fused MAD + bias.  xr/xi (S, f, B), wr/wi (f', f, B), nb (f', 1) f32.

    ``nb`` is the pre-scaled DC contribution ``b · N_total`` per output
    channel.  B must be a multiple of BIN_BLOCK, f' of FP_BLOCK, and f of
    F_CHUNK (ops.py pads; zero f-padding contributes nothing to the MAD).
    Returns (or, oi) (S, f', B).
    """
    S, f, B = xr.shape
    fp = wr.shape[0]
    grid = (S, fp // FP_BLOCK, B // BIN_BLOCK, f // F_CHUNK)
    x_spec = pl.BlockSpec((1, F_CHUNK, BIN_BLOCK), lambda s, j, b, kf: (s, kf, b))
    w_spec = pl.BlockSpec(
        (FP_BLOCK, F_CHUNK, BIN_BLOCK), lambda s, j, b, kf: (j, kf, b)
    )
    nb_spec = pl.BlockSpec((FP_BLOCK, 1), lambda s, j, b, kf: (j, 0))
    # the out index map ignores kf: consecutive f-chunk steps revisit the
    # same output block, which is what makes the in-place accumulation legal
    o_spec = pl.BlockSpec((1, FP_BLOCK, BIN_BLOCK), lambda s, j, b, kf: (s, j, b))
    out_shape = [
        jax.ShapeDtypeStruct((S, fp, B), jnp.float32),
        jax.ShapeDtypeStruct((S, fp, B), jnp.float32),
    ]
    return pl.pallas_call(
        _bias_kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, w_spec, w_spec, nb_spec],
        out_specs=[o_spec, o_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, wr, wi, nb)
