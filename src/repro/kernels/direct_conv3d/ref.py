"""Pure-jnp oracle: 'valid' cross-correlation via lax.conv_general_dilated."""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def conv3d(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x (S, f, nx, ny, nz), w (f', f, kx, ky, kz) -> (S, f', n'x, n'y, n'z)."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1, 1),
        padding="VALID",
        dimension_numbers=("NCDHW", "OIDHW", "NCDHW"),
    )
