"""Pallas TPU kernel: direct 3D conv as k³ offset-shifted matmuls.

TPU-native formulation (DESIGN.md §3): the channel dimension is the MXU
contraction.  For each kernel offset (dx,dy,dz):

    O[j, x,y,z] += W[j, i, dx,dy,dz] @ I[i, x+dx, y+dy, z+dz]

i.e. k³ matmuls of shape (f'_blk × f) @ (f × tile_voxels).  The kernel
offsets are a static Python loop (k ≤ 9 in the paper's nets), so the whole
block is one unrolled chain of MXU dots accumulating in VMEM.

Blocking: grid over (batch, f' blocks, x-tiles).  The input block holds the
x-tile plus its (k-1)-halo and the full (y, z) extent; the planner/ops
wrapper sizes tiles so the block fits VMEM.  Input x-halo overlap is
expressed by passing the whole (per-batch) input as a VMEM-resident block
and slicing with `pl.ds` — revisited blocks stay resident across the
innermost grid dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

FP_BLOCK = 8  # output channels per block


def _kernel(x_ref, w_ref, o_ref, *, k: int, tx: int):
    s = 0  # x_ref block is (1, f, nx, ny, nz)
    it = pl.program_id(2)
    f = x_ref.shape[1]
    ny, nz = x_ref.shape[3], x_ref.shape[4]
    npy, npz = ny - k + 1, nz - k + 1
    w = w_ref[...]  # (FP_BLOCK, f, k, k, k)
    acc = jnp.zeros((FP_BLOCK, tx * npy * npz), jnp.float32)
    for dx in range(k):
        for dy in range(k):
            for dz in range(k):
                xs = x_ref[s, :, pl.ds(it * tx + dx, tx), pl.ds(dy, npy), pl.ds(dz, npz)]
                acc += jax.lax.dot(
                    w[:, :, dx, dy, dz],
                    xs.reshape(f, tx * npy * npz),
                    preferred_element_type=jnp.float32,
                )
    o_ref[0] = acc.reshape(FP_BLOCK, tx, npy, npz)


@functools.partial(jax.jit, static_argnames=("tx", "interpret"))
def conv3d_blocked(
    x: jnp.ndarray, w: jnp.ndarray, *, tx: int, interpret: bool = True
) -> jnp.ndarray:
    """x (S, f, nx, ny, nz) f32, w (f', f, k³) f32; f' % FP_BLOCK == 0,
    (nx - k + 1) % tx == 0 (ops.py pads/chunks)."""
    S, f, nx, ny, nz = x.shape
    fp, _, k, _, _ = w.shape
    npx, npy, npz = nx - k + 1, ny - k + 1, nz - k + 1
    grid = (S, fp // FP_BLOCK, npx // tx)
    x_spec = pl.BlockSpec((1, f, nx, ny, nz), lambda s, j, t: (s, 0, 0, 0, 0))
    w_spec = pl.BlockSpec((FP_BLOCK, f, k, k, k), lambda s, j, t: (j, 0, 0, 0, 0))
    o_spec = pl.BlockSpec((1, FP_BLOCK, tx, npy, npz), lambda s, j, t: (s, j, t, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, k=k, tx=tx),
        grid=grid,
        in_specs=[x_spec, w_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((S, fp, npx, npy, npz), jnp.float32),
        interpret=interpret,
    )(x, w)
