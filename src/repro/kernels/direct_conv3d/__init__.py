"""Direct 3D 'valid' convolution as k³ shifted MXU matmuls."""

from . import kernel, ops, ref  # noqa: F401
