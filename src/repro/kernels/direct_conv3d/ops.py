"""Jitted wrapper for direct 3D conv: pads channels, picks x-tile, dispatches."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..dispatch import resolve_use_pallas
from . import kernel as _k
from . import ref as _ref


def _pick_tx(npx: int) -> int:
    for t in (8, 4, 2, 1):
        if npx % t == 0:
            return t
    return 1


@partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def conv3d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """'valid' cross-correlation; see ref.py for semantics."""
    if not resolve_use_pallas(use_pallas):
        return _ref.conv3d(x, w)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    fp = w.shape[0]
    padF = (-fp) % _k.FP_BLOCK
    if padF:
        w = jnp.pad(w, ((0, padF), (0, 0), (0, 0), (0, 0), (0, 0)))
    k = w.shape[2]
    npx = x.shape[2] - k + 1
    tx = _pick_tx(npx)
    o = _k.conv3d_blocked(
        x.astype(jnp.float32), w.astype(jnp.float32), tx=tx, interpret=interpret
    )
    return o[:, :fp]
