"""Training driver: step builder + CLI loop with checkpointing and the
fault-tolerance hooks.

``make_train_step`` builds the jitted (params, opt, batch) -> (params, opt,
metrics) step used by the dry-run (AOT lowered at full scale) and the CLI
(executed for real on reduced configs in this CPU container).

Run:  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b \
          --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp

from .. import checkpoint as ckpt_lib
from ..configs import get_config
from ..data import SyntheticTokenPipeline, TokenPipelineConfig
from ..distributed.fault_tolerance import HeartbeatMonitor
from ..models import build_model
from ..optim import AdamWConfig, apply_updates, init_state


def make_train_step(model, ocfg: AdamWConfig, *, remat: bool = True):
    """Pure train step: loss -> grads -> AdamW.  Metrics: loss, gnorm."""

    def step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch, remat=remat)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, new_opt = apply_updates(params, grads, opt_state, ocfg)
        from ..optim.adamw import global_norm

        return new_params, new_opt, {"loss": loss, "gnorm": global_norm(grads)}

    return step


def train_loop(
    *,
    arch: str,
    steps: int,
    batch: int,
    seq: int,
    reduced: bool = True,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 20,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 10,
    resume: bool = True,
) -> dict:
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    ocfg = AdamWConfig(lr=lr)

    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    opt_state = init_state(params, ocfg)
    start_step = 0
    if ckpt_dir and resume:
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            params, opt_state = ckpt_lib.restore(
                ckpt_dir, last, (params, opt_state)
            )
            start_step = last
            print(f"[train] resumed from step {last}")

    pipe = SyntheticTokenPipeline(
        TokenPipelineConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch, seed=seed)
    )
    step_fn = jax.jit(make_train_step(model, ocfg))
    monitor = HeartbeatMonitor(n_workers=1)

    losses = []
    t_prev = time.monotonic()
    for s in range(start_step, steps):
        raw = pipe.batch_at(s)
        b = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.frontend == "patch":
            b["patch_embeds"] = jnp.zeros((batch, 8, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.frontend == "audio":
            b["frame_embeds"] = (
                jax.random.normal(jax.random.fold_in(key, s), (batch, cfg.enc_seq, cfg.d_model)) * 0.05
            ).astype(jnp.dtype(cfg.dtype))
        params, opt_state, metrics = step_fn(params, opt_state, b)
        loss = float(metrics["loss"])
        losses.append(loss)
        now = time.monotonic()
        monitor.heartbeat(0, s, now - t_prev)
        t_prev = now
        if s % log_every == 0:
            print(f"[train] step {s:5d} loss {loss:.4f}")
        if ckpt_dir and (s + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, s + 1, (params, opt_state), async_=True)
            ckpt_lib.prune_old(ckpt_dir, keep=3)
    if ckpt_dir:
        ckpt_lib.save(ckpt_dir, steps, (params, opt_state))
    return {"losses": losses, "params": params, "final_loss": losses[-1] if losses else None}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    out = train_loop(
        arch=args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        reduced=args.reduced, ckpt_dir=args.ckpt_dir, lr=args.lr,
    )
    print(f"[train] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
