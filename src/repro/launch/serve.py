"""Serving driver: run the batched engine on a (reduced) model.

Run:  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b \
          --reduced --requests 8 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import build_model
from ..serving import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.enc_dec:
        raise SystemExit("serve driver targets decoder-only archs")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, EngineConfig(slots=args.slots, max_seq=args.max_seq))

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab, size=(args.prompt_len,)).astype(np.int32), args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)

    t0 = time.monotonic()
    ticks = 0
    while any(not r.done for r in reqs) and ticks < 10_000:
        eng.step()
        ticks += 1
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.out) for r in reqs)
    print(
        f"[serve] {args.requests} requests, {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens / dt:.1f} tok/s, {ticks} ticks)"
    )
    for r in reqs[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
