"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first jax
init, and tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = None
    n = 1
    for s in shape:
        n *= s
    avail = jax.devices()
    if len(avail) > n:
        devices = avail[:n]
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes), devices=devices
    )


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh(
        (n // model, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
