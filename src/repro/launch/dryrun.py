import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    " --xla_cpu_strict_dot_conv_math=true"
    " --xla_allow_excess_precision=false"
)

# (flags 2-3: keep bf16 dot operands unconverted — as the TPU MXU does —
# and keep bf16 round-trips so XLA cannot hoist f32 copies of stacked
# weights out of the layer scan; without them the CPU backend's float
# normalization inflates temp-memory and bytes-accessed ~2x vs the TPU
# target.  Residual CPU-only f32 artifacts are noted in EXPERIMENTS.md.)

# Multi-pod dry-run (DESIGN.md §7): lower + compile every
# (architecture x input-shape x mesh) cell with ShapeDtypeStruct inputs —
# no allocation — and record memory_analysis / cost_analysis / collective
# bytes for the roofline table.  The two lines above MUST precede any other
# import (jax locks the device count on first init); they are scoped to
# this entry point only (tests and benches see 1 device).

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import ARCHS, SHAPES, cell_applicable, get_config, get_shape  # noqa: E402
from ..core.hw import TPU_V5E  # noqa: E402
from ..distributed.sharding import batch_shardings, param_shardings, replicated  # noqa: E402
from ..models import build_model  # noqa: E402
from ..optim import AdamWConfig, init_state  # noqa: E402
from ..roofline.analysis import collective_bytes, roofline  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .train import make_train_step  # noqa: E402

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def _model_flops(cfg, shape) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq


def _apply_overrides(cfg, overrides: Dict[str, Any]):
    """Config-level hillclimb levers (EXPERIMENTS.md §Perf)."""
    if overrides.get("pad_q_groups") and cfg.attn is not None:
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn, pad_q_groups=overrides["pad_q_groups"])
        )
    if overrides.get("expand_kv") and cfg.attn is not None:
        cfg = dataclasses.replace(
            cfg, attn=dataclasses.replace(cfg.attn, expand_kv=True)
        )
    if overrides.get("dtype"):
        cfg = dataclasses.replace(cfg, dtype=overrides["dtype"])
    if overrides.get("moe_routing_groups"):
        cfg = dataclasses.replace(cfg, moe_routing_groups=overrides["moe_routing_groups"])
    if overrides.get("decode_replicate_activations"):
        cfg = dataclasses.replace(cfg, decode_replicate_activations=True)
    return cfg


def _compile_cell(cfg, shape, mesh_kind: str, overrides: Dict[str, Any]):
    """Lower + compile one (config x shape x mesh); returns raw artifacts."""
    cfg = _apply_overrides(cfg, overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    model = build_model(cfg)
    t0 = time.monotonic()
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = model.input_specs(shape)
    bsh = batch_shardings(cfg, shape, mesh, specs)

    if shape.kind == "train":
        state_dtype = "bfloat16" if cfg.param_count() > 40e9 else "float32"
        ocfg = AdamWConfig(state_dtype=overrides.get("opt_state_dtype", state_dtype))
        zero_mode = overrides.get("zero", "zero3")  # zero3 | zero1 (H2 lever)
        psh = param_shardings(cfg, params_sds, mesh, zero=(zero_mode == "zero3"))
        opt_sds = jax.eval_shape(lambda p: init_state(p, ocfg), params_sds)
        osh_inner = param_shardings(cfg, params_sds, mesh, zero=True)
        osh = {"m": osh_inner, "v": osh_inner, "step": replicated(mesh)}
        msh = {"loss": replicated(mesh), "gnorm": replicated(mesh)}
        step = make_train_step(model, ocfg, remat=overrides.get("remat", True))
        jitted = jax.jit(
            step,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, msh),
            donate_argnums=(0, 1),  # params/opt updated in place
        )
        with mesh:
            lowered = jitted.lower(params_sds, opt_sds, specs)
    elif shape.kind == "prefill":
        psh = param_shardings(
            cfg, params_sds, mesh, zero=bool(overrides.get("serve_zero", False))
        )

        def serve_step(p, batch):
            return model.prefill(p, batch, cache_len=shape.seq_len)

        jitted = jax.jit(serve_step, in_shardings=(psh, bsh))
        with mesh:
            lowered = jitted.lower(params_sds, specs)
    else:  # decode
        psh = param_shardings(
            cfg, params_sds, mesh, zero=bool(overrides.get("serve_zero", False))
        )

        def serve_step(p, tokens, caches):
            return model.decode_step(p, tokens, caches)

        jitted = jax.jit(
            serve_step,
            in_shardings=(psh, bsh["tokens"], bsh["caches"]),
            donate_argnums=(2,),  # caches updated in place
        )
        with mesh:
            lowered = jitted.lower(params_sds, specs["tokens"], specs["caches"])

    compiled = lowered.compile()
    t1 = time.monotonic()
    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {
        "compile_s": t1 - t0,
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "total_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes + mem.output_size_in_bytes,
        },
        "fits_hbm": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) < TPU_V5E.hbm_bytes,
        "cost": {k: float(v) for k, v in ca.items() if isinstance(v, (int, float))},
        "collectives": coll,
    }


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    verbose: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    chips = 512 if mesh_kind == "multi" else 256
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
    }
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec["skipped"] = why
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: SKIP ({why})")
        return rec

    overrides = overrides or {}
    raw = _compile_cell(cfg, shape, mesh_kind, overrides)
    ca, coll = raw["cost"], raw["collectives"]
    mf = _model_flops(cfg, shape)
    terms = roofline(
        float(ca.get("flops", 0.0)),
        float(ca.get("bytes accessed", 0.0)),
        coll.get("total", 0.0),
        hw=TPU_V5E, chips=chips, model_flops=mf,
    )
    rec.update(raw)
    rec["roofline"] = terms.to_dict()
    rec["overrides"] = overrides
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_kind} ({chips} chips)")
        print(f"  memory_analysis: {raw['mem']}")
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: { {k: f'{v:.3e}' for k, v in coll.items()} }")
        print(
            f"  roofline: compute={terms.compute_s:.3e}s memory={terms.memory_s:.3e}s "
            f"collective={terms.collective_s:.3e}s dominant={terms.dominant} "
            f"useful_ratio={terms.useful_flops_ratio:.3f}"
        )
    return rec


def probe_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    verbose: bool = True,
    overrides: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    """Trip-count-corrected roofline via depth extrapolation.

    XLA's HLO cost analysis counts a while-loop body ONCE regardless of
    trip count, so full-depth numbers undercount the layer scan by ~R.
    Fix: compile the model at 1x and 2x pattern depth with all INNER chunk
    loops unrolled (REPRO_UNROLL_INNER=1 — required, asserted below), then
    extrapolate linearly: f(L) = f1 + (L/PL - 1) * (f2 - f1).  Linear-in-
    depth is exact for everything inside the scan (per-layer flops/bytes/
    collectives are depth-independent); only XLA fusion differences between
    the probe and full compiles are approximated.
    """
    from .. import flags as _flags

    assert _flags.UNROLL_INNER, "probe mode requires REPRO_UNROLL_INNER=1"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    chips = 512 if mesh_kind == "multi" else 256
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "probe": True,
    }
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec["skipped"] = why
        return rec

    overrides = overrides or {}
    PL = len(cfg.block_pattern)

    def probe_cfg(reps: int):
        kw: Dict[str, Any] = {"n_layers": PL * reps}
        if cfg.enc_dec:
            kw["n_enc_layers"] = reps
        return dataclasses.replace(cfg, **kw)

    raws = [_compile_cell(probe_cfg(r), shape, mesh_kind, overrides) for r in (1, 2)]

    def _lin(v1: float, v2: float) -> float:
        # negative slopes are partitioner noise on out-of-loop ops; the
        # quantity itself cannot shrink with depth, so clamp at the probes.
        reps_full = cfg.n_layers / PL
        return max(v1 + (reps_full - 1.0) * (v2 - v1), v1, v2)

    flops = _lin(raws[0]["cost"].get("flops", 0.0), raws[1]["cost"].get("flops", 0.0))
    byts = _lin(
        raws[0]["cost"].get("bytes accessed", 0.0),
        raws[1]["cost"].get("bytes accessed", 0.0),
    )
    coll = _lin(
        raws[0]["collectives"].get("total", 0.0),
        raws[1]["collectives"].get("total", 0.0),
    )
    mf = _model_flops(cfg, shape)
    terms = roofline(flops, byts, coll, hw=TPU_V5E, chips=chips, model_flops=mf)
    rec.update(
        {
            "roofline": terms.to_dict(),
            "probe_raw": raws,
            "compile_s": sum(r["compile_s"] for r in raws),
            "overrides": overrides,
        }
    )
    if verbose:
        print(
            f"[probe] {arch} x {shape_name} x {mesh_kind}: "
            f"compute={terms.compute_s:.3e}s memory={terms.memory_s:.3e}s "
            f"collective={terms.collective_s:.3e}s dominant={terms.dominant} "
            f"useful_ratio={terms.useful_flops_ratio:.3f}"
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default=DEFAULT_OUT)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--probe", action="store_true",
                    help="depth-extrapolated roofline (needs REPRO_UNROLL_INNER=1)")
    args = ap.parse_args()
    if args.probe and args.tag == "baseline":
        args.tag = "probe"

    os.makedirs(args.out_dir, exist_ok=True)
    archs = list(ARCHS) if args.all or args.arch is None else [args.arch]
    shapes = [s.name for s in SHAPES] if args.all or args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                fname = os.path.join(
                    args.out_dir, f"{args.tag}__{arch}__{shape}__{mesh_kind}.json"
                )
                if args.skip_existing and os.path.exists(fname):
                    continue
                try:
                    fn = probe_cell if args.probe else run_cell
                    rec = fn(arch, shape, mesh_kind)
                except Exception as e:  # noqa: BLE001 — record the failure
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    n_fail += 1
                    print(f"[dryrun] {arch} x {shape} x {mesh_kind}: FAIL {e}")
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=2, default=str)
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
