"""Mamba2 block via SSD (state-space duality) chunked form [arXiv:2405.21060].

Layout follows the reference minimal implementation: the sequence is split
into chunks of Q; within a chunk the quadratic "attention-like" form runs
(MXU-friendly einsums with decay matrix L = exp(segsum(a))), and a scan
carries the (H, P, N) state across chunks.

Block: projections -> causal conv1d (width d_conv over x/B/C, cached for
decode) -> SSD -> gated RMSNorm (silu(z)) -> out_proj.

The input projection is stored as separate mats (w_z, w_x, w_B, w_C, w_dt)
rather than one fused w_in so each output dim shards cleanly on the model
axis (a fused dim's split points would not align with shard boundaries —
see distributed/sharding.py).

Decode carries (conv_cache (B, d_conv-1, ch), ssm_state (B,H,P,N)) —
O(1) in sequence length, which is why long_500k runs for ssm/hybrid archs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .. import flags
from ..configs.base import SSMConfig
from .norms import rmsnorm
from .dot import mm


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a (..., q) -> (..., q, q): L[i,j] = Σ_{j < t <= i} a_t (lower-tri, else -inf)."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    i = jnp.arange(q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssm_init(key, d_model: int, s: SSMConfig, dtype) -> dict:
    di = s.d_inner(d_model)
    nh = s.n_ssm_heads(d_model)
    ks = jax.random.split(key, 6)
    scale = (2.0 / d_model) ** 0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d_model, di)) * scale).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d_model, di)) * scale).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (d_model, s.d_state)) * scale).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (d_model, s.d_state)) * scale).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d_model, nh)) * scale).astype(dtype),
        "conv_x": (jax.random.normal(ks[5], (s.d_conv, di)) * 0.3).astype(dtype),
        "conv_B": jnp.zeros((s.d_conv, s.d_state), dtype).at[-1].set(1.0),
        "conv_C": jnp.zeros((s.d_conv, s.d_state), dtype).at[-1].set(1.0),
        "conv_bx": jnp.zeros((di,), dtype),
        "conv_bB": jnp.zeros((s.d_state,), dtype),
        "conv_bC": jnp.zeros((s.d_state,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), dtype),
        "w_out": (jax.random.normal(jax.random.fold_in(key, 7), (di, d_model)) * (2.0 / di) ** 0.5).astype(dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over time + silu. x (B, L, ch), w (d_conv, ch)."""
    dk = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (dk - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for t in range(dk):
        out = out + pad[:, t : t + x.shape[1]] * w[t]
    return jax.nn.silu(out + b)


def ssd_chunked(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A_log: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    chunk: int,
    init_state: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD core.  x (b,l,h,p); dt (b,l,h) >0; A_log (h,); B/C (b,l,n); D (h,).

    Returns (y (b,l,h,p), final_state (b,h,p,n)).  l must be divisible by
    `chunk` (callers pad).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    nc = l // chunk
    a = (-jnp.exp(A_log)[None, None] * dt).astype(jnp.float32)  # (b,l,h)
    xdt = (x * dt[..., None]).astype(jnp.float32)

    xc = xdt.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,q)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, n)

    L = jnp.exp(_segsum(ac))  # (b,h,c,q,q)
    y_diag = jnp.einsum("bcqn,bckn,bhcqk,bckhp->bcqhp", Cc, Bc, L, xc)

    a_cum = jnp.cumsum(ac, axis=-1)  # (b,h,c,q)
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)  # (b,h,c,q)
    states = jnp.einsum("bckn,bhck,bckhp->bchpn", Bc, decay_states, xc)

    chunk_decay = jnp.exp(a_cum[..., -1])  # (b,h,c)

    def scan_fn(s, inp):
        st, dec = inp  # st (b,h,p,n), dec (b,h)
        s_new = s * dec[..., None, None] + st
        return s_new, s  # emit state at chunk START

    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    final, prev_states = flags.chunk_scan(
        scan_fn,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,c,h,p,n)

    state_decay = jnp.exp(a_cum)  # (b,h,c,q)
    y_off = jnp.einsum("bcqn,bchpn,bhcq->bcqhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p) + x.astype(jnp.float32) * D[None, None, :, None]
    return y, final


def _project(p, x, s: SSMConfig):
    z = mm(x, p["w_z"])
    xs = mm(x, p["w_x"])
    Bv = mm(x, p["w_B"])
    Cv = mm(x, p["w_C"])
    dt = mm(x, p["w_dt"])
    return z, xs, Bv, Cv, dt


def _conv_all(p, xs, Bv, Cv):
    xs = _causal_conv(xs, p["conv_x"], p["conv_bx"])
    Bv = _causal_conv(Bv, p["conv_B"], p["conv_bB"])
    Cv = _causal_conv(Cv, p["conv_C"], p["conv_bC"])
    return xs, Bv, Cv


def _run_ssd(p, xs, Bv, Cv, dt, z, s: SSMConfig, L: int, nh: int, di: int, init_state=None):
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    pad = (-L) % s.chunk
    if pad:
        zp = ((0, 0), (0, pad), (0, 0))
        xs, Bv, Cv, dtf = jnp.pad(xs, zp), jnp.pad(Bv, zp), jnp.pad(Cv, zp), jnp.pad(dtf, zp)
    B_ = xs.shape[0]
    y, state = ssd_chunked(
        xs.reshape(B_, L + pad, nh, s.headdim), dtf, p["A_log"], Bv, Cv, p["D"],
        s.chunk, init_state=init_state,
    )
    y = y[:, :L].reshape(B_, L, di).astype(z.dtype)
    return rmsnorm(y * jax.nn.silu(z), p["norm_scale"]), state


def ssm_apply(p: dict, x: jnp.ndarray, s: SSMConfig, d_model: int) -> jnp.ndarray:
    """Full-sequence Mamba2 block. x (B, L, d_model) -> (B, L, d_model)."""
    di, nh = s.d_inner(d_model), s.n_ssm_heads(d_model)
    L = x.shape[1]
    z, xs, Bv, Cv, dt = _project(p, x, s)
    xs, Bv, Cv = _conv_all(p, xs, Bv, Cv)
    y, _ = _run_ssd(p, xs, Bv, Cv, dt, z, s, L, nh, di)
    return mm(y, p["w_out"])


def ssm_prefill(
    p: dict, x: jnp.ndarray, s: SSMConfig, d_model: int
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Like ssm_apply but also returns (conv_cache, ssm_state) for decode.

    conv_cache holds the last d_conv-1 *pre-conv* channel values of
    concat(x, B, C)."""
    di, nh = s.d_inner(d_model), s.n_ssm_heads(d_model)
    L = x.shape[1]
    z, xs, Bv, Cv, dt = _project(p, x, s)
    conv_cache = jnp.concatenate([xs, Bv, Cv], axis=-1)[:, -(s.d_conv - 1) :, :]
    xs, Bv, Cv = _conv_all(p, xs, Bv, Cv)
    y, state = _run_ssd(p, xs, Bv, Cv, dt, z, s, L, nh, di)
    return mm(y, p["w_out"]), (conv_cache.astype(x.dtype), state.astype(jnp.float32))


def ssm_decode(
    p: dict,
    x: jnp.ndarray,
    s: SSMConfig,
    d_model: int,
    conv_cache: jnp.ndarray,
    state: jnp.ndarray,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One decode step.  x (B, 1, d_model); conv_cache (B, d_conv-1, ch);
    state (B, h, p, n).  Returns (y (B,1,d_model), new caches)."""
    di, nh = s.d_inner(d_model), s.n_ssm_heads(d_model)
    B_ = x.shape[0]
    z, xs, Bv, Cv, dt = _project(p, x[:, 0], s)  # (B, ·)
    xBC = jnp.concatenate([xs, Bv, Cv], axis=-1)  # (B, ch)
    window = jnp.concatenate([conv_cache.astype(xBC.dtype), xBC[:, None]], axis=1)
    w_all = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    b_all = jnp.concatenate([p["conv_bx"], p["conv_bB"], p["conv_bC"]], axis=-1)
    conv = jax.nn.silu(jnp.einsum("btc,tc->bc", window, w_all) + b_all)
    new_conv_cache = window[:, 1:]
    xs, Bv, Cv = jnp.split(conv, [di, di + s.d_state], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    xh = xs.reshape(B_, nh, s.headdim).astype(jnp.float32)
    dA = jnp.exp(-jnp.exp(p["A_log"])[None] * dtf)  # (B, nh)
    dBx = jnp.einsum("bn,bh,bhp->bhpn", Bv.astype(jnp.float32), dtf, xh)
    state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cv.astype(jnp.float32), state)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B_, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return mm(y, p["w_out"])[:, None], (new_conv_cache, state)
