"""LM layer zoo: norms, RoPE/M-RoPE, GQA attention, MLP, MoE, Mamba2-SSD,
embeddings, and modality frontend stubs."""

from . import attention, embedding, mlp, moe, norms, rope, ssm, stubs  # noqa: F401
