"""Matmul helper: bf16 operands, f32 accumulation, result in compute dtype.

This is how the TPU MXU actually executes bf16 matmuls (f32 accumulators),
and — via --xla_cpu_strict_dot_conv_math — how the CPU dry-run lowers them
too.  Without the explicit preferred_element_type, XLA's float
normalization rewrites bf16 dots as f32 dots with convert()s on both
operands; the weight-side converts get hoisted out of the layer scan and
materialize an f32 copy of EVERY stacked weight (2x param memory).  Every
weight-touching matmul in the framework goes through these helpers.
"""

from __future__ import annotations

import jax.numpy as jnp


def mm(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x @ w with f32 accumulation, result cast back to x.dtype."""
    return jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def contract(pattern: str, *args: jnp.ndarray, out_dtype=None) -> jnp.ndarray:
    """einsum with f32 accumulation; result in out_dtype (default: first arg's)."""
    out = jnp.einsum(pattern, *args, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or args[0].dtype)
