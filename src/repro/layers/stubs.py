"""Modality frontend stubs (per the assignment: the transformer backbone is
real; vision/audio frontends supply *precomputed* embeddings via
``input_specs()``).

* ``vlm``  (qwen2-vl): the first `n_patches` sequence positions carry patch
  embeddings (B, n_patches, d_model); the rest are text tokens.  M-RoPE ids
  for the patch block use a synthetic (t, h, w) grid; text continues 1D.
* ``audio`` (whisper): the encoder consumes frame embeddings
  (B, enc_seq, d_model) directly.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

# patch grid assumed by the stub (t=4, h=8, w=8 -> 256 patch positions)
VLM_PATCH_GRID: Tuple[int, int, int] = (4, 8, 8)
VLM_N_PATCHES = VLM_PATCH_GRID[0] * VLM_PATCH_GRID[1] * VLM_PATCH_GRID[2]


def vlm_splice(tok_embeds: jnp.ndarray, patch_embeds: jnp.ndarray) -> jnp.ndarray:
    """Replace the first n_patches positions with the patch embeddings."""
    n = patch_embeds.shape[1]
    return jnp.concatenate([patch_embeds.astype(tok_embeds.dtype), tok_embeds[:, n:]], axis=1)


def vlm_mrope_positions(B: int, S: int, n_patches: int = VLM_N_PATCHES) -> jnp.ndarray:
    """(3, B, S) M-RoPE ids: (t,h,w) grid over the patch block (truncated to
    n_patches), then text positions continuing from max(t,h,w) of the grid
    (qwen2-vl scheme)."""
    t, h, w = VLM_PATCH_GRID
    ids_t = jnp.repeat(jnp.arange(t), h * w)[:n_patches]
    ids_h = jnp.tile(jnp.repeat(jnp.arange(h), w), t)[:n_patches]
    ids_w = jnp.tile(jnp.arange(w), t * h)[:n_patches]
    grid = jnp.stack([ids_t, ids_h, ids_w])  # (3, n_patches)
    start = int(max(t, h, w))
    text = jnp.arange(S - n_patches) + start  # (S - n_patches,)
    text3 = jnp.broadcast_to(text[None], (3, S - n_patches))
    pos = jnp.concatenate([grid, text3], axis=1)  # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, B, S))
