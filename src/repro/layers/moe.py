"""Top-k MoE with capacity-bounded sort-free dispatch (GShard-style).

Dispatch avoids the (T, E, C) one-hot tensor: per-expert positions come
from a (T·K, E) cumsum, tokens scatter into an (E·C, d) buffer (unique
destinations), expert FFNs run as one batched einsum over stacked expert
weights, and results gather back with the router weights.  Tokens beyond
an expert's capacity are dropped (standard GShard semantics); the router
adds the load-balancing aux loss of Shazeer et al.

Sharding: the expert dimension E shards over the `model` axis when
divisible (expert parallelism — jamba's 16e on a 16-way axis); otherwise
the per-expert d_ff shards (tensor parallelism inside experts — mixtral's
and grok's 8e).  Both arrive via the name-based rules in
distributed/sharding.py; this module is sharding-agnostic.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from .dot import contract
from ..distributed.constraints import constrain


def moe_init(key, d: int, d_ff: int, cfg: MoEConfig, act: str, dtype) -> dict:
    kr, k1, k2, k3 = jax.random.split(key, 4)
    E = cfg.n_experts
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / d_ff) ** 0.5
    p = {
        "router": (jax.random.normal(kr, (d, E)) * s_in).astype(jnp.float32),
        "w_in": (jax.random.normal(k1, (E, d, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (E, d_ff, d)) * s_out).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (E, d, d_ff)) * s_in).astype(dtype)
    return p


def moe_apply(
    p: dict,
    x: jnp.ndarray,
    cfg: MoEConfig,
    act: str,
    *,
    routing_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``routing_groups`` (EXPERIMENTS.md §Perf H2): capacity and dispatch are
    computed per token GROUP instead of globally.  With groups == the data-
    parallel degree, the rank cumsum and the dispatch scatter never cross a
    shard boundary, so GSPMD partitions them shard-locally (the global
    cumsum otherwise serializes via collective-permute, and the scatter's
    destination indices force an all-gather of the dispatch buffer).
    Per-group capacity C/G is the standard DeepSpeed/GShard local-capacity
    semantics.
    """
    capacity_factor = cfg.capacity_factor
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    Gr = routing_groups
    assert T % Gr == 0, (T, Gr)
    Tg = T // Gr
    xt = x.reshape(Gr, Tg, d)
    xt = constrain(xt, "batch")

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (Gr, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)  # (Gr, Tg, K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # aux load-balance loss: E * Σ_e fraction_tokens(e) * mean_prob(e)
    me = jnp.mean(probs, axis=(0, 1))
    one = jax.nn.one_hot(topi[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(one, axis=(0, 1))
    aux = E * jnp.sum(me * ce) * cfg.aux_loss_weight

    C = int(max(1, capacity_factor * Tg * K / E))
    flat_i = topi.reshape(Gr, Tg * K)  # expert id per (token, k) slot
    oh = jax.nn.one_hot(flat_i, E, dtype=jnp.int32)  # (Gr, TgK, E)
    pos = jnp.cumsum(oh, axis=1) - oh  # rank within expert, per group
    pos = jnp.sum(pos * oh, axis=2)  # (Gr, TgK)
    keep = pos < C
    dest = jnp.where(keep, flat_i * C + pos, E * C)  # overflow -> scratch row

    xr = jnp.repeat(xt, K, axis=1)  # (Gr, TgK, d) token per slot
    buf = jnp.zeros((Gr, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, dst, v: b.at[dst].set(v))(buf, dest, xr)
    ein = buf[:, : E * C].reshape(Gr, E, C, d)
    ein = constrain(ein, "batch")

    # fold groups into the capacity dim so the expert einsums keep the
    # (e batch, c free, d contract) form the CPU thunk runtime executes
    ein2 = ein.transpose(1, 0, 2, 3).reshape(E, Gr * C, d)
    if act == "swiglu":
        h = jax.nn.silu(contract("ecd,edf->ecf", ein2, p["w_gate"])) * contract(
            "ecd,edf->ecf", ein2, p["w_in"]
        )
    else:
        h = jax.nn.gelu(contract("ecd,edf->ecf", ein2, p["w_in"]))
    eout = contract("ecf,efd->ecd", h, p["w_out"])
    eout = eout.reshape(E, Gr, C, d).transpose(1, 0, 2, 3).reshape(Gr, E * C, d)
    eout = jnp.concatenate([eout, jnp.zeros((Gr, 1, d), eout.dtype)], axis=1)

    slot_out = jax.vmap(jnp.take, in_axes=(0, 0, None))(eout, dest, 0)
    slot_out = slot_out * topw.reshape(Gr, Tg * K, 1).astype(eout.dtype)
    out = jnp.sum(slot_out.reshape(Gr, Tg, K, d), axis=2)
    return out.reshape(B, S, d), aux
