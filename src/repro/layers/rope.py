"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE splits the head_dim/2 rotary frequencies into (temporal, height,
width) sections, each rotated by its own position id.  For text-only
positions all three ids coincide and M-RoPE reduces to RoPE (a property the
tests assert).  Position ids: (B, S) for RoPE, (3, B, S) for M-RoPE.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim//2,)."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., d) with cos/sin (..., d//2) broadcastable; pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape)


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x (B, S, H, d), positions (B, S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float,
    sections: Tuple[int, int, int],
) -> jnp.ndarray:
    """x (B, S, H, d), positions (3, B, S) (t/h/w ids), sections sum to d//2."""
    d = x.shape[-1]
    if sum(sections) != d // 2:
        raise ValueError(f"mrope sections {sections} must sum to {d // 2}")
    inv = rope_freqs(d, theta)  # (d/2,)
    ang_all = positions[..., None].astype(jnp.float32) * inv  # (3, B, S, d/2)
    # first `sections[0]` freqs use temporal ids, next height, rest width.
    s0, s1, _ = sections
    ang = jnp.concatenate(
        [ang_all[0, ..., :s0], ang_all[1, ..., s0 : s0 + s1], ang_all[2, ..., s0 + s1 :]],
        axis=-1,
    )  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def text_mrope_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """Text-only M-RoPE ids: all three axes share the 1D position."""
    return jnp.broadcast_to(positions[None], (3, *positions.shape))
