"""Normalization layers (RMSNorm / LayerNorm), f32 statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_apply(kind: str, x, p):
    if kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_init(kind: str, d: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
