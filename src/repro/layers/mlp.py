"""Feed-forward blocks: SwiGLU (3 mats) and GELU (2 mats)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .dot import mm


def mlp_init(key, d: int, d_ff: int, act: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = (2.0 / d) ** 0.5
    s_out = (2.0 / d_ff) ** 0.5
    p = {
        "w_in": (jax.random.normal(k1, (d, d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(k2, (d_ff, d)) * s_out).astype(dtype),
    }
    if act == "swiglu":
        p["w_gate"] = (jax.random.normal(k3, (d, d_ff)) * s_in).astype(dtype)
    else:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d,), dtype)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(mm(x, p["w_gate"])) * mm(x, p["w_in"])
        return mm(h, p["w_out"])
    h = jax.nn.gelu(mm(x, p["w_in"]) + p["b_in"])
    return mm(h, p["w_out"]) + p["b_out"]
