"""GQA attention: q-chunked training/prefill path + cached decode path.

Training/prefill uses a query-chunked online-softmax formulation (pure
lax.scan over query blocks — compiles anywhere, memory bounded by
(q_chunk × S) score tiles instead of S², which is what makes prefill_32k
lowerable).  Sliding-window (`window`) masks |i-j| >= window.

Decode takes one query token against a (B, S_max, Hkv, d) cache and
dispatches through ``repro.kernels.decode_attn`` (Pallas on TPU, einsum
oracle elsewhere).

Shapes: x (B, S, d_model); heads grouped contiguously (H = Hkv·G with
query head h served by kv head h // G).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .. import flags
from ..configs.base import AttnConfig
from ..distributed.constraints import constrain
from ..kernels.decode_attn import ops as da_ops
from .dot import contract
from .rope import apply_mrope, apply_rope, text_mrope_positions

NEG_INF = -1e30


def attn_init(key, d_model: int, a: AttnConfig, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = (2.0 / d_model) ** 0.5
    so = (2.0 / (a.n_heads * a.head_dim)) ** 0.5
    G = a.q_per_kv
    Gp = a.n_heads_eff // a.n_kv_heads
    wq = jax.random.normal(kq, (d_model, a.n_kv_heads, G, a.head_dim)) * s
    wo = jax.random.normal(ko, (a.n_kv_heads, G, a.head_dim, d_model)) * so
    if Gp != G:
        # group-preserving zero padding: kv head j still serves the first G
        # q slots of its group; padded slots are zero in wq AND wo, so they
        # contribute nothing and their gradients stay zero.
        wq = jnp.zeros((d_model, a.n_kv_heads, Gp, a.head_dim)).at[:, :, :G].set(wq)
        wo = jnp.zeros((a.n_kv_heads, Gp, a.head_dim, d_model)).at[:, :G].set(wo)
    H = a.n_heads_eff
    p = {
        "wq": wq.reshape(d_model, H, a.head_dim).astype(dtype),
        "wk": (jax.random.normal(kk, (d_model, a.n_kv_heads, a.head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d_model, a.n_kv_heads, a.head_dim)) * s).astype(dtype),
        "wo": wo.reshape(H, a.head_dim, d_model).astype(dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((H, a.head_dim), dtype)
        p["bk"] = jnp.zeros((a.n_kv_heads, a.head_dim), dtype)
        p["bv"] = jnp.zeros((a.n_kv_heads, a.head_dim), dtype)
    return p


def _qkv(p, x, a: AttnConfig, positions, rope: bool = True):
    q = contract("bsd,dhk->bshk", x, p["wq"])
    k = contract("bsd,dhk->bshk", x, p["wk"])
    v = contract("bsd,dhk->bshk", x, p["wv"])
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if rope and a.rope_kind == "rope":
        q = apply_rope(q, positions, a.rope_theta)
        k = apply_rope(k, positions, a.rope_theta)
    elif rope and a.rope_kind == "mrope":
        pos3 = positions if positions.ndim == 3 else text_mrope_positions(positions)
        q = apply_mrope(q, pos3, a.rope_theta, a.mrope_sections)
        k = apply_mrope(k, pos3, a.rope_theta, a.mrope_sections)
    return q, k, v


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    q_offset: int = 0,
    expand_kv: bool = False,
) -> jnp.ndarray:
    """q (B, Sq, H, d), k/v (B, Skv, Hkv, d) -> (B, Sq, H, d).

    Query-chunked with f32 softmax; masks: causal (query position
    q_offset+i attends to kv j <= i) and optional sliding window.

    ``expand_kv`` (EXPERIMENTS.md §Perf H1): repeat kv heads to the full H
    before the score einsum.  The grouped (Hkv, G) layout defeats GSPMD
    when the model axis divides H but neither factor (qwen2-vl: 16 | 32 but
    4 x 8) — the partitioner all-gathers scores.  The expanded tensor
    shards cleanly on H, and each chip only materializes its own H/tp
    expanded heads.
    """
    B, Sq, H, d = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = 1.0 / (d**0.5)
    qc = min(q_chunk, Sq)
    pad = (-Sq) % qc
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // qc
    kv_j = jnp.arange(Skv)

    if expand_kv and G > 1:
        k = constrain(jnp.repeat(k, G, axis=2), "batch", None, "model")
        v = constrain(jnp.repeat(v, G, axis=2), "batch", None, "model")
    if expand_kv:
        qg = q.reshape(B, nq, qc, H, 1, d)  # degenerate group: plain MHA
    else:
        qg = q.reshape(B, nq, qc, Hkv, G, d)

    def one_chunk(qi_idx):
        qi, idx = qi_idx
        # f32 accumulation via preferred_element_type — explicit astype(f32)
        # on k/v would be hoisted into a full-tensor f32 copy by XLA.
        s = jnp.einsum("bqhgd,bshd->bhgqs", qi, k, preferred_element_type=jnp.float32)
        s = constrain(s, "batch", "model")
        s = s * scale
        q_pos = q_offset + idx * qc + jnp.arange(qc)
        m = jnp.ones((qc, Skv), bool)
        if causal:
            m &= kv_j[None, :] <= q_pos[:, None]
        if window is not None:
            m &= kv_j[None, :] > q_pos[:, None] - window
        s = jnp.where(m[None, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bhgqs,bshd->bqhgd", w.astype(v.dtype), v, preferred_element_type=jnp.float32
        )
        return o.astype(q.dtype)

    # nested remat: the backward recomputes each chunk's scores/softmax
    # instead of keeping (qc x Skv) f32 residuals for every chunk at once —
    # flash-attention memory behaviour from composition, not a kernel.
    o = flags.chunk_map(jax.checkpoint(one_chunk), (jnp.moveaxis(qg, 1, 0), jnp.arange(nq)))
    o = jnp.moveaxis(o, 0, 1).reshape(B, nq * qc, H, d)
    return o[:, :Sq]


def attn_apply(
    p: dict,
    x: jnp.ndarray,
    a: AttnConfig,
    positions: jnp.ndarray,
    *,
    window: Optional[int] = None,
    q_chunk: int = 1024,
) -> jnp.ndarray:
    """Full training/prefill self-attention (causal)."""
    q, k, v = _qkv(p, x, a, positions)
    o = chunked_attention(
        q, k, v, causal=True, window=window, q_chunk=q_chunk, expand_kv=a.expand_kv
    )
    return contract("bshk,hkd->bsd", o, p["wo"])


def attn_prefill(
    p: dict,
    x: jnp.ndarray,
    a: AttnConfig,
    positions: jnp.ndarray,
    cache_len: int,
    *,
    window: Optional[int] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Prefill: returns output and (k, v) padded to cache_len."""
    q, k, v = _qkv(p, x, a, positions)
    o = chunked_attention(q, k, v, causal=True, window=window, expand_kv=a.expand_kv)
    S = x.shape[1]
    pad = ((0, 0), (0, cache_len - S), (0, 0), (0, 0))
    return contract("bshk,hkd->bsd", o, p["wo"]), (jnp.pad(k, pad), jnp.pad(v, pad))


def attn_decode(
    p: dict,
    x: jnp.ndarray,
    a: AttnConfig,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    window: Optional[int] = None,
    use_pallas: bool = False,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One decode step.  x (B, 1, d); cache (B, S_max, Hkv, d); lengths (B,)
    = tokens already in cache.  Returns (out (B,1,d), updated cache).

    The new token is written at index `lengths`; attention then covers
    lengths+1 entries (window-limited if `window`).
    """
    B = x.shape[0]
    positions = lengths[:, None]  # (B, 1)
    q, k_new, v_new = _qkv(p, x, a, positions)
    # one-hot select update: dtype-preserving and shard-local on a
    # sequence-sharded cache (a vmapped dynamic-update-slice lowers to a
    # scatter, which XLA upcasts bf16 -> f32, doubling cache memory).
    S = cache_k.shape[1]
    onehot = jnp.arange(S)[None, :] == lengths[:, None]  # (B, S)
    sel = onehot[:, :, None, None]
    cache_k = jnp.where(sel, k_new.astype(cache_k.dtype), cache_k)
    cache_v = jnp.where(sel, v_new.astype(cache_v.dtype), cache_v)
    valid = lengths + 1
    if window is None:
        o = da_ops.decode_attn(q[:, 0], cache_k, cache_v, valid, use_pallas=use_pallas)
    else:
        # windowed decode: mask entries outside [valid - window, valid).
        # (baseline keeps the full cache; the optimized path uses a ring
        # buffer of `window` entries — see EXPERIMENTS.md §Perf.)
        S = cache_k.shape[1]
        j = jnp.arange(S)
        keep = (j[None] < valid[:, None]) & (j[None] >= (valid - window)[:, None])
        H, d = q.shape[2], q.shape[3]
        Hkv = cache_k.shape[2]
        G = H // Hkv
        qf = q[:, 0].reshape(B, Hkv, G, d)
        s = jnp.einsum(
            "bhgd,bshd->bhgs", qf, cache_k, preferred_element_type=jnp.float32
        ) / (d**0.5)
        s = jnp.where(keep[:, None, None], s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum(
            "bhgs,bshd->bhgd", w.astype(cache_v.dtype), cache_v,
            preferred_element_type=jnp.float32,
        )
        o = o.reshape(B, H, d).astype(x.dtype)
    out = contract("bhk,hkd->bd", o, p["wo"])[:, None]
    return out, (cache_k, cache_v)
