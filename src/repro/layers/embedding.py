"""Token embeddings and the logits head."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from .. import flags
from .dot import mm


def embed_init(key, vocab: int, d: int, tie: bool, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"tok": (jax.random.normal(k1, (vocab, d)) * 0.02).astype(dtype)}
    if not tie:
        p["head"] = (jax.random.normal(k2, (d, vocab)) * 0.02).astype(dtype)
    return p


def embed_apply(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def head_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    w = p.get("head")
    if w is None:
        w = p["tok"].T
    return mm(x, w)


@jax.custom_vjp
def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token CE in f32.  logits (B, S, V) any float dtype, labels int32.

    Custom VJP: the forward materializes only per-token lse (B, S) f32 —
    never a full (B, S, V) f32 copy — and the backward recomputes
    softmax(logits) chunk-by-chunk (d = (softmax - onehot)/N in the input
    dtype).  Without this, whisper train_4k keeps 12+ GiB of f32 logits
    residuals per device.
    """
    return _ce_fwd(logits, labels)[0]


_CE_CHUNK = 512


def _ce_per_token(logits, labels):
    """Chunked per-token (lse - gold); returns (B, S) f32."""
    B, S, V = logits.shape
    c = min(_CE_CHUNK, S)
    pad = (-S) % c
    if pad:
        logits = jnp.pad(logits, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    n = logits.shape[1] // c
    lc = jnp.moveaxis(logits.reshape(B, n, c, V), 1, 0)
    yc = jnp.moveaxis(labels.reshape(B, n, c), 1, 0)

    def one(args):
        lg, y = args
        lf = lg.astype(jnp.float32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, y[..., None], axis=-1)[..., 0]
        return lse - gold  # (B, c)

    per_tok = flags.chunk_map(one, (lc, yc))  # (n, B, c)
    return jnp.moveaxis(per_tok, 0, 1).reshape(B, S + pad)[:, :S]


def _ce_fwd(logits, labels):
    per_tok = _ce_per_token(logits, labels)
    return jnp.mean(per_tok), (logits, labels)


def _ce_bwd(res, g):
    logits, labels = res
    B, S, V = logits.shape
    c = min(_CE_CHUNK, S)
    pad = (-S) % c
    lp = jnp.pad(logits, ((0, 0), (0, pad), (0, 0))) if pad else logits
    yp = jnp.pad(labels, ((0, 0), (0, pad))) if pad else labels
    n = lp.shape[1] // c
    lc = jnp.moveaxis(lp.reshape(B, n, c, V), 1, 0)
    yc = jnp.moveaxis(yp.reshape(B, n, c), 1, 0)
    scale = g / (B * S)

    def one(args):
        lg, y = args
        p = jax.nn.softmax(lg.astype(jnp.float32), axis=-1)
        d = p - jax.nn.one_hot(y, V, dtype=jnp.float32)
        return (d * scale).astype(lg.dtype)  # (B, c, V)

    d = flags.chunk_map(one, (lc, yc))  # (n, B, c, V)
    d = jnp.moveaxis(d, 0, 1).reshape(B, S + pad, V)[:, :S]
    return d, None


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


def sinusoidal_positions(S: int, d: int) -> jnp.ndarray:
    """(S, d) fixed sinusoidal table (whisper-style positions)."""
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / d))
    out = jnp.zeros((S, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
