"""Plan-driven patch executor: run a planner ``Plan`` over a whole volume.

``PlanExecutor`` compiles a plan (per-layer primitives + patch geometry)
into a ``primitives.CompiledPlan`` — one-time per-layer setup: cached
kernel spectra for ``fft_cached`` layers, per-layer pruned-FFT shapes,
pool modes — and sweeps an arbitrary-size volume with jitted walks over
the prepared layers:

* patches come from the tiler (FOV overlap, shifted edge patches, zero
  padding for undersized axes);
* ``batch`` patches are stacked per compiled step (one XLA compile per
  batch size, cached — patch shape is fixed by the plan); the prepared
  states are jit arguments, shared by every batch size, so kernel FFTs
  run once per plan, not once per patch or compile; ragged tail batches
  run through a smaller compiled batch instead of padded-and-discarded
  work;
* MPF plans emit their full ``core³`` dense block per patch in one call
  (fragments recombined on device);
* plain-pool baseline plans sweep the P³ shifted subsamplings of each
  patch — the paper's naive "compute all subsamplings" outer loop —
  interleaving the strided outputs into the same dense core;
* ``pipeline2`` plans route the patch stream through
  ``core.pipeline.pipelined_apply`` (lax.scan over patches, stage hand-off
  across the ``pod`` mesh axis; queue depth 1 per §VII-C).

Patch-geometry invariants this module relies on (see ``tiler``): every
patch spans ``extent = core + FOV - 1`` input voxels and contributes a
``core³`` dense block; adjacent patches overlap by FOV-1 input voxels;
edge patches are *shifted* (value-identical overlap), and the patch stream
is x-major with non-decreasing x.

Overlap-save input-spectra reuse: when the plan's FIRST conv layer is
``overlap_save``, the layer-0 segment grid is pinned to the patch core
(``compile_plan(overlap_seg=core)``) so the segments of x-adjacent patches
land on identical absolute input coordinates.  Within one sweep the
executor caches segment spectra keyed by ``tiler.segment_keys`` — the
FOV-overlap a neighbour shares is transformed once, not once per patch
(ZNNi's border waste removed from the transform).  The cache is scoped to
a *sweep* (``begin_sweep``/``end_sweep``): volume edges (shifted patches,
different y/z rows) and new requests simply miss and recompute; eviction
rides the tiler's non-decreasing-x guarantee.  ``last_stats`` reports
``os_seg_fft`` (input segment FFTs actually run) and ``os_seg_hits``
(segments served from the cache).

Deep activation reuse (``deep_reuse``, default on for reuse-capable
plans): the sweep cache extends BELOW layer 0.  Every patch stores, per
layer l >= 1, the trailing ``size_l - 1`` x-columns of that layer's input
(the activation halo), keyed by the x-successor patch start — per-layer
coordinate frames stay aligned across patches because the patch stride
``core`` is divisible by every cumulative pooling factor, fragment
offsets included.  An *interior* patch (core-aligned x start whose left
neighbour completed in an earlier chunk) then runs the STRIP path: layer
0 pays MAD + inverse only for the ``tail_segments`` covering its new core
columns, and each deeper layer runs on ``new_x + size - 1`` assembled
columns (cached halo + newly computed strip) instead of the full patch
extent — the FOV-1 overlap is never recomputed at any depth.  Interior
and edge patches of one chunk run as two fused jit calls; eligibility is
decided against the halo cache as of the chunk start, so batches never
race on intra-chunk dependencies.  ``last_stats`` adds
``os_mad_segments`` (per-segment MAD+inverse passes actually run),
``deep_strip_patches``/``deep_full_patches``, and ``retraces`` (distinct
jit specializations seen).  ``predict_counts`` returns the planner-side
``SweepCounts`` for a volume shape — by construction these equal the
measured counters exactly (the sweep-aware planning acceptance property).
``fuse_os`` additionally routes eligible ``fft_cached``+``mpf`` pairs of
the capture and strip walks through the halo-emitting fused epilogue
(``fft_conv_pool_fused_halo``): the pool input is never materialized as a
walk step but its trailing columns still reach the halo cache via the
fused call's second output — dense output and exported halos are bitwise
equal to the unfused walks off the Pallas path.  ``last_stats`` adds
``fused_pair_calls`` ((strip+full patches) × eligible pairs) and
``os_fused_segments`` (segments run through the fused Pallas segment
kernel; equals ``os_mad_segments`` on the Pallas path, else 0).

Host-staged streaming (``ram_budget``/``streaming``, ISSUE 5): a plan
solved under a RAM budget executes with the volume resident in HOST
memory only.  Chunks are capped at x-plane boundaries
(``tiler.chunk_patches``) so each chunk reads one constant-shape input
x-slab ``[x0, x0 + span)``; ``_slab`` double-buffers the next plane's
slab onto the device while the current fused step runs, and the per-key
eviction sweep (``_evict_left_of``) frees segment spectra, activation
halos, and slabs the stream moved past — miss spectra are stored split
by absolute segment x (``_store_spectra``) precisely so eviction
releases real buffers.  The fused-step programs are identical to the
dense mode's (only the volume operand and slab-relative miss starts
change), so streamed output is bitwise-equal to the dense path.  A
``_DeviceLedger`` accounts every executor-managed device buffer;
``last_stats["peak_device_bytes"]`` reports the per-sweep peak and
``predict_memory``/``Plan.memory`` reproduce it analytically (the
memory-model contract in docs/architecture.md).

``run`` returns the dense (out_ch, X-FOV+1, ...) output and records
``last_stats`` (patch/batch counts, wall seconds, measured vox/s including
border waste, the planner's predicted vox/s for comparison, and the
measured/predicted peak device bytes).
"""

from __future__ import annotations

import itertools
import math
import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ConvNetConfig
from ..core import overlap_save as os_mod
from ..core.fft_conv import fft_conv_pool_fused_halo
from ..core.mpf import recombine_fragments
from ..core.pipeline import hetero_stage_devices, make_stage_fns, pipelined_apply
from ..core.planner import Plan
from ..core.primitives import (
    CompiledPlan,
    PreparedLayer,
    compile_plan,
    conv_primitive,
    plan_input_size,
    pool_primitive,
    resolve_primitive,
)
from ..kernels import resolve_use_pallas
from ..tuning import TunedConfig, load_tuned_config
from .tiler import (
    HaloSpec,
    SweepCounts,
    VolumeTiling,
    chunk_patches,
    extract_patch,
    pad_volume,
    predict_sweep_counts,
    sweep_perm,
    tile_volume,
)


def _permute_conv_params(params, net: ConvNetConfig, perm: Tuple[int, int, int]):
    """Permute conv kernels into the working frame of a sweep axis.

    The sweep machinery runs in the tiler's working frame (sweep axis =
    spatial axis 0).  Valid correlation commutes with a joint permutation
    of data and kernel spatial axes: if ``x_work = transpose(x, perm)``
    then ``conv(x, w)`` permutes to ``conv(x_work, transpose(w, perm))``
    — so permuting every conv weight by the SAME spatial permutation as
    the volume makes the whole compiled stack axis-generic with no kernel
    changes (pools, bias, ReLU, and MPF recombination are isotropic).
    Identity perm returns ``params`` unchanged (same objects).
    """
    if perm == (0, 1, 2):
        return params
    axes = (0, 1, 2 + perm[0], 2 + perm[1], 2 + perm[2])
    out = []
    for p, layer in zip(params, net.layers):
        if layer.kind == "conv" and p is not None:
            w, b = p
            out.append((jnp.transpose(w, axes), b))
        else:
            out.append(p)
    return out


class _PendingMiss(NamedTuple):
    """Sweep-cache placeholder: this key's spectrum is being computed in
    the current batch as miss row ``idx`` (dedups within-batch repeats)."""

    idx: int


class _SpectrumRef(NamedTuple):
    """Sweep-cache entry: row ``idx`` of a stored miss-FFT output array.

    Rows are never copied out — the fused step receives the parent arrays
    as jit arguments and selects rows at trace time, so a cache hit costs
    no host work at all.  Parents are split by absolute segment x at
    storage time (all rows of one parent share one x), so the per-key
    eviction sweep actually frees device memory: a still-needed tail
    segment can never pin an otherwise-dead batch buffer alive.
    """

    parent: Any  # (M, f, ña, ñb, ñc) device array; one absolute x per parent
    idx: int


class _DeviceLedger:
    """Accounting of the executor-managed device working set (bytes).

    ``current`` tracks buffers the executor holds across steps (prepared
    states, staged slabs, cached segment spectra, activation halos, a
    non-streaming sweep's resident volume); ``transient`` samples a
    step's in-flight extras (patch inputs, chunk outputs, miss spectra,
    freshly captured halos) on top of ``current``.  ``peak`` is the
    number ``last_stats["peak_device_bytes"]`` reports and the planner's
    ``predict_stream_peak`` simulation reproduces: both sides count the
    same objects at the same points, which is what makes the prediction
    pinnable within 10%.  (jit-internal scratch — FFT temporaries inside
    a fused step — is modelled on the planner side as per-layer
    ``LayerCost`` stage peaks, not measured here.)
    """

    def __init__(self) -> None:
        self.current = 0.0
        self.peak = 0.0

    def alloc(self, nbytes: float) -> None:
        self.current += nbytes
        if self.current > self.peak:
            self.peak = self.current

    def free(self, nbytes: float) -> None:
        self.current = max(0.0, self.current - nbytes)

    def transient(self, nbytes: float) -> None:
        """A step's extra in-flight bytes: bumps peak, not current."""
        if self.current + nbytes > self.peak:
            self.peak = self.current + nbytes

    def begin_run(self) -> None:
        """Scope the peak to one sweep (states/caches carry over)."""
        self.peak = self.current


def _tree_nbytes(*trees) -> float:
    """Total bytes of the distinct array buffers in the given pytrees."""
    seen: Dict[int, float] = {}
    for leaf in jax.tree_util.tree_leaves(list(trees)):
        n = getattr(leaf, "nbytes", None)
        if n is not None:
            seen[id(leaf)] = float(n)
    return sum(seen.values())


class PlanExecutor:
    """Bind a Plan (or explicit prims + fragment size) to a volume sweep."""

    def __init__(
        self,
        params,
        net: ConvNetConfig,
        plan: Optional[Plan] = None,
        *,
        prims: Optional[Sequence[str]] = None,
        m: Optional[int] = None,
        batch: Optional[int] = None,
        theta: int = -1,
        use_pallas: Optional[bool] = None,
        fuse_pairs: Optional[bool] = None,
        fprime_chunk=None,
        fuse_os: Optional[bool] = None,
        tuned: Union[str, TunedConfig, None] = "auto",
        deep_reuse: bool = True,
        ram_budget: Optional[float] = None,
        streaming: Optional[bool] = None,
        sweep_axis: Optional[int] = None,
    ):
        # default sweep axis: explicit arg > the plan's costed choice > x.
        # All geometry below lives in that axis's working frame; the conv
        # weights are permuted to match (see _permute_conv_params), so the
        # compiled stack keeps its axis-0 machinery unchanged.
        if sweep_axis is None:
            sweep_axis = getattr(plan, "sweep_axis", 0) if plan is not None else 0
        self.sweep_axis = int(sweep_axis)
        self._orig_params = params
        self.params = _permute_conv_params(
            params, net, sweep_perm(self.sweep_axis)
        )
        self.net = net
        self.plan = plan
        # per-hardware tuned config (repro.tuning): ``"auto"`` loads the
        # persisted winner for (this device kind, net.name) if one exists.
        # A tuned config fills only knobs the caller left unset — and only
        # the execution knobs (use_pallas / fuse_pairs / fprime_chunk) when
        # a Plan is given: m/batch are part of the planner's costed
        # geometry contract (predicted == measured counters) and are taken
        # from the tuner only on plan-less explicit-prims construction.
        self.tuned: Optional[TunedConfig] = (
            load_tuned_config(net.name) if tuned == "auto"
            else (tuned if isinstance(tuned, TunedConfig) else None)
        )
        if self.tuned is not None:
            if use_pallas is None:
                use_pallas = self.tuned.use_pallas
            if fuse_pairs is None:
                fuse_pairs = self.tuned.fuse_pairs
            if fprime_chunk is None:
                fprime_chunk = self.tuned.fprime_chunk
            if fuse_os is None:
                fuse_os = getattr(self.tuned, "fuse_os", None)
            if plan is None and prims is not None:
                m = m if m is not None else self.tuned.m
                batch = batch if batch is not None else self.tuned.batch
        if plan is not None:
            prims = plan.prims
            m = plan.m_final
            batch = batch or plan.batch
            theta = plan.theta if plan.strategy in ("pipeline2", "hetero") else -1
            if ram_budget is None:
                ram_budget = plan.ram_budget
        # hetero plans run the split as a TWO-BACKEND pipeline (stage 0 on
        # the host CPU backend, stage 1 on the default accelerator, host
        # RAM as the hand-off medium) instead of the pod-axis scan
        self.hetero = plan is not None and plan.strategy == "hetero"
        if prims is None or m is None:
            raise ValueError("need either a Plan or explicit prims + m")
        # a plan solved under a RAM budget executes in the mode that honors
        # it: host-staged streaming (the volume never becomes device-
        # resident in full).  ``streaming`` can force either mode.
        self.ram_budget = ram_budget
        self.streaming = (
            bool(streaming) if streaming is not None else ram_budget is not None
        )
        self.prims = tuple(prims)
        self.m = m
        self.batch = max(1, batch or 1)
        self.theta = theta
        self.use_pallas = resolve_use_pallas(use_pallas)

        self.P = net.total_pooling()
        self.fov = net.field_of_view()
        self.core = m * self.P
        self.uses_mpf = "mpf" in self.prims
        # input voxels per axis a patch spans: n_in for MPF; the plain-pool
        # baseline sweeps P³ shifted n_in-windows, needing core + fov - 1.
        self.n_in = self._n_in()
        self.extent = self.n_in if self.uses_mpf else self.n_in + self.P - 1
        assert self.extent == self.core + self.fov - 1, (
            self.extent, self.core, self.fov
        )
        self.out_channels = [l for l in net.layers if l.kind == "conv"][-1].out_channels

        # one-time setup for every layer (cached kernel spectra, per-layer
        # FFT shapes, pool modes) — shared by every compiled batch size and
        # by the pipeline2 stage functions.  A first-layer overlap_save conv
        # gets its segment grid pinned to the patch core so x-adjacent
        # patches share segment spectra (cross-patch input-FFT reuse).
        self.compiled: CompiledPlan = compile_plan(
            self.params, net, prims=self.prims, n_in=self.n_in,
            use_pallas=self.use_pallas, fuse_pairs=fuse_pairs,
            fprime_chunk=fprime_chunk, plan=plan,
            overlap_seg=self.core if self.prims[0] == "overlap_save" else None,
        )
        self.fuse_pairs = self.compiled.fuse_pairs
        self._fprime_chunk = fprime_chunk

        recombine = self.uses_mpf

        def _walk(states, xs):
            return self.compiled.apply(xs, states=states, recombine=recombine)

        # one jitted walk; jax.jit specializes (retraces) per patch-batch
        # shape, while the prepared states stay shared call arguments.
        self._jit_walk = jax.jit(_walk)
        self._seen_batch_sizes: set = set()
        self._pipeline_fn = None
        self._hetero_fns = None
        self._hetero_stats: Dict[str, float] = {}
        self.last_stats: Dict[str, float] = {}

        # -- overlap-save input-spectra reuse state --------------------------
        # active when the patch walk starts with an overlap_save conv over
        # the full patch extent (MPF plans; the plain-pool subsampling sweep
        # slices shifted sub-windows, which breaks segment alignment).
        self._os_reuse = self.prims[0] == "overlap_save" and self.uses_mpf
        self._sweeps: Dict[int, Dict[Tuple[int, int, int], jnp.ndarray]] = {}
        self._sweep_vols: Dict[int, jnp.ndarray] = {}  # non-streaming scopes
        self._sweep_hosts: Dict[int, np.ndarray] = {}  # streaming scopes
        self._sweep_slabs: Dict[int, Dict[int, jnp.ndarray]] = {}
        self._key_bytes: Dict[Tuple[int, Tuple[int, int, int]], float] = {}
        self._sweep_counter = 0
        self._os_misses = 0
        self._os_hits = 0
        self._os_mad_segments = 0
        self._deep_strips = 0
        self._deep_fulls = 0
        self._fused_pair_calls = 0
        self._os_fused_segments = 0
        # layers below the input the halo-emitting fused epilogue can serve
        # as a conv+pool pair (same eligibility as apply_prepared_range's
        # fuse_pairs: fft_cached conv, not the net's last conv, immediately
        # followed by its mpf pool)
        _last_conv = max(
            i for i, l in enumerate(net.layers) if l.kind == "conv"
        )
        pairs = []
        for i in range(1, len(net.layers) - 1):
            pl_i = self.compiled.layers[i]
            nxt = self.compiled.layers[i + 1]
            if (
                pl_i.kind == "conv"
                and pl_i.prim == "fft_cached"
                and pl_i.index != _last_conv
                and nxt.kind == "pool"
                and nxt.prim == "mpf"
                and nxt.index == pl_i.index + 1
            ):
                pairs.append(i)
        self._fused_pairs: Tuple[int, ...] = tuple(pairs)
        # fused halo-emitting epilogue in the capture/strip walks: off by
        # default (conservative; tuned configs switch it on per hardware),
        # and a no-op unless the plan has fusable pairs and runs the
        # overlap-save reuse walks at all
        self.fuse_os = bool(fuse_os) and bool(pairs) and self._os_reuse
        self._trace_keys: set = set()  # distinct jit specializations seen
        # deep activation reuse: interior patches run a strip walk assembled
        # from cached per-layer activation halos (see module docstring)
        self.deep_reuse = bool(self._os_reuse and deep_reuse)
        self._halo_caches: Dict[int, Dict[Tuple[int, int, int], List]] = {}
        if self._os_reuse:
            spec0 = self.compiled.layers[0].os_spec
            self._jit_os_walk = jax.jit(self._os_walk)
            # the fused per-batch step retraces per miss/hit *pattern*; the
            # tiler produces only a handful (first row, interior row,
            # shifted edge row) per batch size
            self._jit_os_step = jax.jit(self._os_step, static_argnames=("pattern",))
            self.halo = HaloSpec(spec0.seg_core, spec0.seg_extent, spec0.starts)
        else:
            self.halo = None
        if self.deep_reuse:
            spec0 = self.compiled.layers[0].os_spec
            # trailing segments covering an interior patch's new core columns
            self._q_strip = os_mod.tail_segments(spec0, self.core)
            self._strip_layers, self._strip_info = self._build_strip_plan()
            self._strip_states = [
                pl.state if pl is not None else None for pl in self._strip_layers
            ]
            self._jit_os_strip_step = jax.jit(
                self._os_strip_step, static_argnames=("pattern",)
            )
        else:
            self._q_strip = None
        # per-axis prepared states for mixed-axis serving: every sweep
        # scope records its axis (``_sweep_axes``); scopes on the default
        # axis use the primary compiled/strip states, other axes get their
        # own state pytrees lazily (``_states_for_axis``) — metadata and
        # jitted step programs are shared, since cubic patches/kernels make
        # every working frame shape-identical.
        self._sweep_axes: Dict[int, int] = {}
        self._axis_states: Dict[int, Tuple[Any, Any]] = {
            self.sweep_axis: (
                self.compiled.states, getattr(self, "_strip_states", None)
            )
        }
        # device-working-set ledger: prepared states (weights, cached kernel
        # spectra at full AND strip shapes) are resident for the executor's
        # lifetime; sweeps add slabs/caches on top.
        self._ledger = _DeviceLedger()
        strip_states = getattr(self, "_strip_states", [])
        self._ledger.alloc(_tree_nbytes(self.params, self.compiled.states, strip_states))
        self._predict_memory_cache: Dict[Tuple[int, int, int], Any] = {}

    def tuned_provenance(self) -> Optional[Dict[str, Any]]:
        """The tuned config this executor runs under (bench-row provenance
        dict, see ``TunedConfig.provenance``) — ``None`` when untuned."""
        return None if self.tuned is None else self.tuned.provenance()

    # -- geometry ------------------------------------------------------------

    def _n_in(self) -> int:
        """Input size per apply call, from the net walked backwards.

        ``primitives.plan_input_size`` generalizes ``net.valid_input_size``
        / ``planner._n_in_for_m`` to per-layer primitive assignments (those
        assume all pools are MPF or none are); the ``extent`` assertion in
        __init__ cross-checks the walks against the shared core/FOV
        identity.
        """
        return plan_input_size(self.net, self.prims, self.m)

    def tiling_for(
        self, vol_shape: Sequence[int], *, sweep_axis: Optional[int] = None
    ) -> VolumeTiling:
        return tile_volume(
            vol_shape, core=self.core, fov=self.fov, halo=self.halo,
            sweep_axis=self.sweep_axis if sweep_axis is None else int(sweep_axis),
        )

    def bucket_shape(self, vol_shape: Sequence[int]) -> Tuple[int, int, int]:
        """Round a volume shape up to the executor's patch-grid bucket.

        Axes are padded so the dense output is a whole number of cores:
        the padded shapes of arbitrary requests collapse onto a small set
        of buckets, every patch start is core-aligned (no shifted edge
        patches, maximum cross-patch reuse), and the fused per-batch jit
        step — keyed on the device-resident volume's shape — stops
        retracing per distinct request size.  Exact by the pad-and-crop
        argument: outputs over the padding are simply never written.
        Raises for axes below the FOV — the same no-valid-output contract
        ``tile_volume`` enforces on unbucketed shapes.
        """
        for ax, x in enumerate(vol_shape):
            if x < self.fov:
                raise ValueError(
                    f"axis {ax} extent {x} < FOV {self.fov}: no valid output exists"
                )
        return tuple(
            math.ceil((x - self.fov + 1) / self.core) * self.core
            + self.fov - 1
            for x in vol_shape
        )

    def predict_counts(
        self, vol_shape: Sequence[int], *, batch: Optional[int] = None,
        sweep_axis: Optional[int] = None,
    ) -> SweepCounts:
        """Planner-side prediction of this executor's sweep counters.

        Simulates the sweep caches over the exact tiling ``run`` would
        use (same ``sweep_axis``, default the executor's); the returned
        counts equal the measured ``last_stats`` counters 1:1 (the
        sweep-aware planning acceptance property, for every axis).
        """
        if not self._os_reuse:
            raise ValueError("predict_counts needs an overlap-save reuse plan")
        tiling = self.tiling_for(vol_shape, sweep_axis=sweep_axis)
        return predict_sweep_counts(
            tiling, batch=batch or self.batch,
            deep_reuse=self.deep_reuse, strip_segments=self._q_strip,
        )

    def _states_for_axis(self, axis: int):
        """Prepared state pytrees ``(states, strip_states)`` for one axis.

        Metadata (segment specs, FFT shapes, pool modes) is axis-
        independent — patches and kernels are cubic, so every working
        frame is shape-identical and all axes share the same jitted step
        functions (and their compiled programs).  Only the numeric state
        buffers differ: weights and cached kernel spectra permuted into
        that axis's working frame.  Non-default axes are built lazily and
        ledger-accounted like the primary states.
        """
        got = self._axis_states.get(axis)
        if got is None:
            p_ax = _permute_conv_params(
                self._orig_params, self.net, sweep_perm(axis)
            )
            compiled = compile_plan(
                p_ax, self.net, prims=self.prims, n_in=self.n_in,
                use_pallas=self.use_pallas, fuse_pairs=self.fuse_pairs,
                fprime_chunk=self._fprime_chunk, plan=self.plan,
                overlap_seg=(
                    self.core if self.prims[0] == "overlap_save" else None
                ),
            )
            strip_states = None
            if self.deep_reuse:
                layers, _ = self._build_strip_plan(p_ax)
                strip_states = [
                    pl.state if pl is not None else None for pl in layers
                ]
            got = (compiled.states, strip_states)
            self._axis_states[axis] = got
            self._ledger.alloc(_tree_nbytes(got[0], strip_states or []))
        return got

    def _build_strip_plan(self, params=None):
        """One-time setup of the interior-patch strip walk (layers >= 1).

        For each layer below the input, bind its primitive to the strip
        extent an interior patch runs: ``new_x + size - 1`` sweep-axis
        columns (the newly computed columns plus the cached activation
        halo) at the full-walk cross extents.  Returns ``(layers, info)``
        where ``layers[i]`` is the strip ``PreparedLayer`` (None at 0 —
        layer 0 runs through the segment-spectra tail) and ``info[i] =
        (halo columns, fragment batch multiplier at this layer's input)``.
        ``params`` defaults to the executor's working-frame params; pass
        another axis's permuted params to build that axis's strip states.
        """
        if params is None:
            params = self.params
        n = self.n_in  # full-walk spatial extent entering each layer
        P_cur, frag = 1, 1
        layers: List[Optional[PreparedLayer]] = [None] * len(self.net.layers)
        info: List[Optional[Tuple[int, int]]] = [None] * len(self.net.layers)
        for i, layer in enumerate(self.net.layers):
            if i > 0:
                new_x = self.core // P_cur
                h = layer.size - 1
                w_in = new_x + h
                assert w_in <= n, (i, w_in, n)
                if layer.kind == "conv":
                    w, b = params[i]
                    layers[i] = conv_primitive(self.prims[i]).setup(
                        w, b, (w_in, n, n), index=i
                    )
                else:
                    layers[i] = pool_primitive(self.prims[i]).setup(
                        layer.size, (w_in, n, n), index=i
                    )
                info[i] = (h, frag)
            if layer.kind == "conv":
                n = n - layer.size + 1
            else:
                n = n // layer.size
                P_cur *= layer.size
                frag *= layer.size**3
        return tuple(layers), tuple(info)

    def _record_trace(self, key: Tuple) -> None:
        """Track distinct jit specializations (last_stats["retraces"])."""
        self._trace_keys.add(key)

    # -- overlap-save sweep cache -------------------------------------------

    def begin_sweep(
        self, padded: np.ndarray, *, sweep_axis: Optional[int] = None
    ) -> int:
        """Open a fresh spectra-reuse scope (one volume sweep / request).

        Scoping the cache to a sweep is what makes reuse safe: segment keys
        are absolute coordinates *within one padded volume swept on one
        axis*, so spectra must never leak across requests — and distinct
        sweep axes are simply distinct scopes, which is what lets one
        serving tick batch mixed-axis requests with no key collisions.
        ``padded`` must already be in ``sweep_axis``'s working frame
        (``tiler.pad_volume`` of a matching tiling); the default is the
        executor's axis.  The volume is extended along working axis 0 so
        the aligned grid's tail segments stay in bounds (the extra voxels
        are zeros; exact, because the outputs they influence are cropped),
        then either uploaded to the device once (dense mode) or kept in
        HOST RAM (streaming mode) — the streaming sweep stages one slab
        per plane on demand (``_slab``), so peak device bytes scale with
        the slab, not the volume.
        """
        spec0 = self.compiled.layers[0].os_spec
        max_x0 = max(0, padded.shape[1] - self.extent)
        short = max(0, max_x0 + spec0.span - padded.shape[1])
        self._sweep_counter += 1
        token = self._sweep_counter
        self._sweeps[token] = {}
        self._sweep_axes[token] = (
            self.sweep_axis if sweep_axis is None else int(sweep_axis)
        )
        if self.streaming:
            host = np.asarray(padded, np.float32)
            if short:
                host = np.pad(host, ((0, 0), (0, short), (0, 0), (0, 0)))
            self._sweep_hosts[token] = host
            self._sweep_slabs[token] = {}
        else:
            vol = jnp.asarray(padded)
            if short:
                vol = jnp.pad(vol, ((0, 0), (0, short), (0, 0), (0, 0)))
            self._sweep_vols[token] = vol
            self._ledger.alloc(vol.nbytes)
        return self._sweep_counter

    def end_sweep(self, token: Optional[int]) -> None:
        self._sweep_axes.pop(token, None)
        vol = self._sweep_vols.pop(token, None)
        if vol is not None:
            self._ledger.free(vol.nbytes)
        self._sweep_hosts.pop(token, None)
        for slab in self._sweep_slabs.pop(token, {}).values():
            self._ledger.free(slab.nbytes)
        for key in self._sweeps.pop(token, {}):
            self._ledger.free(self._key_bytes.pop((token, key), 0.0))
        for entry in self._halo_caches.pop(token, {}).values():
            self._ledger.free(sum(h.nbytes for h in entry))

    # -- host-staged streaming slabs ----------------------------------------

    def _slab(self, token: int, x0: int) -> jnp.ndarray:
        """Device-stage the input x-slab ``[x0, x0 + span)`` of a sweep.

        Every chunk of a plane reads the same constant-shape slab (the
        plane cap in ``tiler.chunk_patches`` guarantees it), so the fused
        step's volume operand never retraces on shape.  Already-staged
        slabs are returned as-is — the double-buffer prefetch in
        ``_run_batched`` stages the next plane's slab while the current
        chunk runs.
        """
        slabs = self._sweep_slabs.setdefault(token, {})
        slab = slabs.get(x0)
        if slab is None:
            host = self._sweep_hosts[token]
            spec0 = self.compiled.layers[0].os_spec
            slab = jnp.asarray(host[:, x0 : x0 + spec0.span])
            slabs[x0] = slab
            self._ledger.alloc(slab.nbytes)
        return slab

    def _drop_slabs(self, token: int, keep) -> None:
        slabs = self._sweep_slabs.get(token, {})
        for x0 in [x for x in slabs if x not in keep]:
            self._ledger.free(slabs.pop(x0).nbytes)

    def _evict_left_of(self, token: int, x_lo: int) -> None:
        """Free every cache entry strictly left of ``x_lo`` (both caches).

        Exact by the tiler's non-decreasing-x patch stream: no later patch
        of this sweep can resolve an evicted key.  Because stored spectra
        parents are split by absolute x, eviction really releases the
        device buffers (and the ledger records it).
        """
        cache = self._sweeps.get(token, {})
        for dead in [k for k in cache if k[0] < x_lo]:
            del cache[dead]
            self._ledger.free(self._key_bytes.pop((token, dead), 0.0))
        halo_cache = self._halo_caches.get(token)
        if halo_cache:
            for dead in [k for k in halo_cache if k[0] < x_lo]:
                self._ledger.free(sum(h.nbytes for h in halo_cache.pop(dead)))
        if self.streaming:
            self._drop_slabs(
                token,
                {x for x in self._sweep_slabs.get(token, {}) if x >= x_lo},
            )

    # -- shard boundary handoff (sharded serving fleet) ----------------------

    def export_handoff(self, token: int, x_lo: int):
        """Stage this sweep scope's boundary caches out to host.

        Returns a ``distributed.collectives.HaloPackage`` holding every
        segment-spectra row and activation-halo entry whose absolute-x key
        is >= ``x_lo`` — exactly the entries a single-device sweep would
        still hold when its next chunk starts at plane ``x_lo`` (everything
        left of it is ``_evict_left_of`` food).  Rows are materialized to
        host ndarrays (output-to-host staging), so the package can cross
        workers; re-import round-trips bit-exactly (no arithmetic touches
        the values, only copies).
        """
        from repro.distributed.collectives import HaloPackage

        spectra = {}
        for key, ref in self._sweeps.get(token, {}).items():
            if key[0] >= x_lo and isinstance(ref, _SpectrumRef):
                spectra[key] = np.asarray(ref.parent[ref.idx])
        halos = {}
        for key, entry in self._halo_caches.get(token, {}).items():
            if key[0] >= x_lo:
                halos[key] = tuple(np.asarray(h) for h in entry)
        return HaloPackage(x_lo=x_lo, spectra=spectra, halos=halos)

    def import_handoff(self, token: int, pkg) -> None:
        """File a predecessor shard's boundary package into this scope.

        Spectra rows are grouped by absolute segment x and uploaded as one
        parent per x (the same split ``_store_spectra`` maintains, so the
        per-key eviction sweep keeps really freeing device memory); halo
        entries upload per key.  The ledger accounts both, mirroring what
        a single-device sweep would have resident at this boundary.
        """
        if pkg is None or pkg.is_empty():
            return
        cache = self._sweeps.setdefault(token, {})
        by_x: Dict[int, List] = {}
        for key in sorted(pkg.spectra):
            by_x.setdefault(key[0], []).append(key)
        for _x, keys in sorted(by_x.items()):
            parent = jnp.asarray(np.stack([pkg.spectra[k] for k in keys]))
            share = parent.nbytes / len(keys)
            self._ledger.alloc(parent.nbytes)
            for i, key in enumerate(keys):
                cache[key] = _SpectrumRef(parent, i)
                self._key_bytes[(token, key)] = share
        halo_cache = self._halo_caches.setdefault(token, {})
        for key in sorted(pkg.halos):
            entry = [jnp.asarray(h) for h in pkg.halos[key]]
            halo_cache[key] = entry
            self._ledger.alloc(sum(h.nbytes for h in entry))

    def handoff_entry_nbytes(self) -> Tuple[int, int]:
        """Per-entry byte sizes of boundary-package contents.

        Returns ``(seg_row_bytes, halo_entry_bytes)``: one layer-0 segment
        spectrum row is the complex64 rfftn of an (f_in, *fft_shape) block;
        one activation-halo entry stacks, per layer below the input, the
        (frag, C_in, size-1, n, n) float32 capture of the strip walk.
        Every key's entry has the same size (patch extent is constant), so
        ``predict_shard_handoff`` counts x per-entry sizes give the exact
        exchanged bytes.
        """
        if not self._os_reuse:
            raise ValueError("handoff accounting needs an overlap-save plan")
        spec0 = self.compiled.layers[0].os_spec
        fa, fb, fc = spec0.fft_shape
        seg_row = self.net.in_channels * fa * fb * (fc // 2 + 1) * 8
        halo_entry = 0
        if self.deep_reuse:
            c = self.net.in_channels
            n = self.n_in
            for i, layer in enumerate(self.net.layers):
                if i > 0:
                    h, frag = self._strip_info[i]
                    halo_entry += frag * c * h * n * n * 4
                if layer.kind == "conv":
                    c = layer.out_channels
                    n = n - layer.size + 1
                else:
                    n = n // layer.size
        return int(seg_row), int(halo_entry)

    def _walk_below_input(self, states, x, S, *, capture: bool):
        """Layers 1.. over a layer-0 output, optionally capturing halos.

        Applies each prepared layer in turn (ReLU after every conv but the
        net's last — the whole-net rule ``apply_prepared_range`` states)
        and, when ``capture`` (deep reuse on), records per layer the
        trailing ``size - 1`` x-columns of its INPUT: the activation halos
        the next x-patch's strip walk assembles from.  ``capture`` is a
        trace-time constant — jitted callers that discard halos (deep
        reuse off, the mixed-sweep fallback) must not materialize them as
        jit outputs.  Returns ``(out, halos)``.

        With ``fuse_os`` every eligible conv+pool pair dispatches to
        ``fft_conv_pool_fused_halo``: the pool layer's input is never a
        separate walk step, yet its trailing columns still reach the halo
        cache via the fused call's second output (bitwise-identical to the
        unfused capture off the Pallas path).
        """
        last_conv = max(
            i for i, l in enumerate(self.net.layers) if l.kind == "conv"
        )
        halos = []
        i = 1
        while i < len(self.net.layers):
            pl = self.compiled.layers[i]
            if capture:
                h = self.net.layers[i].size - 1
                halos.append(x[:, :, -h:])
            if self.fuse_os and i in self._fused_pairs:
                nxt = self.compiled.layers[i + 1]
                x, pool_halo = fft_conv_pool_fused_halo(
                    x, states[i]["W"], states[i]["b"],
                    fft_shape=pl.fft_shape, k=pl.kernel_size,
                    p=nxt.pool_size, halo_cols=nxt.pool_size - 1,
                    use_pallas=self.use_pallas,
                    fprime_chunk=pl.fprime_chunk,
                )
                if capture:
                    halos.append(pool_halo)
                i += 2
                continue
            x = resolve_primitive(pl).apply(
                pl, x, states[i], use_pallas=self.use_pallas
            )
            if pl.kind == "conv" and i != last_conv:
                x = jax.nn.relu(x)
            i += 1
        if self.uses_mpf:
            x = recombine_fragments(x, list(self.compiled.mpf_pools), S)
        return x, tuple(halos)

    def _os_walk(self, states, F, *, capture: bool = False):
        """Jitted forward from precomputed layer-0 segment spectra.

        F (S, n_seg, f, ña, ñb, ñc) — the stacked per-patch spectra the
        sweep cache assembled; layers 1.. walk the shared prepared states
        exactly like the plain batched path.  Returns ``(out, halos)``
        (empty halos unless ``capture``; see ``_walk_below_input``).
        """
        pl0 = self.compiled.layers[0]
        x = os_mod.os_apply_from_spectra(
            F, states[0]["W"], states[0]["b"], pl0.os_spec,
            use_pallas=self.use_pallas,
        )
        last_conv = max(
            i for i, l in enumerate(self.net.layers) if l.kind == "conv"
        )
        if last_conv != 0:
            x = jax.nn.relu(x)
        return self._walk_below_input(states, x, F.shape[0], capture=capture)

    def _assemble_spectra(self, Fm, parents, pattern, rows_per_patch):
        rows = [Fm[j] if p < 0 else parents[p][j] for p, j in pattern]
        S = len(pattern) // rows_per_patch
        return jnp.stack(rows).reshape((S, rows_per_patch) + rows[0].shape)

    def _os_step(self, states, vol, starts, parents, *, pattern):
        """ONE jitted call per full-path patch batch: miss FFTs + assembly
        + walk (+ halo capture).

        ``pattern`` is the batch's static miss/hit layout — slot i of the
        (S·n_seg)-row spectra stack is ``(-1, j)`` (row j of the miss FFTs
        computed here from ``starts``) or ``(p, j)`` (row j of
        ``parents[p]``, a previous batch's miss-FFT output held by the
        sweep cache).  Fusing the miss transforms into the walk's jit lets
        XLA schedule them with the MAD instead of paying a host round-trip
        per batch, and selecting cached rows at trace time means reuse
        costs no host copies; the miss spectra are returned so the sweep
        cache can serve them to the next x-row, the halos so the deep
        activation cache can serve the next x-patch's strip walk.
        """
        spec0 = self.compiled.layers[0].os_spec
        Fm = None
        if starts is not None:
            Fm = os_mod.slice_segment_spectra(vol, starts, spec0, self.extent)
        F_all = self._assemble_spectra(Fm, parents, pattern, spec0.n_segments)
        out, halos = self._os_walk(states, F_all, capture=self.deep_reuse)
        return out, Fm, halos

    def _os_strip_step(
        self, states, strip_states, vol, starts, parents, halos, *, pattern
    ):
        """ONE jitted call per interior-patch batch: the deep-reuse strip.

        Layer 0 pays MAD + inverse only for the ``tail_segments`` covering
        the batch's new core columns (``pattern`` holds q slots per patch,
        mixing cached and miss spectra exactly like the full step); every
        deeper layer runs on ``new_x + size - 1`` assembled columns —
        ``halos[i-1]`` (the left neighbour's cached activation halo)
        concatenated with the newly computed strip from below.  The FOV-1
        overlap is recomputed at no layer.  Returns the patch cores, the
        miss spectra, and the batch's own trailing halos for the cache.
        """
        spec0 = self.compiled.layers[0].os_spec
        Fm = None
        if starts is not None:
            Fm = os_mod.slice_segment_spectra(vol, starts, spec0, self.extent)
        F = self._assemble_spectra(Fm, parents, pattern, self._q_strip)
        S = F.shape[0]
        x = os_mod.os_apply_tail_from_spectra(
            F, states[0]["W"], states[0]["b"], spec0, self.core,
            use_pallas=self.use_pallas,
        )
        last_conv = max(
            i for i, l in enumerate(self.net.layers) if l.kind == "conv"
        )
        if last_conv != 0:
            x = jax.nn.relu(x)
        new_halos = []
        i = 1
        while i < len(self.net.layers):
            pl = self._strip_layers[i]
            h, _ = self._strip_info[i]
            x = jnp.concatenate([halos[i - 1], x], axis=2)
            new_halos.append(x[:, :, -h:])
            if self.fuse_os and i in self._fused_pairs:
                # fused pair: the pool layer's input is the cached lead
                # halo ``halos[i]`` + the conv's ReLU output — assembled
                # INSIDE the fused call, which returns its trailing
                # ``strip_info[i+1]`` columns as the pool-input halo
                nxt = self._strip_layers[i + 1]
                h_pool, _ = self._strip_info[i + 1]
                x, pool_halo = fft_conv_pool_fused_halo(
                    x, strip_states[i]["W"], strip_states[i]["b"],
                    fft_shape=pl.fft_shape, k=pl.kernel_size,
                    p=nxt.pool_size, halo_cols=h_pool, lead=halos[i],
                    use_pallas=self.use_pallas,
                    fprime_chunk=pl.fprime_chunk,
                )
                new_halos.append(pool_halo)
                i += 2
                continue
            x = resolve_primitive(pl).apply(
                pl, x, strip_states[i], use_pallas=self.use_pallas
            )
            if pl.kind == "conv" and i != last_conv:
                x = jax.nn.relu(x)
            i += 1
        if self.uses_mpf:
            x = recombine_fragments(x, list(self.compiled.mpf_pools), S)
        return x, Fm, tuple(new_halos)

    def _run_os_batch(self, meta) -> np.ndarray:
        """Patch batch with layer-0 segment spectra served from the cache.

        ``meta[i] = (sweep_token, segment_keys, patch_start)`` for patch
        i; keys come from ``tiler.segment_keys`` and pair 1:1 (same order)
        with the prepared layer-0 ``os_spec.starts``.  The segment grid is
        volume-global (segments read the padded volume directly, past the
        patch's own extent if needed), so an interior patch transforms only
        the ``core/seg_core`` segments the sweep newly entered — everything
        else is a hit.  Single-sweep batches (the volume sweep, and serving
        ticks that drained one request) run fused: the chunk partitions
        into the full-extent group and (under deep reuse) the
        interior-strip group — eligibility decided against the halo cache
        as of the chunk start, so a patch whose left neighbour is in the
        SAME chunk safely falls back to the full path — and each group is
        one jit call.  Mixed-sweep batches (cross-request serving ticks)
        fall back to one ``segment_spectra_at`` call per sweep plus the
        spectra-stack walk, with no deep reuse.
        """
        tokens = {mm[0] for mm in meta}
        if len(tokens) > 1:
            return self._run_os_batch_mixed(meta)
        token = next(iter(tokens))
        self._sweeps.setdefault(token, {})
        halo_cache = self._halo_caches.setdefault(token, {})
        # the patch stream is x-major with non-decreasing x (tiler
        # invariant): cache entries strictly left of this chunk's earliest
        # patch start can never be requested again.  (Keyed by patch START
        # — not first resolved key — so a strip patch, which resolves only
        # its trailing keys, never evicts a key a same-plane full patch
        # still needs.)  Streaming sweeps also release staged slabs the
        # chunk has moved past.
        x_lo = min(mm[2][0] for mm in meta)
        self._evict_left_of(token, x_lo)
        # partition BEFORE running anything: strip eligibility is decided
        # against the halo cache as of the chunk start
        full_rows: List[int] = []
        strip_rows: List[int] = []
        for idx, (_, keys, start) in enumerate(meta):
            eligible = (
                self.deep_reuse
                and start[0] > 0
                and start[0] % self.core == 0
                and start in halo_cache
            )
            (strip_rows if eligible else full_rows).append(idx)
        groups: List[Tuple[List[int], bool]] = []
        for rows, strip in ((full_rows, False), (strip_rows, True)):
            if not rows:
                continue
            if self.streaming:
                # one staged slab serves one x-plane: sub-partition the
                # group so every jit call reads a single slab (serving
                # ticks can pop patches spanning planes; offline chunks
                # are already plane-capped)
                by_plane: Dict[int, List[int]] = {}
                for i in rows:
                    by_plane.setdefault(meta[i][2][0], []).append(i)
                groups.extend((by_plane[x], strip) for x in sorted(by_plane))
            else:
                groups.append((rows, strip))
        outs: List[Optional[np.ndarray]] = [None] * len(meta)
        for rows, strip in groups:
            ys, halos = self._run_os_group(
                token, [meta[i] for i in rows], strip
            )
            for j, idx in enumerate(rows):
                outs[idx] = ys[j]
            if self.deep_reuse:
                self._store_halos(halo_cache, [meta[i] for i in rows], halos)
        return np.stack(outs)

    def _run_os_group(self, token, metas, strip: bool):
        """Resolve + run one homogeneous (full or strip) patch group.

        Resolution inserts ``_PendingMiss`` markers, so repeated keys
        within the group dedup; groups run sequentially (full before
        strip), so the strip group sees the full group's fresh
        ``_SpectrumRef``s.  Returns ``(outputs, halos)``.
        """
        spec0 = self.compiled.layers[0].os_spec
        cache = self._sweeps[token]
        # the sweep scope's axis picks the state pytrees (working-frame
        # weights + kernel spectra); the jitted step programs are shared
        states, strip_states = self._states_for_axis(
            self._sweep_axes.get(token, self.sweep_axis)
        )
        n_seg = spec0.n_segments
        q = self._q_strip if strip else n_seg
        misses: List[Tuple[int, int, int]] = []
        pattern: List[Tuple[int, int]] = []
        parents: List = []
        parent_pos: Dict[int, int] = {}
        for _, keys, _start in metas:
            for key in keys[n_seg - q :] if strip else keys:
                F = cache.get(key)
                if F is None:
                    # the pending marker in the cache also dedups repeated
                    # keys within this group (bucketed tail repeats)
                    F = _PendingMiss(len(misses))
                    cache[key] = F
                    misses.append(key)
                    self._os_misses += 1
                else:
                    self._os_hits += 1
                if isinstance(F, _PendingMiss):
                    pattern.append((-1, F.idx))
                else:
                    pos = parent_pos.get(id(F.parent))
                    if pos is None:
                        pos = parent_pos[id(F.parent)] = len(parents)
                        parents.append(F.parent)
                    pattern.append((pos, F.idx))
        self._os_mad_segments += len(pattern)
        if self.use_pallas:
            # on the Pallas path every MAD+inverse segment runs through the
            # fused os_segment kernel (one pallas_call: MAD, DC-bin bias,
            # inverse, crop) — same count, so predictions stay exact
            self._os_fused_segments += len(pattern)
        if self.fuse_os:
            self._fused_pair_calls += len(metas) * len(self._fused_pairs)
        if self.streaming:
            # the group is one x-plane (plane-capped chunks / per-plane
            # sub-groups): its segments all live in the staged slab
            # [x0, x0 + span), so miss starts shift into slab coordinates
            # and the fused step's volume operand keeps one constant shape
            x0 = metas[0][2][0]
            vol = self._slab(token, x0)
            off = np.asarray([x0, 0, 0], np.int32)
        else:
            vol = self._sweep_vols[token]
            off = np.zeros(3, np.int32)
        starts = (
            jnp.asarray(np.asarray(misses, np.int32) - off) if misses else None
        )
        if strip:
            halos_in = tuple(
                jnp.concatenate(
                    [self._halo_caches[token][m[2]][pos] for m in metas], axis=0
                )
                for pos in range(len(self.net.layers) - 1)
            )
            self._record_trace(
                ("strip", tuple(pattern), None if starts is None else len(misses),
                 vol.shape, len(parents))
            )
            out, F_m, halos = self._jit_os_strip_step(
                states, strip_states, vol,
                starts, tuple(parents), halos_in, pattern=tuple(pattern),
            )
            self._deep_strips += len(metas)
        else:
            self._record_trace(
                ("full", tuple(pattern), None if starts is None else len(misses),
                 vol.shape, len(parents))
            )
            out, F_m, halos = self._jit_os_step(
                states, vol,
                starts, tuple(parents), pattern=tuple(pattern),
            )
            self._deep_fulls += len(metas)
        # the ledger's transient sample: group output + miss spectra +
        # captured halos in flight on top of the resident working set
        self._ledger.transient(
            out.nbytes
            + (F_m.nbytes if F_m is not None else 0)
            + sum(h.nbytes for h in halos)
        )
        self._store_spectra(token, cache, misses, F_m)
        return np.asarray(out), halos

    def _store_spectra(self, token, cache, misses, F_m) -> None:
        """File a group's miss spectra, split by absolute segment x.

        All rows of one stored parent share one x, so the per-key
        eviction sweep frees whole buffers exactly when their plane falls
        behind the patch stream — the property both the ledger and the
        planner's byte simulation rely on.  (The split costs one gather
        per distinct x; interior planes miss at a single x, so it is
        usually free.)
        """
        if not misses:
            return
        by_x: Dict[int, List[int]] = {}
        for i, key in enumerate(misses):
            by_x.setdefault(key[0], []).append(i)
        for _x, idxs in by_x.items():
            if len(idxs) == len(misses):
                parent = F_m
            else:
                parent = jnp.take(F_m, jnp.asarray(np.asarray(idxs, np.int32)), axis=0)
            self._ledger.alloc(parent.nbytes)
            share = parent.nbytes / len(idxs)
            for j, i in enumerate(idxs):
                cache[misses[i]] = _SpectrumRef(parent, j)
                self._key_bytes[(token, misses[i])] = share

    def _store_halos(self, halo_cache, metas, halos) -> None:
        """File a group's trailing activation halos for the x-successors.

        ``halos[pos]`` stacks the whole group (fragment-expanded batch);
        patch j owns rows [j·frag, (j+1)·frag) at each layer.  Only
        core-aligned patches store — a shifted edge patch's coverage can
        never serve an aligned successor's coordinate frame.
        """
        for j, (_, _, start) in enumerate(metas):
            if start[0] % self.core:
                continue
            entry = []
            for pos in range(len(self.net.layers) - 1):
                _, frag = self._strip_info[pos + 1]
                entry.append(halos[pos][j * frag : (j + 1) * frag])
            key = (start[0] + self.core, start[1], start[2])
            old = halo_cache.get(key)
            if old is not None:
                self._ledger.free(sum(h.nbytes for h in old))
            halo_cache[key] = entry
            self._ledger.alloc(sum(h.nbytes for h in entry))

    def _run_os_batch_mixed(self, meta) -> np.ndarray:
        """Cross-request serving batches: one batched FFT per sweep, then
        the spectra-stack walk (full path; deep reuse resumes on the next
        single-sweep tick — mixed ticks don't store halos)."""
        spec0 = self.compiled.layers[0].os_spec
        slots: List[List] = []  # per patch: (key, _SpectrumRef | _PendingMiss)
        miss_keys: Dict[int, List[Tuple[int, int, int]]] = {}
        for token, keys, start in meta:
            cache = self._sweeps.setdefault(token, {})
            self._evict_left_of(token, start[0])
            per_seg = []
            for key in keys:
                F = cache.get(key)
                if F is None:
                    misses = miss_keys.setdefault(token, [])
                    F = _PendingMiss(len(misses))
                    cache[key] = F
                    misses.append(key)
                    self._os_misses += 1
                else:
                    self._os_hits += 1
                per_seg.append((key, F))
            slots.append(per_seg)
            self._os_mad_segments += spec0.n_segments
            if self.use_pallas:
                self._os_fused_segments += spec0.n_segments
            if self.fuse_os:
                self._fused_pair_calls += len(self._fused_pairs)
            self._deep_fulls += 1
        for token, keys_m in miss_keys.items():
            # pad the miss count to a power of two so the distinct compiled
            # FFT batch sizes stay O(log(S·n_seg))
            M = len(keys_m)
            Mp = 1
            while Mp < M:
                Mp *= 2
            starts = np.asarray(keys_m + [keys_m[-1]] * (Mp - M), np.int32)
            if self.streaming:
                # stage a transient slab covering this token's misses; the
                # shape varies per tick (fallback path — the single-sweep
                # fused path is the one with the constant-shape guarantee)
                host = self._sweep_hosts[token]
                x_min = min(k[0] for k in keys_m)
                x_hi = max(k[0] for k in keys_m) + spec0.seg_extent
                slab = jnp.asarray(host[:, x_min:x_hi])
                self._ledger.transient(slab.nbytes)
                starts = starts - np.asarray([x_min, 0, 0], np.int32)
                vol = slab
            else:
                vol = self._sweep_vols[token]
            F_all_miss = os_mod.segment_spectra_at(
                vol, jnp.asarray(starts), spec0, self.extent
            )
            self._ledger.transient(F_all_miss.nbytes)
            # store split by absolute segment x (same invariant as the
            # single-sweep path): per-key eviction then frees real device
            # buffers instead of leaving a multi-plane parent pinned by
            # its youngest rows — the ledger stays honest in exactly the
            # cross-request mode the shared device budget governs.  The
            # power-of-two padding rows are dropped before storage.
            self._store_spectra(
                token, self._sweeps[token], keys_m, F_all_miss[:M]
            )
        # pass 2: materialize rows and walk.  Requests sweeping different
        # axes need different state pytrees (working-frame weights), so the
        # tick sub-batches per axis — one stacked walk per axis group,
        # outputs reassembled in meta order.  Single-axis ticks (the common
        # case) keep the one-stack walk.
        by_axis: Dict[int, List[int]] = {}
        for i, (token, _, _) in enumerate(meta):
            axis = self._sweep_axes.get(token, self.sweep_axis)
            by_axis.setdefault(axis, []).append(i)
        outs: List[Optional[np.ndarray]] = [None] * len(slots)
        for axis in sorted(by_axis):
            rows = by_axis[axis]
            flat = []
            for i in rows:
                cache = self._sweeps[meta[i][0]]
                for key, F in slots[i]:
                    if isinstance(F, _PendingMiss):
                        F = cache[key]  # _store_spectra filed the real ref
                    flat.append(F.parent[F.idx])
            F_all = jnp.stack(flat).reshape(
                (len(rows), spec0.n_segments) + flat[0].shape
            )  # (S_axis, n_seg, f, ña, ñb, ñc)
            self._record_trace(("oswalk", F_all.shape))
            states, _ = self._states_for_axis(axis)
            out, _ = self._jit_os_walk(states, F_all)
            self._ledger.transient(F_all.nbytes + out.nbytes)
            out = np.asarray(out)
            for j, i in enumerate(rows):
                outs[i] = out[j]
        return np.stack(outs)

    # -- compiled patch-batch kernels ---------------------------------------

    def padded_batch_size(self, n: int) -> int:
        """Batch size to run for ``n`` ready patches without compile churn.

        ``n`` itself when it is full or already compiled; otherwise the next
        power of two (capped at ``batch``), bounding the distinct compiled
        sizes a continuous-serving caller can trigger to O(log batch) while
        still avoiding most padded-and-discarded work.
        """
        if n >= self.batch or n in self._seen_batch_sizes:
            return min(n, self.batch)
        s = 1
        while s < n:
            s *= 2
        return min(s, self.batch)

    def run_patch_batch(
        self, xs: Optional[np.ndarray], *, meta=None
    ) -> np.ndarray:
        """(S, f, extent³) patches -> (S, out_ch, core³) dense cores.

        The per-layer states (weights, cached kernel spectra) are jit
        *arguments*, so every batch-size specialization shares the same
        prepared buffers — kernel FFTs ran once, in ``compile_plan``.

        ``meta`` (overlap-save reuse only): per-patch ``(sweep_token,
        segment_keys, patch_start)`` naming each patch's layer-0 segments
        by absolute volume coordinates, so input spectra shared with an
        x-adjacent patch are served from the sweep cache instead of
        recomputed (and, under deep reuse, interior patches assemble
        deeper-layer inputs from cached activation halos); ``xs`` may then
        be None (the walk starts from spectra of the sweep's
        device-resident volume, never from the raw patch).  Callers without
        sweep context (tests, raw batches) omit ``meta`` and get the
        self-contained walk.
        """
        if self._os_reuse and meta is not None:
            self._seen_batch_sizes.add(len(meta))
            return self._run_os_batch(meta)
        S = xs.shape[0]
        self._seen_batch_sizes.add(S)
        states = self.compiled.states
        if self.uses_mpf:
            self._record_trace(("walk", xs.shape))
            y = self._jit_walk(states, jnp.asarray(xs))
            self._ledger.transient(xs.nbytes + y.nbytes)
            return np.asarray(y)
        # baseline: all-subsamplings outer loop (P³ shifted passes)
        out = np.empty(
            (S, self.out_channels) + (self.core,) * 3, np.float32
        )
        n = self.n_in
        for ox, oy, oz in itertools.product(range(self.P), repeat=3):
            sub = xs[:, :, ox : ox + n, oy : oy + n, oz : oz + n]
            yd = self._jit_walk(states, jnp.asarray(sub))
            self._ledger.transient(sub.nbytes + yd.nbytes)
            y = np.asarray(yd)
            out[:, :, ox :: self.P, oy :: self.P, oz :: self.P] = y
        return out

    # -- volume sweep --------------------------------------------------------

    def run(
        self, vol: np.ndarray, *, sweep_axis: Optional[int] = None
    ) -> np.ndarray:
        """Sweep (f, X, Y, Z) -> dense (out_ch, X-FOV+1, Y-FOV+1, Z-FOV+1).

        Output is always in the VOLUME frame, whatever the sweep axis.
        ``sweep_axis`` overrides the executor's default for this run
        (overlap-save reuse plans only — the split-strategy and non-reuse
        paths run on the default axis's compiled states).
        """
        vol = np.asarray(vol, np.float32)
        axis = self.sweep_axis if sweep_axis is None else int(sweep_axis)
        if axis != self.sweep_axis and not (self._os_reuse and self.theta < 0):
            raise ValueError(
                "per-run sweep_axis override needs an overlap-save reuse plan"
            )
        tiling = self.tiling_for(vol.shape[1:], sweep_axis=axis)
        padded = pad_volume(vol, tiling)  # working frame (sweep axis first)
        out = np.empty(
            (self.out_channels,) + tiling.to_volume_frame(tiling.out_shape),
            np.float32,
        )

        self._os_misses = self._os_hits = self._os_mad_segments = 0
        self._deep_strips = self._deep_fulls = 0
        self._fused_pair_calls = self._os_fused_segments = 0
        self._ledger.begin_run()  # peak scoped to this sweep
        t0 = time.perf_counter()
        # the sweep's device upload is real per-volume work the other
        # execution modes pay per batch (patch extraction + transfer), so
        # it belongs inside the timed region for fair measured vox/s
        sweep = (
            self.begin_sweep(padded, sweep_axis=axis)
            if self._os_reuse and self.theta < 0 else None
        )
        try:
            if self.theta >= 0:
                run_split = self._run_hetero if self.hetero else self._run_pipeline
                n_batches, padded_patches = run_split(padded, tiling, out)
            else:
                n_batches, padded_patches = self._run_batched(
                    padded, tiling, out, sweep
                )
        finally:
            self.end_sweep(sweep)
        dt = time.perf_counter() - t0

        vox = float(np.prod(out.shape[1:]))
        self.last_stats = {
            "patches": tiling.n_patches,
            "batches": n_batches,
            # compute-then-discarded padding slots (pipeline stream padding;
            # the batched path routes ragged tails through a smaller compiled
            # batch instead of padding, so it reports 0)
            "padded_patches": padded_patches,
            "seconds": dt,
            "out_voxels": vox,
            "measured_voxps": vox / dt if dt > 0 else float("inf"),
            "predicted_voxps": self.plan.throughput if self.plan else float("nan"),
            "waste_fraction": tiling.waste_fraction,
            # overlap-save input-spectra reuse (0/0 when not active):
            # segment FFTs actually run vs. segments served from the cache
            "os_seg_fft": self._os_misses,
            "os_seg_hits": self._os_hits,
            # sweep-aware accounting (matches predict_counts exactly):
            # per-segment MAD+inverse passes run, and how many patches
            # took the deep-reuse strip path vs. the full-extent path
            "os_mad_segments": self._os_mad_segments,
            "deep_strip_patches": self._deep_strips,
            "deep_full_patches": self._deep_fulls,
            # fused-epilogue accounting: conv+pool pairs the halo-emitting
            # fused epilogue served (``fuse_os``; (strips+fulls) × eligible
            # pairs), and segments run through the fused Pallas segment
            # kernel (== os_mad_segments on the Pallas path, else 0)
            "fused_pair_calls": self._fused_pair_calls,
            "os_fused_segments": self._os_fused_segments,
            # distinct jit specializations dispatched so far (cumulative
            # over the executor's lifetime — serving watches this to see
            # shape-bucketing suppress per-request retraces)
            "retraces": len(self._trace_keys),
            # peak executor-managed device bytes this sweep (states + slabs
            # + caches + in-flight chunk tensors; the _DeviceLedger's
            # accounting, reproduced by predict_memory / Plan.memory)
            "peak_device_bytes": self._ledger.peak,
            "predicted_peak_device_bytes": (
                self.predict_memory(vol.shape[1:], sweep_axis=axis).device_bytes
                if self._os_reuse and self.theta < 0
                else float("nan")
            ),
        }
        if self.hetero:
            # per-stage / hand-off counters of the two-backend pipeline,
            # next to their plan predictions (bytes match EXACTLY: the
            # per-patch hand-off size is chunk-size independent)
            self.last_stats.update(self._hetero_stats)
        return out

    # -- memory model --------------------------------------------------------

    def predict_memory(
        self, vol_shape: Sequence[int], *, sweep_axis: Optional[int] = None
    ):
        """Predicted peak device working set for sweeping ``vol_shape``.

        The planner-side simulation (``planner.plan_stream_memory``) run
        for THIS executor's mode (streaming or dense) and the given sweep
        axis (default the executor's): the returned
        ``MemoryFootprint.device_bytes`` equals what ``run`` will record
        in ``last_stats["peak_device_bytes"]`` up to the analytic-vs-
        measured state rounding (pinned within 10% by the test suite).
        Memoized per (shape, axis) — the simulation is deterministic, and
        ``run`` consults it every sweep for the predicted-peak stat.
        """
        if not self._os_reuse:
            raise ValueError("predict_memory needs an overlap-save reuse plan")
        axis = self.sweep_axis if sweep_axis is None else int(sweep_axis)
        key = tuple(int(x) for x in vol_shape) + (axis,)
        hit = self._predict_memory_cache.get(key)
        if hit is not None:
            return hit
        from ..core.planner import plan_stream_memory

        mem = plan_stream_memory(
            self.net, self.prims, self.m, key[:3],
            batch=self.batch, deep_reuse=self.deep_reuse,
            streaming=self.streaming, sweep_axis=axis,
        )
        self._predict_memory_cache[key] = mem
        return mem

    def sweep_bytes_estimate(
        self, vol_shape: Sequence[int], *, sweep_axis: Optional[int] = None
    ) -> float:
        """Device bytes OPENING a sweep over ``vol_shape`` would add.

        The serving engine's admission estimate: predicted peak minus the
        always-resident prepared states (already counted in the ledger).
        """
        mem = self.predict_memory(vol_shape, sweep_axis=sweep_axis)
        return mem.device_bytes - mem.spectra_bytes

    def write_core(self, out, tiling, spec, y) -> None:
        """Crop a patch's dense core (out_ch, core³) into the output.

        ``spec``/``y`` are in the tiling's working frame; ``out`` is the
        VOLUME-frame dense output (possibly the true un-bucketed crop).
        Each working axis clips against the matching volume axis's extent
        and, for non-identity frames, the cropped core transposes back —
        the only place sweep output re-enters volume coordinates.
        """
        c = tiling.core
        perm, inv = tiling.perm, tiling.inv_perm
        sls = []
        for i in range(3):
            s = spec.start[i]
            sls.append(slice(s, min(s + c, out.shape[1 + perm[i]])))
        y = y[:, : sls[0].stop - sls[0].start,
              : sls[1].stop - sls[1].start, : sls[2].stop - sls[2].start]
        if perm == (0, 1, 2):
            out[:, sls[0], sls[1], sls[2]] = y
        else:
            out[(slice(None),) + tuple(sls[inv[a]] for a in range(3))] = (
                np.transpose(y, (0,) + tuple(1 + inv[a] for a in range(3)))
            )

    def _run_batched(self, padded, tiling, out, sweep=None):
        S = self.batch
        specs = tiling.patches
        n_batches = 0
        if sweep is not None:
            # reuse path: chunks are capped at x-plane boundaries so every
            # aligned interior patch's left neighbour completed in an
            # EARLIER chunk — the strip path survives batch sizes larger
            # than the x-plane (and, streaming, every chunk reads one slab)
            chunks = [
                [specs[i] for i in idxs] for idxs in chunk_patches(tiling, S)
            ]
        else:
            chunks = [list(specs[i : i + S]) for i in range(0, len(specs), S)]
        for ci, chunk in enumerate(chunks):
            # a ragged tail runs through a smaller compiled batch (one extra
            # compile, cached per size) instead of computing-and-discarding
            # repeated padding patches.
            if sweep is not None:
                if self.streaming:
                    # double-buffered staging: release planes the stream
                    # moved past, keep/stage the current plane, and kick
                    # off the NEXT plane's host→device copy so it overlaps
                    # the current chunk's fused step (async dispatch)
                    x_cur = chunk[0].start[0]
                    keep = {x_cur}
                    if ci + 1 < len(chunks):
                        keep.add(chunks[ci + 1][0].start[0])
                    self._drop_slabs(sweep, keep)
                    for x0 in sorted(keep):
                        self._slab(sweep, x0)
                # overlap-save: the walk starts from cached/computed segment
                # spectra of the sweep's resident volume (or staged slab) —
                # no host-side patch extraction
                meta = [
                    (sweep, tiling.segment_keys(s), s.start) for s in chunk
                ]
                ys = self.run_patch_batch(None, meta=meta)
            else:
                xs = np.stack(
                    [extract_patch(padded, s, tiling.extent) for s in chunk]
                )
                ys = self.run_patch_batch(xs)
            for spec, y in zip(chunk, ys):
                self.write_core(out, tiling, spec, y)
            n_batches += 1
        return n_batches, 0

    def _run_pipeline(self, padded, tiling, out):
        """pipeline2: stream patch chunks through the two-stage scan."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        S = self.batch
        specs = list(tiling.patches)
        n_chunks = math.ceil(len(specs) / S)
        devices = np.array(jax.devices())
        n_pods = len(devices)
        # equal local stream length per pod: pad the chunk count
        T = math.ceil(n_chunks / n_pods) * n_pods
        xs_all = np.empty(
            (T, S, padded.shape[0]) + (tiling.extent,) * 3, np.float32
        )
        chunk_specs: List[List] = []
        for t in range(T):
            chunk = specs[t * S : (t + 1) * S] or [specs[-1]]
            chunk_specs.append(chunk)
            for j in range(S):
                spec = chunk[min(j, len(chunk) - 1)]
                xs_all[t, j] = extract_patch(padded, spec, tiling.extent)

        if self._pipeline_fn is None:
            mesh = Mesh(devices, ("pod",))

            def local(states, xs):  # xs (T_local, S, f, n³) — this pod's stream
                # prepared states arrive as (replicated) jit arguments, not
                # trace constants, matching the batched path's convention
                stage0, stage1 = make_stage_fns(
                    self.compiled, self.theta, states=states
                )
                return pipelined_apply(stage0, stage1, xs, axis_name="pod")

            self._pipeline_fn = jax.jit(
                shard_map(
                    local, mesh=mesh,
                    in_specs=(P(), P("pod")), out_specs=P("pod"),
                )
            )

        # the pipeline schedule stages the whole patch stream at once; the
        # ledger records it so peak_device_bytes stays honest there too
        self._ledger.transient(xs_all.nbytes)
        ys = np.asarray(
            self._pipeline_fn(self.compiled.states, jnp.asarray(xs_all))
        )
        # ring hand-off: pod p's local outputs are pod p-1's patches; roll
        # the pod-major chunk axis by one local-stream length to realign.
        if n_pods > 1:
            ys = np.roll(
                ys.reshape((n_pods, T // n_pods) + ys.shape[1:]), -1, axis=0
            ).reshape((T,) + ys.shape[1:])
        pools = list(self.compiled.mpf_pools)
        for t, chunk in enumerate(chunk_specs):
            y = ys[t]
            if pools:
                y = np.asarray(recombine_fragments(jnp.asarray(y), pools, S))
            for j, spec in enumerate(chunk[:S]):
                self.write_core(out, tiling, spec, y[j])
        return T, T * S - tiling.n_patches

    def _run_hetero(self, padded, tiling, out):
        """hetero: two-backend pipeline, host RAM as the hand-off medium.

        Stage 0 (layers [0, θ)) runs on ``jax.devices("cpu")[0]``, stage 1
        (layers [θ, L) + MPF recombination) on the default accelerator —
        the plan's ``devices[0]``/``devices[1]`` profiles respectively.
        Between them the split-point activation is materialized as a host
        ndarray (the paper's §VII-C "host RAM is the shared medium"), so
        the hand-off is an explicit, measured device→host→device round
        trip, not a backend-internal transfer.  Chunks run the two stages
        back to back with per-stage timing; a single-accelerator container
        cannot physically overlap them, so measured wall time is t0+t1+
        xfer per chunk while the plan's steady-state model is
        max(t0,t1)+xfer — the per-stage/hand-off counters in
        ``last_stats`` are what pin the prediction, and the hand-off
        *bytes* match ``Plan.xfer_bytes`` exactly (per-patch size is
        chunk-size independent).
        """
        S = self.batch
        specs = list(tiling.patches)
        dev0, dev1 = hetero_stage_devices()
        pools = list(self.compiled.mpf_pools)
        frag = 1
        for p in pools:
            frag *= p**3

        if self._hetero_fns is None:
            theta = self.theta

            def stage0_fn(states, xs):
                return self.compiled.apply_range(xs, 0, theta, states=states)

            def stage1_fn(states, a):
                y = self.compiled.apply_range(a, theta, None, states=states)
                if pools:
                    y = recombine_fragments(y, pools, y.shape[0] // frag)
                return y

            # per-device copies of the prepared states; committed inputs
            # pin each jitted stage to its backend
            self._hetero_fns = (
                jax.jit(stage0_fn),
                jax.jit(stage1_fn),
                jax.device_put(self.compiled.states, dev0),
                jax.device_put(self.compiled.states, dev1),
            )
            self._ledger.alloc(_tree_nbytes(self._hetero_fns[3]))
        jit0, jit1, states0, states1 = self._hetero_fns

        stage0_s = stage1_s = xfer_s = 0.0
        xfer_bytes = 0.0
        n_chunks = 0
        for i in range(0, len(specs), S):
            chunk = specs[i : i + S]  # ragged tail runs at true size
            xs = np.stack(
                [extract_patch(padded, s, tiling.extent) for s in chunk]
            )
            self._record_trace(("hetero", xs.shape))
            t = time.perf_counter()
            a = jit0(states0, jax.device_put(xs, dev0))
            a.block_until_ready()
            t2 = time.perf_counter()
            stage0_s += t2 - t
            # the hand-off: device 0 → host RAM → device 1
            a_host = np.asarray(a)
            a1 = jax.device_put(a_host, dev1)
            a1.block_until_ready()
            t3 = time.perf_counter()
            xfer_s += t3 - t2
            xfer_bytes += float(a_host.nbytes)
            y = jit1(states1, a1)
            y.block_until_ready()
            stage1_s += time.perf_counter() - t3
            self._ledger.transient(xs.nbytes + a.nbytes + y.nbytes)
            for spec, yy in zip(chunk, np.asarray(y)):
                self.write_core(out, tiling, spec, yy)
            n_chunks += 1

        plan = self.plan
        scale = tiling.n_patches / plan.batch  # plan counters are per batch
        self._hetero_stats = {
            "stage0_seconds": stage0_s,
            "stage1_seconds": stage1_s,
            "xfer_seconds": xfer_s,
            "xfer_bytes": xfer_bytes,
            "predicted_stage0_seconds": plan.stage_times[0] * scale,
            "predicted_stage1_seconds": plan.stage_times[1] * scale,
            "predicted_xfer_seconds": plan.xfer_seconds * scale,
            "predicted_xfer_bytes": plan.xfer_bytes * scale,
        }
        return n_chunks, 0


def tiled_apply(
    params,
    net: ConvNetConfig,
    vol: np.ndarray,
    prims: Sequence[str],
    m: int,
    *,
    batch: int = 1,
    use_pallas: bool = False,
) -> np.ndarray:
    """One-shot tiled inference without a Plan (tests, notebooks)."""
    ex = PlanExecutor(
        params, net, prims=prims, m=m, batch=batch, use_pallas=use_pallas
    )
    return ex.run(vol)
