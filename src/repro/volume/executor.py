"""Plan-driven patch executor: run a planner ``Plan`` over a whole volume.

``PlanExecutor`` compiles a plan (per-layer primitives + patch geometry)
into a ``primitives.CompiledPlan`` — one-time per-layer setup: cached
kernel spectra for ``fft_cached`` layers, per-layer pruned-FFT shapes,
pool modes — and sweeps an arbitrary-size volume with jitted walks over
the prepared layers:

* patches come from the tiler (FOV overlap, shifted edge patches, zero
  padding for undersized axes);
* ``batch`` patches are stacked per compiled step (one XLA compile per
  batch size, cached — patch shape is fixed by the plan); the prepared
  states are jit arguments, shared by every batch size, so kernel FFTs
  run once per plan, not once per patch or compile; ragged tail batches
  run through a smaller compiled batch instead of padded-and-discarded
  work;
* MPF plans emit their full ``core³`` dense block per patch in one call
  (fragments recombined on device);
* plain-pool baseline plans sweep the P³ shifted subsamplings of each
  patch — the paper's naive "compute all subsamplings" outer loop —
  interleaving the strided outputs into the same dense core;
* ``pipeline2`` plans route the patch stream through
  ``core.pipeline.pipelined_apply`` (lax.scan over patches, stage hand-off
  across the ``pod`` mesh axis; queue depth 1 per §VII-C).

``run`` returns the dense (out_ch, X-FOV+1, ...) output and records
``last_stats`` (patch/batch counts, wall seconds, measured vox/s including
border waste, and the planner's predicted vox/s for comparison).
"""

from __future__ import annotations

import itertools
import math
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ConvNetConfig
from ..core.mpf import recombine_fragments
from ..core.pipeline import make_stage_fns, pipelined_apply
from ..core.planner import Plan
from ..core.primitives import CompiledPlan, compile_plan, plan_input_size
from .tiler import VolumeTiling, extract_patch, pad_volume, tile_volume


class PlanExecutor:
    """Bind a Plan (or explicit prims + fragment size) to a volume sweep."""

    def __init__(
        self,
        params,
        net: ConvNetConfig,
        plan: Optional[Plan] = None,
        *,
        prims: Optional[Sequence[str]] = None,
        m: Optional[int] = None,
        batch: Optional[int] = None,
        theta: int = -1,
        use_pallas: bool = False,
    ):
        self.params = params
        self.net = net
        self.plan = plan
        if plan is not None:
            prims = plan.prims
            m = plan.m_final
            batch = batch or plan.batch
            theta = plan.theta if plan.strategy == "pipeline2" else -1
        if prims is None or m is None:
            raise ValueError("need either a Plan or explicit prims + m")
        self.prims = tuple(prims)
        self.m = m
        self.batch = max(1, batch or 1)
        self.theta = theta
        self.use_pallas = use_pallas

        self.P = net.total_pooling()
        self.fov = net.field_of_view()
        self.core = m * self.P
        self.uses_mpf = "mpf" in self.prims
        # input voxels per axis a patch spans: n_in for MPF; the plain-pool
        # baseline sweeps P³ shifted n_in-windows, needing core + fov - 1.
        self.n_in = self._n_in()
        self.extent = self.n_in if self.uses_mpf else self.n_in + self.P - 1
        assert self.extent == self.core + self.fov - 1, (
            self.extent, self.core, self.fov
        )
        self.out_channels = [l for l in net.layers if l.kind == "conv"][-1].out_channels

        # one-time setup for every layer (cached kernel spectra, per-layer
        # FFT shapes, pool modes) — shared by every compiled batch size and
        # by the pipeline2 stage functions.
        self.compiled: CompiledPlan = compile_plan(
            params, net, prims=self.prims, n_in=self.n_in,
            use_pallas=use_pallas, plan=plan,
        )

        recombine = self.uses_mpf

        def _walk(states, xs):
            return self.compiled.apply(xs, states=states, recombine=recombine)

        # one jitted walk; jax.jit specializes (retraces) per patch-batch
        # shape, while the prepared states stay shared call arguments.
        self._jit_walk = jax.jit(_walk)
        self._seen_batch_sizes: set = set()
        self._pipeline_fn = None
        self.last_stats: Dict[str, float] = {}

    # -- geometry ------------------------------------------------------------

    def _n_in(self) -> int:
        """Input size per apply call, from the net walked backwards.

        ``primitives.plan_input_size`` generalizes ``net.valid_input_size``
        / ``planner._n_in_for_m`` to per-layer primitive assignments (those
        assume all pools are MPF or none are); the ``extent`` assertion in
        __init__ cross-checks the walks against the shared core/FOV
        identity.
        """
        return plan_input_size(self.net, self.prims, self.m)

    def tiling_for(self, vol_shape: Sequence[int]) -> VolumeTiling:
        return tile_volume(vol_shape, core=self.core, fov=self.fov)

    # -- compiled patch-batch kernels ---------------------------------------

    def padded_batch_size(self, n: int) -> int:
        """Batch size to run for ``n`` ready patches without compile churn.

        ``n`` itself when it is full or already compiled; otherwise the next
        power of two (capped at ``batch``), bounding the distinct compiled
        sizes a continuous-serving caller can trigger to O(log batch) while
        still avoiding most padded-and-discarded work.
        """
        if n >= self.batch or n in self._seen_batch_sizes:
            return min(n, self.batch)
        s = 1
        while s < n:
            s *= 2
        return min(s, self.batch)

    def run_patch_batch(self, xs: np.ndarray) -> np.ndarray:
        """(S, f, extent³) patches -> (S, out_ch, core³) dense cores.

        The per-layer states (weights, cached kernel spectra) are jit
        *arguments*, so every batch-size specialization shares the same
        prepared buffers — kernel FFTs ran once, in ``compile_plan``.
        """
        S = xs.shape[0]
        self._seen_batch_sizes.add(S)
        states = self.compiled.states
        if self.uses_mpf:
            return np.asarray(self._jit_walk(states, jnp.asarray(xs)))
        # baseline: all-subsamplings outer loop (P³ shifted passes)
        out = np.empty(
            (S, self.out_channels) + (self.core,) * 3, np.float32
        )
        n = self.n_in
        for ox, oy, oz in itertools.product(range(self.P), repeat=3):
            sub = xs[:, :, ox : ox + n, oy : oy + n, oz : oz + n]
            y = np.asarray(self._jit_walk(states, jnp.asarray(sub)))
            out[:, :, ox :: self.P, oy :: self.P, oz :: self.P] = y
        return out

    # -- volume sweep --------------------------------------------------------

    def run(self, vol: np.ndarray) -> np.ndarray:
        """Sweep (f, X, Y, Z) -> dense (out_ch, X-FOV+1, Y-FOV+1, Z-FOV+1)."""
        vol = np.asarray(vol, np.float32)
        tiling = self.tiling_for(vol.shape[1:])
        padded = pad_volume(vol, tiling)
        out = np.empty((self.out_channels,) + tiling.out_shape, np.float32)

        t0 = time.perf_counter()
        if self.theta >= 0:
            n_batches, padded_patches = self._run_pipeline(padded, tiling, out)
        else:
            n_batches, padded_patches = self._run_batched(padded, tiling, out)
        dt = time.perf_counter() - t0

        vox = float(np.prod(out.shape[1:]))
        self.last_stats = {
            "patches": tiling.n_patches,
            "batches": n_batches,
            # compute-then-discarded padding slots (pipeline stream padding;
            # the batched path routes ragged tails through a smaller compiled
            # batch instead of padding, so it reports 0)
            "padded_patches": padded_patches,
            "seconds": dt,
            "out_voxels": vox,
            "measured_voxps": vox / dt if dt > 0 else float("inf"),
            "predicted_voxps": self.plan.throughput if self.plan else float("nan"),
            "waste_fraction": tiling.waste_fraction,
        }
        return out

    def write_core(self, out, tiling, spec, y) -> None:
        """Crop a patch's dense core (out_ch, core³) into the output."""
        x, yy, z = spec.start
        c = tiling.core
        sl = np.s_[
            x : min(x + c, out.shape[1]),
            yy : min(yy + c, out.shape[2]),
            z : min(z + c, out.shape[3]),
        ]
        out[:, sl[0], sl[1], sl[2]] = y[
            :, : sl[0].stop - x, : sl[1].stop - yy, : sl[2].stop - z
        ]

    def _run_batched(self, padded, tiling, out):
        S = self.batch
        specs = tiling.patches
        n_batches = 0
        for i in range(0, len(specs), S):
            chunk = specs[i : i + S]
            xs = np.stack(
                [extract_patch(padded, s, tiling.extent) for s in chunk]
            )
            # a ragged tail runs through a smaller compiled batch (one extra
            # compile, cached per size) instead of computing-and-discarding
            # repeated padding patches.
            ys = self.run_patch_batch(xs)
            for spec, y in zip(chunk, ys):
                self.write_core(out, tiling, spec, y)
            n_batches += 1
        return n_batches, 0

    def _run_pipeline(self, padded, tiling, out):
        """pipeline2: stream patch chunks through the two-stage scan."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        S = self.batch
        specs = list(tiling.patches)
        n_chunks = math.ceil(len(specs) / S)
        devices = np.array(jax.devices())
        n_pods = len(devices)
        # equal local stream length per pod: pad the chunk count
        T = math.ceil(n_chunks / n_pods) * n_pods
        xs_all = np.empty(
            (T, S, padded.shape[0]) + (tiling.extent,) * 3, np.float32
        )
        chunk_specs: List[List] = []
        for t in range(T):
            chunk = specs[t * S : (t + 1) * S] or [specs[-1]]
            chunk_specs.append(chunk)
            for j in range(S):
                spec = chunk[min(j, len(chunk) - 1)]
                xs_all[t, j] = extract_patch(padded, spec, tiling.extent)

        if self._pipeline_fn is None:
            mesh = Mesh(devices, ("pod",))

            def local(states, xs):  # xs (T_local, S, f, n³) — this pod's stream
                # prepared states arrive as (replicated) jit arguments, not
                # trace constants, matching the batched path's convention
                stage0, stage1 = make_stage_fns(
                    self.compiled, self.theta, states=states
                )
                return pipelined_apply(stage0, stage1, xs, axis_name="pod")

            self._pipeline_fn = jax.jit(
                shard_map(
                    local, mesh=mesh,
                    in_specs=(P(), P("pod")), out_specs=P("pod"),
                )
            )

        ys = np.asarray(
            self._pipeline_fn(self.compiled.states, jnp.asarray(xs_all))
        )
        # ring hand-off: pod p's local outputs are pod p-1's patches; roll
        # the pod-major chunk axis by one local-stream length to realign.
        if n_pods > 1:
            ys = np.roll(
                ys.reshape((n_pods, T // n_pods) + ys.shape[1:]), -1, axis=0
            ).reshape((T,) + ys.shape[1:])
        pools = list(self.compiled.mpf_pools)
        for t, chunk in enumerate(chunk_specs):
            y = ys[t]
            if pools:
                y = np.asarray(recombine_fragments(jnp.asarray(y), pools, S))
            for j, spec in enumerate(chunk[:S]):
                self.write_core(out, tiling, spec, y[j])
        return T, T * S - tiling.n_patches


def tiled_apply(
    params,
    net: ConvNetConfig,
    vol: np.ndarray,
    prims: Sequence[str],
    m: int,
    *,
    batch: int = 1,
    use_pallas: bool = False,
) -> np.ndarray:
    """One-shot tiled inference without a Plan (tests, notebooks)."""
    ex = PlanExecutor(
        params, net, prims=prims, m=m, batch=batch, use_pallas=use_pallas
    )
    return ex.run(vol)
