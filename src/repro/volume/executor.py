"""Plan-driven patch executor: run a planner ``Plan`` over a whole volume.

``PlanExecutor`` compiles a plan (per-layer primitives + patch geometry)
into a ``primitives.CompiledPlan`` — one-time per-layer setup: cached
kernel spectra for ``fft_cached`` layers, per-layer pruned-FFT shapes,
pool modes — and sweeps an arbitrary-size volume with jitted walks over
the prepared layers:

* patches come from the tiler (FOV overlap, shifted edge patches, zero
  padding for undersized axes);
* ``batch`` patches are stacked per compiled step (one XLA compile per
  batch size, cached — patch shape is fixed by the plan); the prepared
  states are jit arguments, shared by every batch size, so kernel FFTs
  run once per plan, not once per patch or compile; ragged tail batches
  run through a smaller compiled batch instead of padded-and-discarded
  work;
* MPF plans emit their full ``core³`` dense block per patch in one call
  (fragments recombined on device);
* plain-pool baseline plans sweep the P³ shifted subsamplings of each
  patch — the paper's naive "compute all subsamplings" outer loop —
  interleaving the strided outputs into the same dense core;
* ``pipeline2`` plans route the patch stream through
  ``core.pipeline.pipelined_apply`` (lax.scan over patches, stage hand-off
  across the ``pod`` mesh axis; queue depth 1 per §VII-C).

Patch-geometry invariants this module relies on (see ``tiler``): every
patch spans ``extent = core + FOV - 1`` input voxels and contributes a
``core³`` dense block; adjacent patches overlap by FOV-1 input voxels;
edge patches are *shifted* (value-identical overlap), and the patch stream
is x-major with non-decreasing x.

Overlap-save input-spectra reuse: when the plan's FIRST conv layer is
``overlap_save``, the layer-0 segment grid is pinned to the patch core
(``compile_plan(overlap_seg=core)``) so the segments of x-adjacent patches
land on identical absolute input coordinates.  Within one sweep the
executor caches segment spectra keyed by ``tiler.segment_keys`` — the
FOV-overlap a neighbour shares is transformed once, not once per patch
(ZNNi's border waste removed from the transform).  The cache is scoped to
a *sweep* (``begin_sweep``/``end_sweep``): volume edges (shifted patches,
different y/z rows) and new requests simply miss and recompute; eviction
rides the tiler's non-decreasing-x guarantee.  ``last_stats`` reports
``os_seg_fft`` (input segment FFTs actually run) and ``os_seg_hits``
(segments served from the cache).

``run`` returns the dense (out_ch, X-FOV+1, ...) output and records
``last_stats`` (patch/batch counts, wall seconds, measured vox/s including
border waste, and the planner's predicted vox/s for comparison).
"""

from __future__ import annotations

import itertools
import math
import time
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ConvNetConfig
from ..core import overlap_save as os_mod
from ..core.mpf import recombine_fragments
from ..core.pipeline import make_stage_fns, pipelined_apply
from ..core.planner import Plan
from ..core.primitives import CompiledPlan, compile_plan, plan_input_size
from .tiler import HaloSpec, VolumeTiling, extract_patch, pad_volume, tile_volume


class _PendingMiss(NamedTuple):
    """Sweep-cache placeholder: this key's spectrum is being computed in
    the current batch as miss row ``idx`` (dedups within-batch repeats)."""

    idx: int


class _SpectrumRef(NamedTuple):
    """Sweep-cache entry: row ``idx`` of a batch's miss-FFT output array.

    Rows are never copied out — the fused step receives the parent arrays
    as jit arguments and selects rows at trace time, so a cache hit costs
    no host work at all.
    """

    parent: Any  # (M, f, ña, ñb, ñc) device array
    idx: int


class PlanExecutor:
    """Bind a Plan (or explicit prims + fragment size) to a volume sweep."""

    def __init__(
        self,
        params,
        net: ConvNetConfig,
        plan: Optional[Plan] = None,
        *,
        prims: Optional[Sequence[str]] = None,
        m: Optional[int] = None,
        batch: Optional[int] = None,
        theta: int = -1,
        use_pallas: bool = False,
    ):
        self.params = params
        self.net = net
        self.plan = plan
        if plan is not None:
            prims = plan.prims
            m = plan.m_final
            batch = batch or plan.batch
            theta = plan.theta if plan.strategy == "pipeline2" else -1
        if prims is None or m is None:
            raise ValueError("need either a Plan or explicit prims + m")
        self.prims = tuple(prims)
        self.m = m
        self.batch = max(1, batch or 1)
        self.theta = theta
        self.use_pallas = use_pallas

        self.P = net.total_pooling()
        self.fov = net.field_of_view()
        self.core = m * self.P
        self.uses_mpf = "mpf" in self.prims
        # input voxels per axis a patch spans: n_in for MPF; the plain-pool
        # baseline sweeps P³ shifted n_in-windows, needing core + fov - 1.
        self.n_in = self._n_in()
        self.extent = self.n_in if self.uses_mpf else self.n_in + self.P - 1
        assert self.extent == self.core + self.fov - 1, (
            self.extent, self.core, self.fov
        )
        self.out_channels = [l for l in net.layers if l.kind == "conv"][-1].out_channels

        # one-time setup for every layer (cached kernel spectra, per-layer
        # FFT shapes, pool modes) — shared by every compiled batch size and
        # by the pipeline2 stage functions.  A first-layer overlap_save conv
        # gets its segment grid pinned to the patch core so x-adjacent
        # patches share segment spectra (cross-patch input-FFT reuse).
        self.compiled: CompiledPlan = compile_plan(
            params, net, prims=self.prims, n_in=self.n_in,
            use_pallas=use_pallas, plan=plan,
            overlap_seg=self.core if self.prims[0] == "overlap_save" else None,
        )

        recombine = self.uses_mpf

        def _walk(states, xs):
            return self.compiled.apply(xs, states=states, recombine=recombine)

        # one jitted walk; jax.jit specializes (retraces) per patch-batch
        # shape, while the prepared states stay shared call arguments.
        self._jit_walk = jax.jit(_walk)
        self._seen_batch_sizes: set = set()
        self._pipeline_fn = None
        self.last_stats: Dict[str, float] = {}

        # -- overlap-save input-spectra reuse state --------------------------
        # active when the patch walk starts with an overlap_save conv over
        # the full patch extent (MPF plans; the plain-pool subsampling sweep
        # slices shifted sub-windows, which breaks segment alignment).
        self._os_reuse = self.prims[0] == "overlap_save" and self.uses_mpf
        self._sweeps: Dict[int, Dict[Tuple[int, int, int], jnp.ndarray]] = {}
        self._sweep_vols: Dict[int, jnp.ndarray] = {}
        self._sweep_counter = 0
        self._os_misses = 0
        self._os_hits = 0
        if self._os_reuse:
            spec0 = self.compiled.layers[0].os_spec
            self._jit_os_walk = jax.jit(self._os_walk)
            # the fused per-batch step retraces per miss/hit *pattern*; the
            # tiler produces only a handful (first row, interior row,
            # shifted edge row) per batch size
            self._jit_os_step = jax.jit(self._os_step, static_argnames=("pattern",))
            self.halo = HaloSpec(spec0.seg_core, spec0.seg_extent, spec0.starts)
        else:
            self.halo = None

    # -- geometry ------------------------------------------------------------

    def _n_in(self) -> int:
        """Input size per apply call, from the net walked backwards.

        ``primitives.plan_input_size`` generalizes ``net.valid_input_size``
        / ``planner._n_in_for_m`` to per-layer primitive assignments (those
        assume all pools are MPF or none are); the ``extent`` assertion in
        __init__ cross-checks the walks against the shared core/FOV
        identity.
        """
        return plan_input_size(self.net, self.prims, self.m)

    def tiling_for(self, vol_shape: Sequence[int]) -> VolumeTiling:
        return tile_volume(
            vol_shape, core=self.core, fov=self.fov, halo=self.halo
        )

    # -- overlap-save sweep cache -------------------------------------------

    def begin_sweep(self, padded: np.ndarray) -> int:
        """Open a fresh spectra-reuse scope (one volume sweep / request).

        Scoping the cache to a sweep is what makes reuse safe: segment keys
        are absolute coordinates *within one padded volume*, so spectra
        must never leak across requests.  The padded volume is uploaded to
        the device once here — misses then slice and transform on device
        (no per-segment host copies) — extended along x so the aligned
        grid's tail segments stay in bounds (the extra voxels are zeros;
        exact, because the outputs they influence are cropped).
        """
        spec0 = self.compiled.layers[0].os_spec
        max_x0 = max(0, padded.shape[1] - self.extent)
        short = max(0, max_x0 + spec0.span - padded.shape[1])
        vol = jnp.asarray(padded)
        if short:
            vol = jnp.pad(vol, ((0, 0), (0, short), (0, 0), (0, 0)))
        self._sweep_counter += 1
        self._sweeps[self._sweep_counter] = {}
        self._sweep_vols[self._sweep_counter] = vol
        return self._sweep_counter

    def end_sweep(self, token: Optional[int]) -> None:
        self._sweeps.pop(token, None)
        self._sweep_vols.pop(token, None)

    def _os_walk(self, states, F):
        """Jitted forward from precomputed layer-0 segment spectra.

        F (S, n_seg, f, ña, ñb, ñc) — the stacked per-patch spectra the
        sweep cache assembled; layers 1.. walk the shared prepared states
        exactly like the plain batched path.
        """
        pl0 = self.compiled.layers[0]
        x = os_mod.os_apply_from_spectra(
            F, states[0]["W"], states[0]["b"], pl0.os_spec,
            use_pallas=self.use_pallas,
        )
        last_conv = max(
            i for i, l in enumerate(self.net.layers) if l.kind == "conv"
        )
        if last_conv != 0:
            x = jax.nn.relu(x)
        x = self.compiled.apply_range(x, lo=1, states=states)
        if self.uses_mpf:
            x = recombine_fragments(x, list(self.compiled.mpf_pools), F.shape[0])
        return x

    def _os_step(self, states, vol, starts, parents, *, pattern):
        """ONE jitted call per patch batch: miss FFTs + assembly + walk.

        ``pattern`` is the batch's static miss/hit layout — slot i of the
        (S·n_seg)-row spectra stack is ``(-1, j)`` (row j of the miss FFTs
        computed here from ``starts``) or ``(p, j)`` (row j of
        ``parents[p]``, a previous batch's miss-FFT output held by the
        sweep cache).  Fusing the miss transforms into the walk's jit lets
        XLA schedule them with the MAD instead of paying a host round-trip
        per batch, and selecting cached rows at trace time means reuse
        costs no host copies; the miss spectra are returned so the sweep
        cache can serve them to the next x-row.
        """
        spec0 = self.compiled.layers[0].os_spec
        Fm = None
        if starts is not None:
            Fm = os_mod.slice_segment_spectra(vol, starts, spec0, self.extent)
        rows = [Fm[j] if p < 0 else parents[p][j] for p, j in pattern]
        S = len(pattern) // spec0.n_segments
        F_all = jnp.stack(rows).reshape(
            (S, spec0.n_segments) + rows[0].shape
        )
        return self._os_walk(states, F_all), Fm

    def _run_os_batch(self, meta) -> np.ndarray:
        """Patch batch with layer-0 segment spectra served from the cache.

        ``meta[i] = (sweep_token, segment_keys)`` for patch i; keys come
        from ``tiler.segment_keys`` and pair 1:1 (same order) with the
        prepared layer-0 ``os_spec.starts``.  The segment grid is
        volume-global (segments read the padded volume directly, past the
        patch's own extent if needed), so an interior patch transforms only
        the ``core/seg_core`` segments the sweep newly entered — everything
        else is a hit.  Single-sweep batches (the volume sweep, and serving
        ticks that drained one request) run the fused ``_os_step``;
        mixed-sweep batches fall back to one ``segment_spectra_at`` call
        per sweep plus the spectra-stack walk.
        """
        spec0 = self.compiled.layers[0].os_spec
        # pass 1: resolve every (patch, segment) against the sweep caches;
        # group the misses per sweep for batched device slicing.
        slots: List[List] = []  # per patch: (key, _SpectrumRef | _PendingMiss)
        miss_keys: Dict[int, List[Tuple[int, int, int]]] = {}
        for token, keys in meta:
            cache = self._sweeps.setdefault(token, {})
            # the patch stream is x-major with non-decreasing x (tiler
            # invariant): segments strictly left of this patch are dead.
            x_lo = keys[0][0]
            for dead in [k for k in cache if k[0] < x_lo]:
                del cache[dead]
            per_seg = []
            for key in keys:
                F = cache.get(key)
                if F is None:
                    # the pending marker in the cache also dedups repeated
                    # keys within this batch (bucketed tail repeats)
                    misses = miss_keys.setdefault(token, [])
                    F = _PendingMiss(len(misses))
                    cache[key] = F
                    misses.append(key)
                    self._os_misses += 1
                else:
                    self._os_hits += 1
                per_seg.append((key, F))
            slots.append(per_seg)
        tokens = {m[0] for m in meta}
        if len(tokens) == 1:
            # fused path: the whole batch — miss FFTs, assembly, walk — is
            # one jit call specialized on the (small, recurring) pattern.
            token = next(iter(tokens))
            cache = self._sweeps[token]
            misses = miss_keys.get(token, [])
            pattern: List[Tuple[int, int]] = []
            parents: List = []
            parent_pos: Dict[int, int] = {}
            for per_seg in slots:
                for key, F in per_seg:
                    if isinstance(F, _PendingMiss):
                        pattern.append((-1, F.idx))
                    else:
                        pos = parent_pos.get(id(F.parent))
                        if pos is None:
                            pos = parent_pos[id(F.parent)] = len(parents)
                            parents.append(F.parent)
                        pattern.append((pos, F.idx))
            starts = (
                jnp.asarray(np.asarray(misses, np.int32)) if misses else None
            )
            out, F_m = self._jit_os_step(
                self.compiled.states, self._sweep_vols[token],
                starts, tuple(parents), pattern=tuple(pattern),
            )
            for i, key in enumerate(misses):
                cache[key] = _SpectrumRef(F_m, i)
            return np.asarray(out)

        # fallback (cross-request serving batches): one batched FFT per
        # sweep, then the spectra-stack walk.
        F_miss: Dict[int, jnp.ndarray] = {}
        for token, keys_m in miss_keys.items():
            # pad the miss count to a power of two so the distinct compiled
            # FFT batch sizes stay O(log(S·n_seg))
            M = len(keys_m)
            Mp = 1
            while Mp < M:
                Mp *= 2
            starts = np.asarray(keys_m + [keys_m[-1]] * (Mp - M), np.int32)
            F_miss[token] = os_mod.segment_spectra_at(
                self._sweep_vols[token], jnp.asarray(starts), spec0, self.extent
            )
        # pass 2: materialize rows; ONE stack builds the batch.
        flat = []
        for (token, _), per_seg in zip(meta, slots):
            cache = self._sweeps[token]
            for key, F in per_seg:
                if isinstance(F, _PendingMiss):
                    cache[key] = F = _SpectrumRef(F_miss[token], F.idx)
                flat.append(F.parent[F.idx])
        F_all = jnp.stack(flat).reshape(
            (len(slots), spec0.n_segments) + flat[0].shape
        )  # (S, n_seg, f, ña, ñb, ñc)
        return np.asarray(self._jit_os_walk(self.compiled.states, F_all))

    # -- compiled patch-batch kernels ---------------------------------------

    def padded_batch_size(self, n: int) -> int:
        """Batch size to run for ``n`` ready patches without compile churn.

        ``n`` itself when it is full or already compiled; otherwise the next
        power of two (capped at ``batch``), bounding the distinct compiled
        sizes a continuous-serving caller can trigger to O(log batch) while
        still avoiding most padded-and-discarded work.
        """
        if n >= self.batch or n in self._seen_batch_sizes:
            return min(n, self.batch)
        s = 1
        while s < n:
            s *= 2
        return min(s, self.batch)

    def run_patch_batch(
        self, xs: Optional[np.ndarray], *, meta=None
    ) -> np.ndarray:
        """(S, f, extent³) patches -> (S, out_ch, core³) dense cores.

        The per-layer states (weights, cached kernel spectra) are jit
        *arguments*, so every batch-size specialization shares the same
        prepared buffers — kernel FFTs ran once, in ``compile_plan``.

        ``meta`` (overlap-save reuse only): per-patch ``(sweep_token,
        segment_keys)`` naming each patch's layer-0 segments by absolute
        volume coordinates, so input spectra shared with an x-adjacent
        patch are served from the sweep cache instead of recomputed; ``xs``
        may then be None (the walk starts from spectra of the sweep's
        device-resident volume, never from the raw patch).  Callers without
        sweep context (tests, raw batches) omit ``meta`` and get the
        self-contained walk.
        """
        if self._os_reuse and meta is not None:
            self._seen_batch_sizes.add(len(meta))
            return self._run_os_batch(meta)
        S = xs.shape[0]
        self._seen_batch_sizes.add(S)
        states = self.compiled.states
        if self.uses_mpf:
            return np.asarray(self._jit_walk(states, jnp.asarray(xs)))
        # baseline: all-subsamplings outer loop (P³ shifted passes)
        out = np.empty(
            (S, self.out_channels) + (self.core,) * 3, np.float32
        )
        n = self.n_in
        for ox, oy, oz in itertools.product(range(self.P), repeat=3):
            sub = xs[:, :, ox : ox + n, oy : oy + n, oz : oz + n]
            y = np.asarray(self._jit_walk(states, jnp.asarray(sub)))
            out[:, :, ox :: self.P, oy :: self.P, oz :: self.P] = y
        return out

    # -- volume sweep --------------------------------------------------------

    def run(self, vol: np.ndarray) -> np.ndarray:
        """Sweep (f, X, Y, Z) -> dense (out_ch, X-FOV+1, Y-FOV+1, Z-FOV+1)."""
        vol = np.asarray(vol, np.float32)
        tiling = self.tiling_for(vol.shape[1:])
        padded = pad_volume(vol, tiling)
        out = np.empty((self.out_channels,) + tiling.out_shape, np.float32)

        self._os_misses = self._os_hits = 0
        t0 = time.perf_counter()
        # the sweep's device upload is real per-volume work the other
        # execution modes pay per batch (patch extraction + transfer), so
        # it belongs inside the timed region for fair measured vox/s
        sweep = (
            self.begin_sweep(padded)
            if self._os_reuse and self.theta < 0 else None
        )
        try:
            if self.theta >= 0:
                n_batches, padded_patches = self._run_pipeline(padded, tiling, out)
            else:
                n_batches, padded_patches = self._run_batched(
                    padded, tiling, out, sweep
                )
        finally:
            self.end_sweep(sweep)
        dt = time.perf_counter() - t0

        vox = float(np.prod(out.shape[1:]))
        self.last_stats = {
            "patches": tiling.n_patches,
            "batches": n_batches,
            # compute-then-discarded padding slots (pipeline stream padding;
            # the batched path routes ragged tails through a smaller compiled
            # batch instead of padding, so it reports 0)
            "padded_patches": padded_patches,
            "seconds": dt,
            "out_voxels": vox,
            "measured_voxps": vox / dt if dt > 0 else float("inf"),
            "predicted_voxps": self.plan.throughput if self.plan else float("nan"),
            "waste_fraction": tiling.waste_fraction,
            # overlap-save input-spectra reuse (0/0 when not active):
            # segment FFTs actually run vs. segments served from the cache
            "os_seg_fft": self._os_misses,
            "os_seg_hits": self._os_hits,
        }
        return out

    def write_core(self, out, tiling, spec, y) -> None:
        """Crop a patch's dense core (out_ch, core³) into the output."""
        x, yy, z = spec.start
        c = tiling.core
        sl = np.s_[
            x : min(x + c, out.shape[1]),
            yy : min(yy + c, out.shape[2]),
            z : min(z + c, out.shape[3]),
        ]
        out[:, sl[0], sl[1], sl[2]] = y[
            :, : sl[0].stop - x, : sl[1].stop - yy, : sl[2].stop - z
        ]

    def _run_batched(self, padded, tiling, out, sweep=None):
        S = self.batch
        specs = tiling.patches
        n_batches = 0
        for i in range(0, len(specs), S):
            chunk = specs[i : i + S]
            # a ragged tail runs through a smaller compiled batch (one extra
            # compile, cached per size) instead of computing-and-discarding
            # repeated padding patches.
            if sweep is not None:
                # overlap-save: the walk starts from cached/computed segment
                # spectra of the device-resident volume — no patch extraction
                meta = [(sweep, tiling.segment_keys(s)) for s in chunk]
                ys = self.run_patch_batch(None, meta=meta)
            else:
                xs = np.stack(
                    [extract_patch(padded, s, tiling.extent) for s in chunk]
                )
                ys = self.run_patch_batch(xs)
            for spec, y in zip(chunk, ys):
                self.write_core(out, tiling, spec, y)
            n_batches += 1
        return n_batches, 0

    def _run_pipeline(self, padded, tiling, out):
        """pipeline2: stream patch chunks through the two-stage scan."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P

        S = self.batch
        specs = list(tiling.patches)
        n_chunks = math.ceil(len(specs) / S)
        devices = np.array(jax.devices())
        n_pods = len(devices)
        # equal local stream length per pod: pad the chunk count
        T = math.ceil(n_chunks / n_pods) * n_pods
        xs_all = np.empty(
            (T, S, padded.shape[0]) + (tiling.extent,) * 3, np.float32
        )
        chunk_specs: List[List] = []
        for t in range(T):
            chunk = specs[t * S : (t + 1) * S] or [specs[-1]]
            chunk_specs.append(chunk)
            for j in range(S):
                spec = chunk[min(j, len(chunk) - 1)]
                xs_all[t, j] = extract_patch(padded, spec, tiling.extent)

        if self._pipeline_fn is None:
            mesh = Mesh(devices, ("pod",))

            def local(states, xs):  # xs (T_local, S, f, n³) — this pod's stream
                # prepared states arrive as (replicated) jit arguments, not
                # trace constants, matching the batched path's convention
                stage0, stage1 = make_stage_fns(
                    self.compiled, self.theta, states=states
                )
                return pipelined_apply(stage0, stage1, xs, axis_name="pod")

            self._pipeline_fn = jax.jit(
                shard_map(
                    local, mesh=mesh,
                    in_specs=(P(), P("pod")), out_specs=P("pod"),
                )
            )

        ys = np.asarray(
            self._pipeline_fn(self.compiled.states, jnp.asarray(xs_all))
        )
        # ring hand-off: pod p's local outputs are pod p-1's patches; roll
        # the pod-major chunk axis by one local-stream length to realign.
        if n_pods > 1:
            ys = np.roll(
                ys.reshape((n_pods, T // n_pods) + ys.shape[1:]), -1, axis=0
            ).reshape((T,) + ys.shape[1:])
        pools = list(self.compiled.mpf_pools)
        for t, chunk in enumerate(chunk_specs):
            y = ys[t]
            if pools:
                y = np.asarray(recombine_fragments(jnp.asarray(y), pools, S))
            for j, spec in enumerate(chunk[:S]):
                self.write_core(out, tiling, spec, y[j])
        return T, T * S - tiling.n_patches


def tiled_apply(
    params,
    net: ConvNetConfig,
    vol: np.ndarray,
    prims: Sequence[str],
    m: int,
    *,
    batch: int = 1,
    use_pallas: bool = False,
) -> np.ndarray:
    """One-shot tiled inference without a Plan (tests, notebooks)."""
    ex = PlanExecutor(
        params, net, prims=prims, m=m, batch=batch, use_pallas=use_pallas
    )
    return ex.run(vol)
