"""Volume → patch decomposition (overlap-save tiling, ZNNi §II).

Patch-geometry invariants (the contract every consumer relies on —
executor, serving engine, and the overlap-save spectra cache):

* **core** — each patch contributes a ``core³`` block of dense output
  voxels (core = m · P), and interior patches start at multiples of
  ``core``; input start == dense-output start for valid convolution.
* **FOV overlap** — a patch spans ``extent = core + FOV - 1`` input voxels
  per axis, so adjacent patches share FOV-1 input voxels (the paper's
  recomputed "border waste"; the overlap-save mode below turns the shared
  region into reusable spectra instead).
* **shifted edge patches** — an edge remainder is handled with a patch
  shifted flush against the volume end; its core overlaps the previous
  patch's core, and since both compute the same sliding-window function of
  the same input window, the overwrite is value-identical (up to FFT
  round-off).  Per-axis starts are sorted ascending, and patches enumerate
  with axis 0 outermost — consumers may assume the x-coordinate of the
  patch stream is non-decreasing (the overlap-save cache evicts on it).
* **zero padding** — an axis shorter than one patch extent is zero-padded
  at its far end.  Valid-convolution output at dense coordinate v depends
  only on input [v, v+FOV), so outputs cropped to the true ``X - FOV + 1``
  range never see the padding — pad-and-crop is exact, not approximate.

MPF divisibility is the *plan's* obligation (n_in = valid_input_size(m)
satisfies (n+1) % p == 0 at every pool by construction); the tiler only
checks it, and otherwise works purely in dense-output coordinates, which
makes the same grid serve MPF plans (extent = n_in) and plain-pool
baseline plans (extent = n_in + P - 1, swept at P³ offsets by the
executor).

Overlap-save mode: ``tile_volume(..., halo=HaloSpec(...))`` additionally
describes the layer-0 overlap-save segment grid each patch carries — the
patch *core* plus the halo segmentation shared with its x-neighbours.
``VolumeTiling.segment_keys`` names each segment by its absolute input
coordinates; x-adjacent patches produce identical keys for the segments
they share, which is what lets the executor reuse their input spectra
(ZNNi's border waste paid once instead of per patch).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ConvNetConfig


@dataclass(frozen=True)
class PatchSpec:
    """One patch: input start == dense-output start (valid convolution)."""

    start: Tuple[int, int, int]


@dataclass(frozen=True)
class HaloSpec:
    """Layer-0 overlap-save segmentation a patch shares with x-neighbours.

    ``rel_starts`` are segment starts along axis 0 relative to the patch
    start (mirroring ``core.overlap_save.OverlapSaveSpec.starts``); each
    segment spans ``seg_extent`` input voxels and the full patch extent on
    the y/z axes.  When ``seg_core`` divides the tiling ``core``, the
    aligned segments of x-adjacent patches land on identical absolute
    coordinates — the shared halo the executor's spectra cache exploits.
    """

    seg_core: int
    seg_extent: int
    rel_starts: Tuple[int, ...]


@dataclass(frozen=True)
class VolumeTiling:
    """The full patch grid plus the geometry needed to reassemble output."""

    vol_shape: Tuple[int, int, int]  # true input extents (X, Y, Z)
    out_shape: Tuple[int, int, int]  # dense output extents (X-FOV+1, ...)
    pad: Tuple[int, int, int]  # zero padding appended per axis
    extent: int  # input voxels per patch per axis
    core: int  # dense output voxels per patch per axis
    fov: int
    patches: Tuple[PatchSpec, ...]
    halo: Optional[HaloSpec] = None  # overlap-save mode (None: plain tiling)

    @property
    def n_patches(self) -> int:
        return len(self.patches)

    def segment_keys(self, spec: PatchSpec) -> Tuple[Tuple[int, int, int], ...]:
        """Absolute identities of a patch's layer-0 overlap-save segments.

        Key = (absolute x start of the segment, patch y start, patch z
        start): a segment is the input window
        ``[x, x+seg_extent) × [y, y+extent) × [z, z+extent)``, so equal keys
        mean equal input windows — and therefore equal spectra — across
        patches of the same (padded) volume.
        """
        if self.halo is None:
            raise ValueError("tiling was not built in overlap-save mode")
        x0, y0, z0 = spec.start
        return tuple((x0 + r, y0, z0) for r in self.halo.rel_starts)

    @property
    def waste_fraction(self) -> float:
        """Fraction of patch input voxels recomputed or padded — the
        paper's border waste, end-to-end over this volume."""
        read = self.n_patches * self.extent**3
        useful = math.prod(self.vol_shape)  # padding voxels are waste too
        return 1.0 - min(useful / read, 1.0)


def _axis_starts(size: int, core: int, fov: int, extent: int) -> List[int]:
    """Patch start offsets along one (possibly padded) axis."""
    size = max(size, extent)  # undersized axes are padded to one patch
    out = size - (fov - 1)
    n_steps = max(1, math.ceil(out / core))
    starts = [min(i * core, out - core) for i in range(n_steps)]
    return sorted(set(starts))


def tile_volume(
    vol_shape: Sequence[int], *, core: int, fov: int,
    halo: Optional[HaloSpec] = None,
) -> VolumeTiling:
    """Tile an (X, Y, Z) volume for patches of dense-core ``core`` per axis.

    ``halo`` switches on overlap-save mode: the tiling then also hands the
    executor each patch's core plus the layer-0 segment grid shared with
    its x-neighbours (see ``VolumeTiling.segment_keys``).
    """
    if len(vol_shape) != 3:
        raise ValueError(f"expected (X, Y, Z) spatial shape, got {vol_shape}")
    if core < 1 or fov < 1:
        raise ValueError(f"invalid geometry core={core} fov={fov}")
    extent = core + fov - 1
    for ax, x in enumerate(vol_shape):
        if x < fov:
            raise ValueError(
                f"axis {ax} extent {x} < FOV {fov}: no valid output exists"
            )
    pad = tuple(max(0, extent - x) for x in vol_shape)
    out_shape = tuple(x - (fov - 1) for x in vol_shape)
    per_axis = [_axis_starts(x, core, fov, extent) for x in vol_shape]
    patches = tuple(
        PatchSpec(start=s) for s in itertools.product(*per_axis)
    )
    return VolumeTiling(
        vol_shape=tuple(vol_shape),
        out_shape=out_shape,
        pad=pad,
        extent=extent,
        core=core,
        fov=fov,
        patches=patches,
        halo=halo,
    )


@dataclass(frozen=True)
class SweepCounts:
    """Exact sweep-level reuse accounting for one tiling.

    Produced by ``predict_sweep_counts`` (the planner side) and matched
    1:1 against the executor's measured ``last_stats`` counters — the
    acceptance property of sweep-aware planning: what the planner priced
    is what the executor ran.
    """

    seg_fft: int  # input segment FFTs actually run (cache misses)
    seg_hits: int  # segments served from the sweep spectra cache
    mad_segments: int  # per-segment MAD + inverse passes executed
    strip_patches: int  # interior patches run on the deep-reuse strip path
    full_patches: int  # patches run on the full-extent path

    @property
    def n_patches(self) -> int:
        return self.strip_patches + self.full_patches


def predict_sweep_counts(
    tiling: VolumeTiling,
    *,
    batch: int = 1,
    deep_reuse: bool = False,
    strip_segments: Optional[int] = None,
) -> SweepCounts:
    """Simulate the executor's sweep caches over this tiling, exactly.

    Mirrors ``PlanExecutor``'s per-chunk processing: patches run in tiler
    order in chunks of ``batch``; within a chunk the full-path group
    resolves (and inserts) its segment keys before the strip group; a
    patch takes the strip path iff deep reuse is on, its start is
    core-aligned on x, and its left neighbour's activation halos were
    stored by an EARLIER chunk (same-chunk neighbours fall back to the
    full path — the executor decides eligibility before running the
    chunk).  Strip patches resolve only the trailing ``strip_segments``
    keys and pay that many MAD segments; full patches resolve the whole
    grid.  Spectra-cache eviction (keys strictly left of the current
    patch start) can never evict a key a later patch resolves — the
    patch stream has non-decreasing x — so it does not enter the counts.
    """
    if tiling.halo is None:
        raise ValueError("tiling was not built in overlap-save mode")
    n_seg = len(tiling.halo.rel_starts)
    q = strip_segments if (deep_reuse and strip_segments) else n_seg
    q = min(q, n_seg)
    cache: set = set()
    halo_ready: set = set()
    seg_fft = seg_hits = mad = strips = fulls = 0
    specs = tiling.patches
    core = tiling.core
    for i in range(0, len(specs), max(1, batch)):
        chunk = specs[i : i + max(1, batch)]
        strip_flags = []
        for p in chunk:
            x0, y0, z0 = p.start
            strip_flags.append(
                deep_reuse and x0 > 0 and x0 % core == 0 and p.start in halo_ready
            )
        for group_is_strip in (False, True):
            for p, is_strip in zip(chunk, strip_flags):
                if is_strip != group_is_strip:
                    continue
                keys = tiling.segment_keys(p)
                use = keys[n_seg - q :] if is_strip else keys
                for key in use:
                    if key in cache:
                        seg_hits += 1
                    else:
                        cache.add(key)
                        seg_fft += 1
                if is_strip:
                    mad += q
                    strips += 1
                else:
                    mad += n_seg
                    fulls += 1
        if deep_reuse:
            for p in chunk:
                x0, y0, z0 = p.start
                if x0 % core == 0:
                    halo_ready.add((x0 + core, y0, z0))
    return SweepCounts(seg_fft, seg_hits, mad, strips, fulls)


def tile_for_net(
    vol_shape: Sequence[int], net: ConvNetConfig, m: int
) -> VolumeTiling:
    """Tiling for fragment size ``m`` of ``net`` (checks MPF divisibility)."""
    n_in = net.valid_input_size(m)
    if net.output_size(n_in) != m:
        raise ValueError(
            f"n_in={n_in} violates the MPF divisibility constraints of {net.name}"
        )
    core = m * net.total_pooling()
    return tile_volume(vol_shape, core=core, fov=net.field_of_view())


def pad_volume(vol: np.ndarray, tiling: VolumeTiling) -> np.ndarray:
    """Zero-pad (f, X, Y, Z) at each axis end per the tiling (no-op if full)."""
    if not any(tiling.pad):
        return vol
    widths = [(0, 0)] + [(0, p) for p in tiling.pad]
    return np.pad(vol, widths)


def extract_patch(
    padded: np.ndarray, spec: PatchSpec, extent: int
) -> np.ndarray:
    """Slice one (f, extent³) patch out of the padded volume."""
    x, y, z = spec.start
    return padded[:, x : x + extent, y : y + extent, z : z + extent]
