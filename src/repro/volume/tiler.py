"""Volume → patch decomposition (overlap-save tiling, ZNNi §II).

Patch-geometry invariants (the contract every consumer relies on —
executor, serving engine, and the overlap-save spectra cache):

* **core** — each patch contributes a ``core³`` block of dense output
  voxels (core = m · P), and interior patches start at multiples of
  ``core``; input start == dense-output start for valid convolution.
* **FOV overlap** — a patch spans ``extent = core + FOV - 1`` input voxels
  per axis, so adjacent patches share FOV-1 input voxels (the paper's
  recomputed "border waste"; the overlap-save mode below turns the shared
  region into reusable spectra instead).
* **shifted edge patches** — an edge remainder is handled with a patch
  shifted flush against the volume end; its core overlaps the previous
  patch's core, and since both compute the same sliding-window function of
  the same input window, the overwrite is value-identical (up to FFT
  round-off).  Per-axis starts are sorted ascending, and patches enumerate
  with axis 0 outermost — consumers may assume the x-coordinate of the
  patch stream is non-decreasing (the overlap-save cache evicts on it).
* **zero padding** — an axis shorter than one patch extent is zero-padded
  at its far end.  Valid-convolution output at dense coordinate v depends
  only on input [v, v+FOV), so outputs cropped to the true ``X - FOV + 1``
  range never see the padding — pad-and-crop is exact, not approximate.

MPF divisibility is the *plan's* obligation (n_in = valid_input_size(m)
satisfies (n+1) % p == 0 at every pool by construction); the tiler only
checks it, and otherwise works purely in dense-output coordinates, which
makes the same grid serve MPF plans (extent = n_in) and plain-pool
baseline plans (extent = n_in + P - 1, swept at P³ offsets by the
executor).

Working frame (axis-generic sweeps): the sweep may advance along any
volume axis.  ``tile_volume(..., sweep_axis=a)`` permutes the volume
extents so the sweep axis becomes **working axis 0** and stores ALL
geometry — ``vol_shape``, ``out_shape``, ``pad``, patch starts, segment
keys — in that working frame.  Every consumer of a tiling (executor
caches, chunk scheduling, plane shards, the sweep simulations below)
keeps its existing axis-0 indexing and is therefore axis-generic for
free; only the two volume-frame boundaries translate:
``pad_volume`` permutes input volumes *into* the working frame, and the
executor's ``write_core`` permutes output cores back *out* of it
(``VolumeTiling.perm``/``inv_perm``).  ``sweep_axis=0`` is the identity
frame — bit-for-bit the pre-existing x-sweep behaviour.

Overlap-save mode: ``tile_volume(..., halo=HaloSpec(...))`` additionally
describes the layer-0 overlap-save segment grid each patch carries — the
patch *core* plus the halo segmentation shared with its x-neighbours.
``VolumeTiling.segment_keys`` names each segment by its absolute input
coordinates; x-adjacent patches produce identical keys for the segments
they share, which is what lets the executor reuse their input spectra
(ZNNi's border waste paid once instead of per patch).

Streaming schedule (ISSUE 5): ``chunk_patches`` partitions the patch
stream into executor chunks capped at x-plane boundaries (one input
x-slab per chunk; strip eligibility never degrades with batch size);
``plane_starts``/``final_rows_after_plane`` tell a consumer which dense
output rows are FINAL once a plane completes (the serving engine's
per-strip completion); ``predict_stream_peak`` replays the executor's
streaming schedule with caller-supplied byte weights and returns the
exact peak device working set (``StreamPeak``) — the planner's
``Plan.memory`` and the executor's measured ledger both come from this
one simulation, which is what makes prediction-vs-measurement pinnable.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ConvNetConfig


@dataclass(frozen=True)
class PatchSpec:
    """One patch: input start == dense-output start (valid convolution)."""

    start: Tuple[int, int, int]


def sweep_perm(sweep_axis: int) -> Tuple[int, int, int]:
    """Working→volume axis map: working axis i is volume axis perm[i].

    The sweep axis leads; the other two axes follow in ascending volume
    order.  ``sweep_axis=0`` is the identity ``(0, 1, 2)``.
    """
    if sweep_axis not in (0, 1, 2):
        raise ValueError(f"sweep_axis must be 0, 1 or 2, got {sweep_axis!r}")
    return (sweep_axis,) + tuple(b for b in range(3) if b != sweep_axis)


@dataclass(frozen=True)
class HaloSpec:
    """Layer-0 overlap-save segmentation a patch shares with sweep-neighbours.

    ``rel_starts`` are segment starts along working axis 0 (the sweep
    axis) relative to the patch start (mirroring
    ``core.overlap_save.OverlapSaveSpec.starts``); each segment spans
    ``seg_extent`` input voxels and the full patch extent on the two
    cross axes.  When ``seg_core`` divides the tiling ``core``, the
    aligned segments of sweep-adjacent patches land on identical absolute
    coordinates — the shared halo the executor's spectra cache exploits.
    """

    seg_core: int
    seg_extent: int
    rel_starts: Tuple[int, ...]


@dataclass(frozen=True)
class VolumeTiling:
    """The full patch grid plus the geometry needed to reassemble output.

    All spatial tuples (``vol_shape``/``out_shape``/``pad``/patch starts)
    live in the WORKING frame: working axis 0 is the sweep axis
    (``sweep_axis`` names the volume axis it came from; ``perm``/
    ``inv_perm`` translate between the frames).
    """

    vol_shape: Tuple[int, int, int]  # true input extents, working frame
    out_shape: Tuple[int, int, int]  # dense output extents (X-FOV+1, ...)
    pad: Tuple[int, int, int]  # zero padding appended per working axis
    extent: int  # input voxels per patch per axis
    core: int  # dense output voxels per patch per axis
    fov: int
    patches: Tuple[PatchSpec, ...]
    halo: Optional[HaloSpec] = None  # overlap-save mode (None: plain tiling)
    sweep_axis: int = 0  # volume axis the sweep advances on

    @property
    def n_patches(self) -> int:
        return len(self.patches)

    @property
    def perm(self) -> Tuple[int, int, int]:
        """Working→volume axis map (``sweep_perm(self.sweep_axis)``)."""
        return sweep_perm(self.sweep_axis)

    @property
    def inv_perm(self) -> Tuple[int, int, int]:
        """Volume→working axis map: volume axis a is working axis inv[a]."""
        p = self.perm
        inv = [0, 0, 0]
        for i, a in enumerate(p):
            inv[a] = i
        return tuple(inv)

    def to_volume_frame(
        self, shape: Sequence[int]
    ) -> Tuple[int, int, int]:
        """Map a working-frame spatial triple back to volume-frame order."""
        inv = self.inv_perm
        return tuple(shape[inv[a]] for a in range(3))

    def segment_keys(self, spec: PatchSpec) -> Tuple[Tuple[int, int, int], ...]:
        """Absolute identities of a patch's layer-0 overlap-save segments.

        Key = (absolute working-axis-0 start of the segment, patch cross
        starts): a segment is the working-frame input window
        ``[x, x+seg_extent) × [y, y+extent) × [z, z+extent)``, so equal keys
        mean equal input windows — and therefore equal spectra — across
        patches of the same (padded) volume swept on the same axis.
        """
        if self.halo is None:
            raise ValueError("tiling was not built in overlap-save mode")
        x0, y0, z0 = spec.start
        return tuple((x0 + r, y0, z0) for r in self.halo.rel_starts)

    @property
    def waste_fraction(self) -> float:
        """Fraction of patch input voxels recomputed or padded — the
        paper's border waste, end-to-end over this volume."""
        read = self.n_patches * self.extent**3
        useful = math.prod(self.vol_shape)  # padding voxels are waste too
        return 1.0 - min(useful / read, 1.0)


def _axis_starts(size: int, core: int, fov: int, extent: int) -> List[int]:
    """Patch start offsets along one (possibly padded) axis."""
    size = max(size, extent)  # undersized axes are padded to one patch
    out = size - (fov - 1)
    n_steps = max(1, math.ceil(out / core))
    starts = [min(i * core, out - core) for i in range(n_steps)]
    return sorted(set(starts))


def tile_volume(
    vol_shape: Sequence[int], *, core: int, fov: int,
    halo: Optional[HaloSpec] = None, sweep_axis: int = 0,
) -> VolumeTiling:
    """Tile an (X, Y, Z) volume for patches of dense-core ``core`` per axis.

    ``halo`` switches on overlap-save mode: the tiling then also hands the
    executor each patch's core plus the layer-0 segment grid shared with
    its sweep-neighbours (see ``VolumeTiling.segment_keys``).
    ``sweep_axis`` picks the volume axis the sweep advances on; the
    returned tiling stores every shape and patch start in the working
    frame with that axis first (see the module docstring).
    """
    if len(vol_shape) != 3:
        raise ValueError(f"expected (X, Y, Z) spatial shape, got {vol_shape}")
    if core < 1 or fov < 1:
        raise ValueError(f"invalid geometry core={core} fov={fov}")
    perm = sweep_perm(sweep_axis)
    vol_shape = tuple(vol_shape[a] for a in perm)
    extent = core + fov - 1
    for ax, x in enumerate(vol_shape):
        if x < fov:
            raise ValueError(
                f"axis {perm[ax]} extent {x} < FOV {fov}: no valid output exists"
            )
    pad = tuple(max(0, extent - x) for x in vol_shape)
    out_shape = tuple(x - (fov - 1) for x in vol_shape)
    per_axis = [_axis_starts(x, core, fov, extent) for x in vol_shape]
    patches = tuple(
        PatchSpec(start=s) for s in itertools.product(*per_axis)
    )
    return VolumeTiling(
        vol_shape=tuple(vol_shape),
        out_shape=out_shape,
        pad=pad,
        extent=extent,
        core=core,
        fov=fov,
        patches=patches,
        halo=halo,
        sweep_axis=sweep_axis,
    )


def chunk_patches(tiling: VolumeTiling, batch: int) -> Tuple[Tuple[int, ...], ...]:
    """Partition patch indices into executor chunks, capped at x-planes.

    Chunks hold up to ``batch`` patches and NEVER span an x-plane boundary
    (patches with different x starts).  Two consumers rely on the cap:

    * deep reuse — strip eligibility requires the left neighbour's halos
      to be stored by an *earlier* chunk, so a chunk spanning planes
      degrades its later-plane patches to the full path (the
      ``batch > patches-per-x-plane`` regression this fixes);
    * streaming — every patch of a chunk reads the same input x-slab
      ``[x0, x0 + span)``, so the staged slab has one constant shape
      (no per-chunk jit retraces on the slab operand).

    The trailing chunk of a plane may be ragged; the executor runs ragged
    chunks through a smaller compiled batch, as everywhere else.
    """
    batch = max(1, batch)
    chunks: List[Tuple[int, ...]] = []
    cur: List[int] = []
    for idx, p in enumerate(tiling.patches):
        if cur and (
            len(cur) == batch
            or tiling.patches[cur[0]].start[0] != p.start[0]
        ):
            chunks.append(tuple(cur))
            cur = []
        cur.append(idx)
    if cur:
        chunks.append(tuple(cur))
    return tuple(chunks)


def plane_starts(tiling: VolumeTiling) -> Tuple[int, ...]:
    """Distinct patch x starts in sweep order (one entry per x-plane)."""
    seen: List[int] = []
    for p in tiling.patches:
        if not seen or p.start[0] != seen[-1]:
            seen.append(p.start[0])
    return tuple(seen)


def final_rows_after_plane(
    tiling: VolumeTiling, plane_x0: int
) -> int:
    """Dense output x-rows final once every patch with start <= plane_x0 ran.

    A row is *final* when no remaining patch can write it.  Patches of the
    next plane (start x1 > plane_x0) write rows [x1, ...), so rows
    [0, x1) are final; after the last plane the whole output is final.
    Shifted edge planes are covered automatically: their start is simply
    the next entry in ``plane_starts``.
    """
    planes = plane_starts(tiling)
    later = [x for x in planes if x > plane_x0]
    return min(later) if later else tiling.out_shape[0]


@dataclass(frozen=True)
class SweepCounts:
    """Exact sweep-level reuse accounting for one tiling.

    Produced by ``predict_sweep_counts`` (the planner side) and matched
    1:1 against the executor's measured ``last_stats`` counters — the
    acceptance property of sweep-aware planning: what the planner priced
    is what the executor ran.
    """

    seg_fft: int  # input segment FFTs actually run (cache misses)
    seg_hits: int  # segments served from the sweep spectra cache
    mad_segments: int  # per-segment MAD + inverse passes executed
    strip_patches: int  # interior patches run on the deep-reuse strip path
    full_patches: int  # patches run on the full-extent path

    @property
    def n_patches(self) -> int:
        return self.strip_patches + self.full_patches


@dataclass(frozen=True)
class StreamPeak:
    """Predicted peak device working set of one executor sweep (bytes).

    Components are *at the peak step* of the simulated schedule, so
    ``peak_bytes`` equals their sum — not a sum of independent maxima.
    Produced by ``predict_stream_peak`` and matched against the
    executor's measured ``last_stats["peak_device_bytes"]`` (whose ledger
    samples the same components at the same points).
    """

    peak_bytes: float
    base_bytes: float  # prepared states (params + cached kernel spectra)
    slab_bytes: float  # staged input slabs (or the dense resident volume)
    cache_bytes: float  # live segment spectra + activation-halo entries
    out_bytes: float  # chunk output awaiting its host fetch
    scratch_bytes: float  # miss spectra + fresh halos at the peak step


def _simulate_sweep(
    tiling: VolumeTiling,
    *,
    batch: int,
    deep_reuse: bool,
    strip_segments: Optional[int],
    seg_bytes: float = 0.0,
    halo_entry_bytes: float = 0.0,
    out_patch_bytes: float = 0.0,
    slab_bytes: float = 0.0,
    base_bytes: float = 0.0,
    streaming: bool = True,
    dense_vol_bytes: float = 0.0,
    handoff: Optional[dict] = None,
) -> Tuple[SweepCounts, StreamPeak]:
    """One pass that produces both the reuse counts and the byte peak.

    Mirrors ``PlanExecutor``'s schedule exactly: plane-capped chunks
    (``chunk_patches``), full group before strip group, strip eligibility
    frozen at chunk start, per-key cache eviction strictly left of the
    chunk, halos stored only by core-aligned patches, and — on the byte
    side — the ledger's sampling points: slabs staged for the current and
    next chunk's planes, then per group the transient (chunk output +
    miss spectra + captured halos) on top of the pre-insert cache state.
    """
    if tiling.halo is None:
        raise ValueError("tiling was not built in overlap-save mode")
    n_seg = len(tiling.halo.rel_starts)
    q = strip_segments if (deep_reuse and strip_segments) else n_seg
    q = min(q, n_seg)
    cache: set = set()
    halo_ready: set = set()
    seg_fft = seg_hits = mad = strips = fulls = 0
    core = tiling.core
    specs = tiling.patches
    chunks = chunk_patches(tiling, batch)
    peak = StreamPeak(0.0, base_bytes, 0.0, 0.0, 0.0, 0.0)
    seg_cache_bytes = 0.0
    halo_cache_bytes = 0.0
    for ci, chunk_idx in enumerate(chunks):
        chunk = [specs[i] for i in chunk_idx]
        x_lo = min(p.start[0] for p in chunk)
        # per-key eviction strictly left of the chunk (both caches)
        for key in [kk for kk in cache if kk[0] < x_lo]:
            cache.discard(key)
            seg_cache_bytes -= seg_bytes
        for key in [kk for kk in halo_ready if kk[0] < x_lo]:
            halo_ready.discard(key)
            halo_cache_bytes -= halo_entry_bytes
        # shard-boundary snapshot: the cache state here (post-evict, before
        # this chunk inserts anything) is exactly what a predecessor shard
        # ending at x_lo exports and the successor imports
        if handoff is not None and x_lo in handoff and handoff[x_lo] is None:
            handoff[x_lo] = (len(cache), len(halo_ready))
        # staged slabs: current plane plus the prefetched next plane
        if streaming:
            x_cur = chunk[0].start[0]
            n_slabs = 1
            if ci + 1 < len(chunks):
                x_next = specs[chunks[ci + 1][0]].start[0]
                n_slabs = 2 if x_next != x_cur else 1
            resident_slabs = n_slabs * slab_bytes
        else:
            resident_slabs = dense_vol_bytes
        strip_flags = [
            deep_reuse
            and p.start[0] > 0
            and p.start[0] % core == 0
            and p.start in halo_ready
            for p in chunk
        ]
        for group_is_strip in (False, True):
            group = [
                p for p, s in zip(chunk, strip_flags) if s == group_is_strip
            ]
            if not group:
                continue
            misses = 0
            for p in group:
                keys = tiling.segment_keys(p)
                use = keys[n_seg - q :] if group_is_strip else keys
                for key in use:
                    if key in cache:
                        seg_hits += 1
                    else:
                        cache.add(key)
                        seg_fft += 1
                        misses += 1
                if group_is_strip:
                    mad += q
                    strips += 1
                else:
                    mad += n_seg
                    fulls += 1
            # the ledger's transient sample: group output + miss spectra +
            # captured halos live on top of the PRE-insert cache state
            out_b = len(group) * out_patch_bytes
            scratch_b = misses * seg_bytes + (
                len(group) * halo_entry_bytes if deep_reuse else 0.0
            )
            total = (
                base_bytes
                + resident_slabs
                + seg_cache_bytes
                + halo_cache_bytes
                + out_b
                + scratch_b
            )
            if total > peak.peak_bytes:
                peak = StreamPeak(
                    total, base_bytes, resident_slabs,
                    seg_cache_bytes + halo_cache_bytes, out_b, scratch_b,
                )
            seg_cache_bytes += misses * seg_bytes
            if deep_reuse:
                for p in group:
                    if p.start[0] % core == 0:
                        succ = (p.start[0] + core, p.start[1], p.start[2])
                        if succ not in halo_ready:
                            halo_ready.add(succ)
                            halo_cache_bytes += halo_entry_bytes
    counts = SweepCounts(seg_fft, seg_hits, mad, strips, fulls)
    return counts, peak


def predict_sweep_counts(
    tiling: VolumeTiling,
    *,
    batch: int = 1,
    deep_reuse: bool = False,
    strip_segments: Optional[int] = None,
) -> SweepCounts:
    """Simulate the executor's sweep caches over this tiling, exactly.

    Mirrors ``PlanExecutor``'s per-chunk processing: patches run in tiler
    order in chunks of ``batch`` capped at x-plane boundaries
    (``chunk_patches``); within a chunk the full-path group resolves (and
    inserts) its segment keys before the strip group; a patch takes the
    strip path iff deep reuse is on, its start is core-aligned on x, and
    its left neighbour's activation halos were stored by an EARLIER chunk
    (the plane cap makes every aligned interior patch eligible, whatever
    the batch size).  Strip patches resolve only the trailing
    ``strip_segments`` keys and pay that many MAD segments; full patches
    resolve the whole grid.  Spectra-cache eviction (keys strictly left
    of the current patch start) can never evict a key a later patch
    resolves — the patch stream has non-decreasing x — so it does not
    enter the counts.
    """
    counts, _ = _simulate_sweep(
        tiling, batch=batch, deep_reuse=deep_reuse,
        strip_segments=strip_segments,
    )
    return counts


def predict_stream_peak(
    tiling: VolumeTiling,
    *,
    batch: int = 1,
    deep_reuse: bool = False,
    strip_segments: Optional[int] = None,
    seg_bytes: float,
    halo_entry_bytes: float = 0.0,
    out_patch_bytes: float,
    slab_bytes: float,
    base_bytes: float = 0.0,
    streaming: bool = True,
    dense_vol_bytes: float = 0.0,
) -> StreamPeak:
    """Predict the executor's peak device bytes for sweeping this tiling.

    Byte weights come from the caller (the planner computes them
    analytically; ``PlanExecutor.predict_memory`` reads them off its
    compiled buffers) — the simulation itself is pure geometry, the same
    cache walk as ``predict_sweep_counts``.  ``streaming=False`` models
    the dense-materialized path: the whole padded volume is device
    resident (``dense_vol_bytes``) instead of the staged slabs.
    """
    _, mem_peak = _simulate_sweep(
        tiling, batch=batch, deep_reuse=deep_reuse,
        strip_segments=strip_segments,
        seg_bytes=seg_bytes, halo_entry_bytes=halo_entry_bytes,
        out_patch_bytes=out_patch_bytes, slab_bytes=slab_bytes,
        base_bytes=base_bytes, streaming=streaming,
        dense_vol_bytes=dense_vol_bytes,
    )
    return mem_peak


def plane_shards(
    tiling: VolumeTiling,
    n_workers: int,
    weights: Optional[Sequence[float]] = None,
) -> Tuple[Tuple[int, ...], ...]:
    """Partition the sweep's x-planes into ``n_workers`` contiguous runs.

    Returns one tuple of plane x-starts per worker, in sweep order (worker
    w's run is strictly left of worker w+1's).  Contiguity is what makes a
    shard exactly one prefix/suffix of the single-device sweep: the only
    cross-shard state is the cache contents at the boundary plane, which
    ``predict_shard_handoff`` sizes and ``PlanExecutor.export_handoff``
    ships.  Every plane holds the same y×z patch grid, so balancing plane
    counts balances patch counts; ``weights`` (e.g. 1/step-time, the
    straggler-rebalance lever) skews the split via ``elastic_shard_sizes``.
    Workers may receive empty runs when there are fewer planes than
    workers — an empty shard is a no-op with an empty handoff.
    """
    if n_workers < 1:
        raise ValueError("n_workers must be >= 1")
    from repro.distributed.fault_tolerance import elastic_shard_sizes

    planes = plane_starts(tiling)
    sizes = elastic_shard_sizes(
        len(planes), n_workers,
        list(weights) if weights is not None else None,
    )
    out: List[Tuple[int, ...]] = []
    pos = 0
    for s in sizes:
        out.append(planes[pos:pos + s])
        pos += s
    assert pos == len(planes)
    return tuple(out)


def shard_input_span(
    tiling: VolumeTiling, planes: Sequence[int]
) -> Tuple[int, int]:
    """Input x-range [lo, hi) one shard's patches read (its host slab).

    Patches of plane x0 read input rows [x0, x0 + extent); consecutive
    shards overlap by ``extent - core`` rows (= FOV - 1, the halo) — that
    overlap is what the boundary handoff carries in transformed form.
    """
    if not planes:
        return (0, 0)
    return (min(planes), max(planes) + tiling.extent)


@dataclass(frozen=True)
class ShardHandoff:
    """Predicted boundary-package contents at one shard boundary."""

    boundary_x: int  # successor shard's first plane start
    seg_keys: int  # layer-0 segment-spectra entries crossing the boundary
    halo_entries: int  # activation-halo entries (0 unless deep reuse)


def predict_shard_handoff(
    tiling: VolumeTiling,
    boundaries: Sequence[int],
    *,
    batch: int = 1,
    deep_reuse: bool = False,
    strip_segments: Optional[int] = None,
) -> Tuple[ShardHandoff, ...]:
    """Predict the cache entries each shard boundary hands to its successor.

    Runs the same sweep simulation as ``predict_sweep_counts`` and
    snapshots both caches at each boundary plane's first chunk, after
    eviction and before any insert — exactly the entry set (absolute-key
    x >= boundary) the predecessor shard exports.  Multiplying by the
    executor's per-entry byte sizes (``handoff_entry_nbytes``) gives the
    exact exchanged byte count, which tests pin against the measured
    ``HaloPackage.nbytes``.
    """
    snap = {int(b): None for b in boundaries}
    _simulate_sweep(
        tiling, batch=batch, deep_reuse=deep_reuse,
        strip_segments=strip_segments, handoff=snap,
    )
    out = []
    for b in boundaries:
        got = snap[int(b)]
        if got is None:  # boundary past the last plane: nothing crosses
            got = (0, 0)
        out.append(ShardHandoff(int(b), got[0], got[1]))
    return tuple(out)


def tile_for_net(
    vol_shape: Sequence[int], net: ConvNetConfig, m: int,
    *, sweep_axis: int = 0,
) -> VolumeTiling:
    """Tiling for fragment size ``m`` of ``net`` (checks MPF divisibility)."""
    n_in = net.valid_input_size(m)
    if net.output_size(n_in) != m:
        raise ValueError(
            f"n_in={n_in} violates the MPF divisibility constraints of {net.name}"
        )
    core = m * net.total_pooling()
    return tile_volume(
        vol_shape, core=core, fov=net.field_of_view(), sweep_axis=sweep_axis
    )


def pad_volume(vol: np.ndarray, tiling: VolumeTiling) -> np.ndarray:
    """Permute (f, X, Y, Z) into the tiling's working frame and zero-pad.

    The returned array has the sweep axis as spatial axis 0 (identity for
    ``sweep_axis=0``) and each working axis padded at its far end per the
    tiling (no-op if full) — exactly the frame every tiling coordinate
    (patch starts, segment keys, slab windows) addresses.
    """
    perm = tiling.perm
    if perm != (0, 1, 2):
        vol = np.ascontiguousarray(
            np.transpose(vol, (0, 1 + perm[0], 1 + perm[1], 1 + perm[2]))
        )
    if not any(tiling.pad):
        return vol
    widths = [(0, 0)] + [(0, p) for p in tiling.pad]
    return np.pad(vol, widths)


def extract_patch(
    padded: np.ndarray, spec: PatchSpec, extent: int
) -> np.ndarray:
    """Slice one (f, extent³) patch out of the padded volume."""
    x, y, z = spec.start
    return padded[:, x : x + extent, y : y + extent, z : z + extent]
