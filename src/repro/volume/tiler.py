"""Volume → patch decomposition (overlap-save tiling, ZNNi §II).

A plan fixes the per-patch geometry: each patch spans ``extent`` input
voxels per axis and contributes a ``core³`` block of dense output voxels
(core = m · P).  Adjacent patches overlap by FOV-1 input voxels — the
paper's recomputed "border waste".  The tiler turns an arbitrary
``(X, Y, Z)`` volume into the patch grid:

* interior patches start at multiples of ``core`` (input start == dense
  output start for valid convolution);
* an edge remainder is handled with a *shifted* patch flush against the
  volume end — its core overlaps the previous patch's core, and since both
  compute the same sliding-window function of the same input window, the
  overwrite is value-identical (up to FFT round-off);
* an axis shorter than one patch extent is zero-padded at its far end.
  Valid-convolution output at dense coordinate v depends only on input
  [v, v+FOV), so outputs cropped to the true ``X - FOV + 1`` range never
  see the padding — pad-and-crop is exact, not approximate.

MPF divisibility is the *plan's* obligation (n_in = valid_input_size(m)
satisfies (n+1) % p == 0 at every pool by construction); the tiler only
checks it, and otherwise works purely in dense-output coordinates, which
makes the same grid serve MPF plans (extent = n_in) and plain-pool
baseline plans (extent = n_in + P - 1, swept at P³ offsets by the
executor).
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np

from ..configs.base import ConvNetConfig


@dataclass(frozen=True)
class PatchSpec:
    """One patch: input start == dense-output start (valid convolution)."""

    start: Tuple[int, int, int]


@dataclass(frozen=True)
class VolumeTiling:
    """The full patch grid plus the geometry needed to reassemble output."""

    vol_shape: Tuple[int, int, int]  # true input extents (X, Y, Z)
    out_shape: Tuple[int, int, int]  # dense output extents (X-FOV+1, ...)
    pad: Tuple[int, int, int]  # zero padding appended per axis
    extent: int  # input voxels per patch per axis
    core: int  # dense output voxels per patch per axis
    fov: int
    patches: Tuple[PatchSpec, ...]

    @property
    def n_patches(self) -> int:
        return len(self.patches)

    @property
    def waste_fraction(self) -> float:
        """Fraction of patch input voxels recomputed or padded — the
        paper's border waste, end-to-end over this volume."""
        read = self.n_patches * self.extent**3
        useful = math.prod(self.vol_shape)  # padding voxels are waste too
        return 1.0 - min(useful / read, 1.0)


def _axis_starts(size: int, core: int, fov: int, extent: int) -> List[int]:
    """Patch start offsets along one (possibly padded) axis."""
    size = max(size, extent)  # undersized axes are padded to one patch
    out = size - (fov - 1)
    n_steps = max(1, math.ceil(out / core))
    starts = [min(i * core, out - core) for i in range(n_steps)]
    return sorted(set(starts))


def tile_volume(
    vol_shape: Sequence[int], *, core: int, fov: int
) -> VolumeTiling:
    """Tile an (X, Y, Z) volume for patches of dense-core ``core`` per axis."""
    if len(vol_shape) != 3:
        raise ValueError(f"expected (X, Y, Z) spatial shape, got {vol_shape}")
    if core < 1 or fov < 1:
        raise ValueError(f"invalid geometry core={core} fov={fov}")
    extent = core + fov - 1
    for ax, x in enumerate(vol_shape):
        if x < fov:
            raise ValueError(
                f"axis {ax} extent {x} < FOV {fov}: no valid output exists"
            )
    pad = tuple(max(0, extent - x) for x in vol_shape)
    out_shape = tuple(x - (fov - 1) for x in vol_shape)
    per_axis = [_axis_starts(x, core, fov, extent) for x in vol_shape]
    patches = tuple(
        PatchSpec(start=s) for s in itertools.product(*per_axis)
    )
    return VolumeTiling(
        vol_shape=tuple(vol_shape),
        out_shape=out_shape,
        pad=pad,
        extent=extent,
        core=core,
        fov=fov,
        patches=patches,
    )


def tile_for_net(
    vol_shape: Sequence[int], net: ConvNetConfig, m: int
) -> VolumeTiling:
    """Tiling for fragment size ``m`` of ``net`` (checks MPF divisibility)."""
    n_in = net.valid_input_size(m)
    if net.output_size(n_in) != m:
        raise ValueError(
            f"n_in={n_in} violates the MPF divisibility constraints of {net.name}"
        )
    core = m * net.total_pooling()
    return tile_volume(vol_shape, core=core, fov=net.field_of_view())


def pad_volume(vol: np.ndarray, tiling: VolumeTiling) -> np.ndarray:
    """Zero-pad (f, X, Y, Z) at each axis end per the tiling (no-op if full)."""
    if not any(tiling.pad):
        return vol
    widths = [(0, 0)] + [(0, p) for p in tiling.pad]
    return np.pad(vol, widths)


def extract_patch(
    padded: np.ndarray, spec: PatchSpec, extent: int
) -> np.ndarray:
    """Slice one (f, extent³) patch out of the padded volume."""
    x, y, z = spec.start
    return padded[:, x : x + extent, y : y + extent, z : z + extent]
