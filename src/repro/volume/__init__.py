"""Volume inference runtime — run a planner Plan over arbitrary-size volumes.

README / architecture
=====================

ZNNi's output is a *plan* (patch size n_in, batch S, per-layer primitives,
strategy); this package is the runtime that turns a plan into dense output
over a volume far larger than any single patch:

┌────────────┐  PatchSpecs  ┌──────────────────────────────┐  (S,out,core³)
│  tiler     │ ───────────▶ │ PlanExecutor                 │ ──▶ dense
│ (geometry) │              │  CompiledPlan + jit-per-S    │     output
└────────────┘              └──────────────────────────────┘

* ``tiler``     — pure geometry.  Decomposes (X, Y, Z) into overlapping
  patches: interior starts at multiples of core = m·P, a shifted patch for
  the edge remainder (value-identical overlap), zero padding for axes
  shorter than one patch (exact, because valid-conv output v only reads
  input [v, v+FOV)).  MPF divisibility is checked, never re-derived.
* ``executor``  — ``PlanExecutor`` compiles the plan ONCE into a
  ``core.primitives.CompiledPlan`` (per-layer one-time setup via the
  primitive registry: cached kernel spectra for ``fft_cached``, per-layer
  pruned-FFT shapes, overlap-save segment grids, pool modes), then jits
  one prepared-layer walk per batch size — the prepared states are jit
  *arguments*, shared by all compiled sizes, so kernel FFTs run once per
  plan rather than once per patch.  Ragged tail batches run through a
  smaller compiled batch (no padded-and-discarded work;
  ``last_stats["padded_patches"]`` counts any remaining pipeline-stream
  padding).  MPF plans recombine fragments on device; plain-pool baseline
  plans sweep the P³ shifted subsamplings (the paper's naive outer loop);
  pipeline2 plans stream patch chunks through
  ``core.pipeline.pipelined_apply`` on the ``pod`` mesh axis, both stages
  walking the same CompiledPlan.  Plans whose first conv is
  ``overlap_save`` additionally reuse layer-0 input segment spectra
  between x-adjacent patches within a sweep (the FOV overlap transformed
  once — see ``core/overlap_save.py`` and docs/architecture.md).  Plans
  solved under a ``ram_budget`` execute host-staged (ISSUE 5): the volume
  stays in host RAM, one x-slab per plane double-buffers onto the device,
  caches evict per plane, and ``last_stats["peak_device_bytes"]`` (the
  executor's ledger) is pinned against ``Plan.memory``'s prediction.
  ``run`` fills ``last_stats`` with measured vs. planner-predicted vox/s,
  border waste included, plus ``os_seg_fft``/``os_seg_hits`` reuse
  counters and the memory counters.
* ``serving.volume_engine`` — ``VolumeEngine`` queues volume requests and
  continuously batches *patches across requests* into executor steps (the
  3D analogue of token-level continuous batching in ``serving/engine.py``);
  every request shares the executor's one CompiledPlan.

Entry points: ``examples/serve_volume.py`` (service demo) and
``benchmarks/volume_throughput.py`` (measured vs. predicted vox/s).

Test-suite conventions (repo-wide, recorded here per ISSUE 1):
* slow tests carry ``@pytest.mark.slow`` and are deselected by default via
  ``pytest.ini``; run them with ``-m "slow or not slow"``.
* hypothesis is optional: property tests import from
  ``tests/_hypothesis_compat.py``, which falls back to a deterministic
  boundary grid when hypothesis is missing.
"""

from .executor import PlanExecutor, tiled_apply  # noqa: F401
from .tiler import (  # noqa: F401
    PatchSpec,
    VolumeTiling,
    extract_patch,
    pad_volume,
    tile_for_net,
    tile_volume,
)
