"""Decoder-only LM assembled from the layer zoo, covering dense / MoE / SSM
/ hybrid / VLM families via the config's ``block_pattern``.

Layer stacking: the pattern (length PL) repeats R = n_layers // PL times;
parameters for pattern position j are stacked over repeats (leading dim R)
and the repeats run under one ``lax.scan`` (small HLO, fast compiles, remat
per repeat).  A partial trailing repeat (gemma3's 62 = 10·6 + 2) is applied
unrolled after the scan.

Three entry points share the block code:
  forward  — full-sequence logits (training)
  prefill  — full-sequence logits + decode caches
  decode   — single-token step against the caches

Caches (leading dim R, stacked like params):
  attn/local/global: {k, v: (R, B, S_max, Hkv, hd)}         + lengths (B,)
  mamba:             {conv: (R, B, d_conv-1, ch), state: (R, B, h, p, n)}
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .. import flags
from ..configs.base import ModelConfig, parse_block_token
from ..distributed.constraints import constrain, constrain_replicated
from ..layers import attention as attn_l
from ..layers import embedding as emb_l
from ..layers import mlp as mlp_l
from ..layers import moe as moe_l
from ..layers import norms as norm_l
from ..layers import ssm as ssm_l
from ..layers import stubs


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, tok: str) -> Dict[str, Any]:
    mixer, is_moe = parse_block_token(tok)
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": norm_l.norm_init(cfg.norm, cfg.d_model, dt)}
    if mixer == "mamba":
        p["mixer"] = ssm_l.ssm_init(keys[0], cfg.d_model, cfg.ssm, dt)
    else:
        p["mixer"] = attn_l.attn_init(keys[0], cfg.d_model, cfg.attn, dt)
    if cfg.d_ff > 0:
        p["norm2"] = norm_l.norm_init(cfg.norm, cfg.d_model, dt)
        if is_moe:
            p["ffn"] = moe_l.moe_init(keys[1], cfg.d_model, cfg.d_ff, cfg.moe, cfg.act, dt)
        else:
            p["ffn"] = mlp_l.mlp_init(keys[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    return p


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dt = _dtype(cfg)
    PL = len(cfg.block_pattern)
    R = cfg.n_layers // PL
    REM = cfg.n_layers % PL
    k_emb, k_blocks, k_rem, k_fin = jax.random.split(key, 4)

    params: Dict[str, Any] = {
        "embed": emb_l.embed_init(k_emb, cfg.vocab, cfg.d_model, cfg.tie_embeddings, dt),
        "final_norm": norm_l.norm_init(cfg.norm, cfg.d_model, dt),
    }

    def init_repeat(k):
        ks = jax.random.split(k, PL)
        return {str(j): _init_block(ks[j], cfg, tok) for j, tok in enumerate(cfg.block_pattern)}

    rkeys = jax.random.split(k_blocks, R)
    params["blocks"] = jax.vmap(init_repeat)(rkeys)
    if REM:
        ks = jax.random.split(k_rem, REM)
        params["rem"] = {
            str(j): _init_block(ks[j], cfg, cfg.block_pattern[j]) for j in range(REM)
        }
    return params


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _block_full(p, x, tok: str, cfg: ModelConfig, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence block (train/prefill without cache capture)."""
    mixer, is_moe = parse_block_token(tok)
    aux = jnp.zeros((), jnp.float32)
    h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
    if mixer == "mamba":
        y = ssm_l.ssm_apply(p["mixer"], h, cfg.ssm, cfg.d_model)
    else:
        window = cfg.attn.swa_window if mixer == "local" else None
        y = attn_l.attn_apply(p["mixer"], h, cfg.attn, positions, window=window)
    x = x + y
    if cfg.d_ff > 0:
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        if is_moe:
            y, aux = moe_l.moe_apply(
                p["ffn"], h, cfg.moe, cfg.act, routing_groups=cfg.moe_routing_groups
            )
        else:
            y = mlp_l.mlp_apply(p["ffn"], h, cfg.act)
        x = x + y
    return x, aux


def _block_prefill(p, x, tok, cfg, positions, cache_len):
    mixer, is_moe = parse_block_token(tok)
    aux = jnp.zeros((), jnp.float32)
    h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
    if mixer == "mamba":
        y, cache = ssm_l.ssm_prefill(p["mixer"], h, cfg.ssm, cfg.d_model)
        cache = {"conv": cache[0], "state": cache[1]}
    else:
        window = cfg.attn.swa_window if mixer == "local" else None
        y, (k, v) = attn_l.attn_prefill(p["mixer"], h, cfg.attn, positions, cache_len, window=window)
        cache = {"k": k, "v": v}
    x = x + y
    if cfg.d_ff > 0:
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        if is_moe:
            y, aux = moe_l.moe_apply(
                p["ffn"], h, cfg.moe, cfg.act, routing_groups=cfg.moe_routing_groups
            )
        else:
            y = mlp_l.mlp_apply(p["ffn"], h, cfg.act)
        x = x + y
    return x, aux, cache


def _block_decode(p, x, tok, cfg, cache, lengths, use_pallas):
    mixer, is_moe = parse_block_token(tok)
    h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
    if mixer == "mamba":
        y, (conv, state) = ssm_l.ssm_decode(
            p["mixer"], h, cfg.ssm, cfg.d_model, cache["conv"], cache["state"]
        )
        cache = {"conv": conv, "state": state}
    else:
        window = cfg.attn.swa_window if mixer == "local" else None
        y, (k, v) = attn_l.attn_decode(
            p["mixer"], h, cfg.attn, cache["k"], cache["v"], lengths,
            window=window, use_pallas=use_pallas,
        )
        cache = {"k": k, "v": v}
    x = x + y
    if cfg.d_ff > 0:
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        if is_moe:
            y, _ = moe_l.moe_apply(
                p["ffn"], h, cfg.moe, cfg.act, routing_groups=cfg.moe_routing_groups
            )
        else:
            y = mlp_l.mlp_apply(p["ffn"], h, cfg.act)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Embedding / positions
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray]):
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = emb_l.embed_apply(params["embed"], tokens)
    x = constrain(x, "batch")
    if cfg.frontend == "patch" and "patch_embeds" in batch:
        x = stubs.vlm_splice(x, batch["patch_embeds"])
        positions = stubs.vlm_mrope_positions(B, S, batch["patch_embeds"].shape[1])
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


# ---------------------------------------------------------------------------
# Forward / prefill / decode
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *, remat: bool = True):
    """Full-sequence logits (B, S, vocab) + moe aux loss."""
    x, positions = _embed_inputs(params, cfg, batch)
    PL = len(cfg.block_pattern)

    def repeat_body(carry, rep_params):
        x, aux = carry
        for j, tok in enumerate(cfg.block_pattern):
            x, a = _block_full(rep_params[str(j)], x, tok, cfg, positions)
            x = constrain(x, "batch")
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(repeat_body) if remat else repeat_body
    (x, aux), _ = flags.chunk_scan(body, (x, jnp.zeros((), jnp.float32)), params["blocks"])
    for j in range(cfg.n_layers % PL):
        x, a = _block_full(params["rem"][str(j)], x, cfg.block_pattern[j], cfg, positions)
        aux = aux + a
    x = norm_l.norm_apply(cfg.norm, x, params["final_norm"])
    logits = emb_l.head_apply(params["embed"], x)
    return logits, aux


def prefill(params, cfg: ModelConfig, batch, *, cache_len: int):
    """Logits + decode caches (stacked over repeats)."""
    x, positions = _embed_inputs(params, cfg, batch)
    PL = len(cfg.block_pattern)

    def repeat_body(x, rep_params):
        caches = {}
        for j, tok in enumerate(cfg.block_pattern):
            x, _, cache = _block_prefill(rep_params[str(j)], x, tok, cfg, positions, cache_len)
            x = constrain(x, "batch")
            caches[str(j)] = cache
        return x, caches

    x, caches = flags.chunk_scan(repeat_body, x, params["blocks"])
    rem_caches = {}
    for j in range(cfg.n_layers % PL):
        x, _, cache = _block_prefill(
            params["rem"][str(j)], x, cfg.block_pattern[j], cfg, positions, cache_len
        )
        rem_caches[str(j)] = cache
    x = norm_l.norm_apply(cfg.norm, x, params["final_norm"])
    logits = emb_l.head_apply(params["embed"], x)
    lengths = jnp.full((batch["tokens"].shape[0],), batch["tokens"].shape[1], jnp.int32)
    return logits, {"blocks": caches, "rem": rem_caches, "lengths": lengths}


def decode_step(params, cfg: ModelConfig, tokens, caches, *, use_pallas: bool = False):
    """tokens (B, 1) -> logits (B, 1, vocab) + updated caches.

    Caches ride the scan CARRY (updated in place via per-repeat
    dynamic-update-slice on the stacked dim) rather than as xs/ys — the
    while-loop state aliases in place, so the cache exists ONCE in memory
    instead of as separate input and output stacks.
    """
    lengths = caches["lengths"]
    x = emb_l.embed_apply(params["embed"], tokens)
    if cfg.decode_replicate_activations:
        x = constrain_replicated(x)
    PL = len(cfg.block_pattern)

    def repeat_body(carry, inp):
        x, blocks = carry
        rep_params, r = inp
        for j, tok in enumerate(cfg.block_pattern):
            cache_rj = jax.tree.map(
                lambda c: lax.dynamic_index_in_dim(c, r, 0, keepdims=False),
                blocks[str(j)],
            )
            x, c_new = _block_decode(
                rep_params[str(j)], x, tok, cfg, cache_rj, lengths, use_pallas
            )
            blocks = {
                **blocks,
                str(j): jax.tree.map(
                    lambda c, n: lax.dynamic_update_index_in_dim(c, n, r, 0),
                    blocks[str(j)],
                    c_new,
                ),
            }
        return (x, blocks), None

    R = cfg.n_layers // PL
    (x, new_caches), _ = flags.chunk_scan(
        repeat_body, (x, caches["blocks"]), (params["blocks"], jnp.arange(R))
    )
    new_rem = {}
    for j in range(cfg.n_layers % PL):
        x, c = _block_decode(
            params["rem"][str(j)], x, cfg.block_pattern[j], cfg,
            caches["rem"][str(j)], lengths, use_pallas,
        )
        new_rem[str(j)] = c
    x = norm_l.norm_apply(cfg.norm, x, params["final_norm"])
    logits = emb_l.head_apply(params["embed"], x)
    return logits, {"blocks": new_caches, "rem": new_rem, "lengths": lengths + 1}


# ---------------------------------------------------------------------------
# Cache constructors (zeros + ShapeDtypeStruct variants)
# ---------------------------------------------------------------------------


def _cache_shape_for(cfg: ModelConfig, tok: str, B: int, S_max: int):
    mixer, _ = parse_block_token(tok)
    dt = _dtype(cfg)
    if mixer == "mamba":
        s = cfg.ssm
        ch = s.d_inner(cfg.d_model) + 2 * s.d_state
        return {
            "conv": ((B, s.d_conv - 1, ch), dt),
            "state": ((B, s.n_ssm_heads(cfg.d_model), s.headdim, s.d_state), jnp.float32),
        }
    a = cfg.attn
    return {
        "k": ((B, S_max, a.n_kv_heads, a.head_dim), dt),
        "v": ((B, S_max, a.n_kv_heads, a.head_dim), dt),
    }


def make_caches(cfg: ModelConfig, B: int, S_max: int, *, abstract: bool = False):
    """Zero (or ShapeDtypeStruct) caches matching prefill's output layout."""
    PL = len(cfg.block_pattern)
    R = cfg.n_layers // PL
    REM = cfg.n_layers % PL

    def mk(shape, dtype, lead=None):
        full = ((lead,) if lead else ()) + shape
        if abstract:
            return jax.ShapeDtypeStruct(full, dtype)
        return jnp.zeros(full, dtype)

    blocks = {}
    for j, tok in enumerate(cfg.block_pattern):
        spec = _cache_shape_for(cfg, tok, B, S_max)
        blocks[str(j)] = {k: mk(s, d, lead=R) for k, (s, d) in spec.items()}
    rem = {}
    for j in range(REM):
        spec = _cache_shape_for(cfg, cfg.block_pattern[j], B, S_max)
        rem[str(j)] = {k: mk(s, d) for k, (s, d) in spec.items()}
    lengths = (
        jax.ShapeDtypeStruct((B,), jnp.int32) if abstract else jnp.zeros((B,), jnp.int32)
    )
    return {"blocks": blocks, "rem": rem, "lengths": lengths}
