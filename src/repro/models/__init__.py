"""Model builders: decoder-only LM (transformer.py), enc-dec (encdec.py),
and the unified build_model API (api.py)."""

from .api import Model, build_model  # noqa: F401
