"""Unified model API: ``build_model(cfg)`` -> init / forward / loss /
prefill / decode_step / make_caches / input_specs.

``input_specs(shape)`` returns weak-type-correct ShapeDtypeStruct stand-ins
for every *non-parameter* input of the step the shape exercises (train ->
train loss inputs; prefill -> token batch; decode -> token + caches), so
the dry-run can ``jax.jit(step).lower(**specs)`` without allocating.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..layers import embedding as emb_l
from ..layers import stubs
from . import encdec, transformer


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    make_caches: Callable
    input_specs: Callable


def _frontend_specs(cfg: ModelConfig, B: int) -> Dict[str, Any]:
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "patch":
        return {
            "patch_embeds": jax.ShapeDtypeStruct((B, stubs.VLM_N_PATCHES, cfg.d_model), dt)
        }
    if cfg.frontend == "audio":
        return {"frame_embeds": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dt)}
    return {}


def build_model(cfg: ModelConfig) -> Model:
    mod = encdec if cfg.enc_dec else transformer

    def init(key):
        return mod.init_params(key, cfg)

    def forward(params, batch, *, remat=True):
        return mod.forward(params, cfg, batch, remat=remat)

    def loss(params, batch, *, remat=True):
        logits, aux = mod.forward(params, cfg, batch, remat=remat)
        ce = emb_l.cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return ce + aux

    def prefill(params, batch, *, cache_len):
        return mod.prefill(params, cfg, batch, cache_len=cache_len)

    def decode_step(params, tokens, caches, *, use_pallas=False):
        return mod.decode_step(params, cfg, tokens, caches, use_pallas=use_pallas)

    def make_caches(B, S_max, *, abstract=False):
        return mod.make_caches(cfg, B, S_max, abstract=abstract)

    def input_specs(shape: ShapeConfig) -> Dict[str, Any]:
        B, S = shape.global_batch, shape.seq_len
        tok = jax.ShapeDtypeStruct
        if shape.kind == "train":
            specs = {
                "tokens": tok((B, S), jnp.int32),
                "labels": tok((B, S), jnp.int32),
                **_frontend_specs(cfg, B),
            }
            return specs
        if shape.kind == "prefill":
            return {"tokens": tok((B, S), jnp.int32), **_frontend_specs(cfg, B)}
        # decode: one new token against a cache of S entries
        return {
            "tokens": tok((B, 1), jnp.int32),
            "caches": make_caches(B, S, abstract=True),
        }

    return Model(cfg, init, forward, loss, prefill, decode_step, make_caches, input_specs)
