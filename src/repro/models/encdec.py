"""Whisper-style encoder-decoder (audio family).

Encoder: `n_enc_layers` bidirectional attention blocks over precomputed
frame embeddings (the conv frontend is a stub per the assignment) with
sinusoidal positions.  Decoder: causal self-attention + cross-attention to
the encoder output + MLP, with sinusoidal positions on token embeddings.

Caches: self-attn KV per decoder layer (stacked) + cross-attn KV computed
once at prefill (keyed off the encoder output, static during decode).
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from .. import flags
from ..configs.base import ModelConfig
from ..layers import attention as attn_l
from ..layers import embedding as emb_l
from ..layers import mlp as mlp_l
from ..layers import norms as norm_l
from ..layers.attention import chunked_attention
from ..layers.dot import contract
from ..distributed.constraints import constrain


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def _init_enc_block(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
        "attn": attn_l.attn_init(k1, cfg.d_model, cfg.attn, _dt(cfg)),
        "norm2": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
        "mlp": mlp_l.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, _dt(cfg)),
    }


def _init_dec_block(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
        "self": attn_l.attn_init(k1, cfg.d_model, cfg.attn, _dt(cfg)),
        "norm_x": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
        "cross": attn_l.attn_init(k2, cfg.d_model, cfg.attn, _dt(cfg)),
        "norm2": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
        "mlp": mlp_l.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, _dt(cfg)),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ke, kd, kt = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": emb_l.embed_init(kt, cfg.vocab, cfg.d_model, cfg.tie_embeddings, _dt(cfg)),
        "enc_blocks": jax.vmap(lambda k: _init_enc_block(k, cfg))(enc_keys),
        "enc_norm": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(k, cfg))(dec_keys),
        "final_norm": norm_l.norm_init(cfg.norm, cfg.d_model, _dt(cfg)),
    }


# ---------------------------------------------------------------------------


def _cross_kv(p, enc_out, cfg):
    k = contract("bsd,dhk->bshk", enc_out, p["wk"])
    v = contract("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.attn.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def _cross_attend(p, x, k, v, cfg):
    q = contract("bsd,dhk->bshk", x, p["wq"])
    if cfg.attn.qkv_bias:
        q = q + p["bq"]
    o = chunked_attention(q, k, v, causal=False)
    return contract("bshk,hkd->bsd", o, p["wo"])


def encode(params, cfg: ModelConfig, frame_embeds: jnp.ndarray) -> jnp.ndarray:
    """frame_embeds (B, enc_seq, d_model) -> encoder output."""
    S = frame_embeds.shape[1]
    pos = emb_l.sinusoidal_positions(S, cfg.d_model).astype(frame_embeds.dtype)
    x = constrain(frame_embeds + pos[None], "batch")
    dummy_pos = jnp.broadcast_to(jnp.arange(S)[None], (x.shape[0], S))

    def body(x, p):
        h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
        q, k, v = attn_l._qkv(p["attn"], h, cfg.attn, dummy_pos, rope=False)
        o = chunked_attention(q, k, v, causal=False)
        x = x + contract("bshk,hkd->bsd", o, p["attn"]["wo"])
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        x = x + mlp_l.mlp_apply(p["mlp"], h, cfg.act)
        return constrain(x, "batch"), None

    x, _ = flags.chunk_scan(body, x, params["enc_blocks"])
    return norm_l.norm_apply(cfg.norm, x, params["enc_norm"])


def forward(params, cfg: ModelConfig, batch, *, remat: bool = True):
    """Training: batch {tokens (B,S), frame_embeds (B,enc_seq,d)} -> logits."""
    enc_out = encode(params, cfg, batch["frame_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = emb_l.embed_apply(params["embed"], tokens)
    x = constrain(x + emb_l.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None], "batch")
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
        q, k, v = attn_l._qkv(p["self"], h, cfg.attn, positions, rope=False)
        o = chunked_attention(q, k, v, causal=True)
        x = x + contract("bshk,hkd->bsd", o, p["self"]["wo"])
        h = norm_l.norm_apply(cfg.norm, x, p["norm_x"])
        kx, vx = _cross_kv(p["cross"], enc_out, cfg)
        x = x + _cross_attend(p["cross"], h, kx, vx, cfg)
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        x = x + mlp_l.mlp_apply(p["mlp"], h, cfg.act)
        return constrain(x, "batch"), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = flags.chunk_scan(body_fn, x, params["dec_blocks"])
    x = norm_l.norm_apply(cfg.norm, x, params["final_norm"])
    return emb_l.head_apply(params["embed"], x), jnp.zeros((), jnp.float32)


def prefill(params, cfg: ModelConfig, batch, *, cache_len: int):
    enc_out = encode(params, cfg, batch["frame_embeds"])
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = emb_l.embed_apply(params["embed"], tokens)
    x = constrain(x + emb_l.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)[None], "batch")
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(x, p):
        h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
        q, k, v = attn_l._qkv(p["self"], h, cfg.attn, positions, rope=False)
        o = chunked_attention(q, k, v, causal=True)
        pad = ((0, 0), (0, cache_len - S), (0, 0), (0, 0))
        cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
        x = x + contract("bshk,hkd->bsd", o, p["self"]["wo"])
        h = norm_l.norm_apply(cfg.norm, x, p["norm_x"])
        kx, vx = _cross_kv(p["cross"], enc_out, cfg)
        cache["xk"], cache["xv"] = kx, vx
        x = x + _cross_attend(p["cross"], h, kx, vx, cfg)
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        x = x + mlp_l.mlp_apply(p["mlp"], h, cfg.act)
        return constrain(x, "batch"), cache

    x, caches = flags.chunk_scan(body, x, params["dec_blocks"])
    x = norm_l.norm_apply(cfg.norm, x, params["final_norm"])
    lengths = jnp.full((B,), S, jnp.int32)
    return emb_l.head_apply(params["embed"], x), {"dec": caches, "lengths": lengths}


def decode_step(params, cfg: ModelConfig, tokens, caches, *, use_pallas: bool = False):
    lengths = caches["lengths"]
    B = tokens.shape[0]
    x = emb_l.embed_apply(params["embed"], tokens)
    # position = current length (per batch); use mean position embedding via
    # dynamic gather from the sinusoidal table.
    S_max = caches["dec"]["k"].shape[2]
    table = emb_l.sinusoidal_positions(S_max, cfg.d_model).astype(x.dtype)
    x = x + table[lengths][:, None]

    def body(carry, inp):
        x, dec = carry
        p, r = inp
        c = jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, r, 0, keepdims=False), dec
        )
        h = norm_l.norm_apply(cfg.norm, x, p["norm1"])
        y, (k, v) = attn_l.attn_decode(
            p["self"], h, cfg.attn, c["k"], c["v"], lengths, use_pallas=use_pallas
        )
        x = x + y
        h = norm_l.norm_apply(cfg.norm, x, p["norm_x"])
        x = x + _cross_attend(p["cross"], h, c["xk"], c["xv"], cfg)
        h = norm_l.norm_apply(cfg.norm, x, p["norm2"])
        x = x + mlp_l.mlp_apply(p["mlp"], h, cfg.act)
        dec = {
            "k": lax.dynamic_update_index_in_dim(dec["k"], k, r, 0),
            "v": lax.dynamic_update_index_in_dim(dec["v"], v, r, 0),
            "xk": dec["xk"],
            "xv": dec["xv"],
        }
        return (x, dec), None

    (x, new), _ = flags.chunk_scan(
        body, (x, caches["dec"]), (params["dec_blocks"], jnp.arange(cfg.n_layers))
    )
    x = norm_l.norm_apply(cfg.norm, x, params["final_norm"])
    return emb_l.head_apply(params["embed"], x), {"dec": new, "lengths": lengths + 1}


def make_caches(cfg: ModelConfig, B: int, S_max: int, *, abstract: bool = False):
    a = cfg.attn
    L = cfg.n_layers
    dt = jnp.dtype(cfg.dtype)

    def mk(shape, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    dec = {
        "k": mk((L, B, S_max, a.n_kv_heads, a.head_dim), dt),
        "v": mk((L, B, S_max, a.n_kv_heads, a.head_dim), dt),
        "xk": mk((L, B, cfg.enc_seq, a.n_kv_heads, a.head_dim), dt),
        "xv": mk((L, B, cfg.enc_seq, a.n_kv_heads, a.head_dim), dt),
    }
    lengths = mk((B,), jnp.int32)
    return {"dec": dec, "lengths": lengths}
