"""Persisted per-hardware tuned configs: the autotuner's output, keyed by
(device kind, net).

ZNNi's central claim is that the throughput-optimal primitive schedule and
its knobs are a *property of the hardware* — the paper re-derives them per
machine (Table IV/V differ between the 4-way CPU and the Titan X).  This
module is the repo's equivalent of those tables: ``repro.tuning.autotune``
sweeps the executor's tunables on the machine it runs on and persists the
winner here as JSON; planner/executor/``VolumeEngine`` auto-load it so a
fresh process on the same hardware starts from the tuned point instead of
defaults.

Key schema (also docs/architecture.md "Kernels & autotuning"):

* file: ``src/repro/tuning/configs/<device_kind>__<net>.json``
* ``device_kind`` — ``jax.devices()[0].device_kind`` lower-cased with
  spaces/slashes collapsed to ``-`` (e.g. ``cpu``, ``tpu-v5e``,
  ``nvidia-h100-80gb-hbm3``);
* ``net`` — ``ConvNetConfig.name`` (e.g. ``bench-net``, ``n537``).

A config never overrides plan *geometry* when the caller supplies a Plan
(m/batch are part of the planner's costed contract); it fills the
execution-only knobs — ``use_pallas``, ``fuse_pairs``, ``fprime_chunk``,
``fuse_os`` — and supplies m/batch only when the caller left them unset.

Schema v2 (this file): ``fprime_chunk`` may be a per-ABSOLUTE-layer
schedule (a list in JSON, loaded as a tuple; ``None`` entries at pools
and past the end — ``primitives.layer_fprime_chunk`` resolves it per
layer) and ``fuse_os`` selects the halo-emitting fused epilogue in the
volume executor's capture/strip walks.  v1 files (scalar
``fprime_chunk``, no ``fuse_os``) load unchanged; files from FUTURE
schema versions are ignored rather than misread.
"""

from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, Optional, Tuple, Union

import jax

CONFIG_DIR = Path(__file__).parent / "configs"

_SCHEMA_VERSION = 2


@dataclass(frozen=True)
class TunedConfig:
    """One hardware profile's winning knobs for one net.

    ``None`` fields mean "no opinion — keep the caller's value".
    ``xla_flags`` names a bundle in ``repro.tuning.xla_flags`` (applied at
    process start, before jax initializes; it cannot be applied
    retroactively, so loaders only *report* it).
    """

    device_kind: str
    net: str
    m: Optional[int] = None
    batch: Optional[int] = None
    # scalar (every chunked layer) or per-absolute-layer schedule (tuple,
    # None at pools / unchunked layers) — see primitives.layer_fprime_chunk
    fprime_chunk: Union[int, Tuple[Optional[int], ...], None] = None
    use_pallas: Optional[bool] = None
    fuse_pairs: Optional[bool] = None
    fuse_os: Optional[bool] = None  # fused halo-emitting strip epilogue
    seg_core: Optional[int] = None
    xla_flags: Optional[str] = None  # bundle name, see tuning.xla_flags
    source: str = "autotune"  # autotune | manual
    measured_voxps: Optional[float] = None
    tuned_at: Optional[str] = None  # ISO date, stamped by the tuner CLI

    def provenance(self) -> Dict[str, Any]:
        """The compact dict benchmark rows embed as ``tuned_config``."""
        return {
            "device_kind": self.device_kind,
            "net": self.net,
            "fprime_chunk": self.fprime_chunk,
            "use_pallas": self.use_pallas,
            "fuse_pairs": self.fuse_pairs,
            "fuse_os": self.fuse_os,
            "xla_flags": self.xla_flags,
            "source": self.source,
            "tuned_at": self.tuned_at,
        }


def normalize_device_kind(kind: Optional[str] = None) -> str:
    """Canonical hardware-profile key (filesystem-safe, stable across runs)."""
    if kind is None:
        kind = jax.devices()[0].device_kind
    return re.sub(r"[^a-z0-9.-]+", "-", kind.strip().lower()).strip("-")


def config_key(net: str, device_kind: Optional[str] = None) -> str:
    return f"{normalize_device_kind(device_kind)}__{net}"


def config_path(net: str, device_kind: Optional[str] = None,
                root: Optional[Path] = None) -> Path:
    return Path(root or CONFIG_DIR) / f"{config_key(net, device_kind)}.json"


def save_tuned_config(cfg: TunedConfig, *, root: Optional[Path] = None) -> Path:
    path = config_path(cfg.net, cfg.device_kind, root=root)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = {"schema_version": _SCHEMA_VERSION, **dataclasses.asdict(cfg)}
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def load_tuned_config(
    net: str,
    device_kind: Optional[str] = None,
    *,
    root: Optional[Path] = None,
) -> Optional[TunedConfig]:
    """The persisted winner for (this hardware, ``net``), or ``None``.

    Missing file → ``None`` (callers fall back to defaults); a file with a
    future schema version is ignored rather than misread.
    """
    path = config_path(net, device_kind, root=root)
    if not path.exists():
        return None
    payload = json.loads(path.read_text())
    if payload.pop("schema_version", _SCHEMA_VERSION) > _SCHEMA_VERSION:
        return None
    fp = payload.get("fprime_chunk")
    if isinstance(fp, list):  # JSON has no tuples: schedule round-trip
        payload["fprime_chunk"] = tuple(
            None if v is None else int(v) for v in fp
        )
    fields = {f.name for f in dataclasses.fields(TunedConfig)}
    return TunedConfig(**{k: v for k, v in payload.items() if k in fields})
