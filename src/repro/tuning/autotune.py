"""Per-hardware autotuner: sweep executor tunables, persist the winner.

ZNNi derives the optimal schedule per machine by measurement (§VII); this
module is that loop for the repo's runtime.  It sweeps the *execution*
tunables the planner's analytic model does not price —

* fragment size ``m`` and patch batch (together these set the layer-0
  segment-grid size: ``seg_core = m * P`` pins the overlap-save segment
  grid to the patch core, so sweeping ``m`` IS the segment-grid sweep);
* ``fprime_chunk`` — output-channel chunking of the cached-spectra MAD;
* ``fuse_pairs`` — the fused conv+pool strip-path epilogue;
* XLA flag bundles (``repro.tuning.xla_flags``) via subprocess re-exec,
  since ``XLA_FLAGS`` is read once at backend init —

measuring each candidate end-to-end with ``PlanExecutor`` on a small
volume (the ``experiments/hillclimb.py`` harness pattern: warmup sweep,
interleaved repetitions, best-of wall clock), and persists the winning
``TunedConfig`` under ``src/repro/tuning/configs/`` keyed by
(device kind, net) — auto-loaded by ``PlanExecutor``/``VolumeEngine``.

Run:  PYTHONPATH=src python -m repro.tuning.autotune --net bench-net
      [--max-m 2] [--batches 1,2] [--reps 2] [--sweep-xla] [--dry-run]
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, Optional, Sequence, Tuple

from .store import TunedConfig, normalize_device_kind, save_tuned_config
from .xla_flags import bundles_for, xla_flags_env


def _measure_candidate(
    params, net, plan, vol, *, fuse_pairs, fprime_chunk, reps: int
) -> Optional[float]:
    """Best-of-``reps`` measured vox/s for one candidate, None if it fails."""
    from ..volume import PlanExecutor

    try:
        ex = PlanExecutor(
            params, net, plan, tuned=None,
            fuse_pairs=fuse_pairs, fprime_chunk=fprime_chunk,
        )
        ex.run(vol)  # warmup: compiles + first sweep
        best = 0.0
        for _ in range(max(1, reps)):
            ex.run(vol)
            best = max(best, ex.last_stats["measured_voxps"])
        return best
    except Exception as e:  # infeasible geometry, OOM — skip the point
        print(f"    candidate failed: {type(e).__name__}: {e}")
        return None


def _os_prims(net) -> list:
    """The deployed primitive mix: overlap_save at the input conv (the one
    layer with cross-patch input identity), fft_cached deeper, MPF pools."""
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    return [
        "overlap_save" if i == first_conv
        else ("fft_cached" if l.kind == "conv" else "mpf")
        for i, l in enumerate(net.layers)
    ]


def autotune_net(
    net_name: str,
    *,
    max_m: int = 2,
    batches: Sequence[int] = (1, 2),
    fprime_chunks: Sequence[Optional[int]] = (None, 4),
    fuse_options: Sequence[bool] = (False, True),
    reps: int = 2,
    seed: int = 0,
    xla_bundle: Optional[str] = None,
) -> Tuple[TunedConfig, Dict[str, float]]:
    """Sweep the candidate grid for one net on this process's hardware.

    Returns the winning ``TunedConfig`` (not yet persisted) and the full
    ``candidate-key -> vox/s`` measurement map.
    """
    import jax
    import numpy as np

    from ..configs.znni_nets import net_by_name
    from ..core import convnet, planner
    from ..core.hw import TPU_V5E
    from ..kernels import backend_supports_pallas

    net = net_by_name(net_name)
    params = convnet.init_params(jax.random.PRNGKey(seed), net)
    use_pallas = backend_supports_pallas()
    prims = _os_prims(net)
    rng = np.random.default_rng(seed)

    results: Dict[str, float] = {}
    winner: Optional[TunedConfig] = None
    best_voxps = 0.0
    for m, batch in itertools.product(range(1, max_m + 1), batches):
        plan = planner.plan_fixed(
            net, TPU_V5E, prims, m=m, batch=batch, strategy_name="autotune"
        )
        if plan is None:
            continue
        # a CI-sized sweep volume: >1 patch per axis with interior x-rows
        # (the regime the strip path and sweep caches live in)
        shape = (
            3 * plan.core + plan.fov - 1 + 1,
            2 * plan.core + plan.fov - 1,
            2 * plan.core + plan.fov - 1,
        )
        vol = rng.normal(size=(net.in_channels,) + shape).astype(np.float32)
        for fp_chunk, fuse in itertools.product(fprime_chunks, fuse_options):
            key = f"m={m} batch={batch} fprime_chunk={fp_chunk} fuse={fuse}"
            voxps = _measure_candidate(
                params, net, plan, vol,
                fuse_pairs=fuse, fprime_chunk=fp_chunk, reps=reps,
            )
            if voxps is None:
                continue
            results[key] = voxps
            print(f"  {key:<44s} {voxps:>12,.0f} vox/s")
            if voxps > best_voxps:
                best_voxps = voxps
                winner = TunedConfig(
                    device_kind=normalize_device_kind(),
                    net=net.name,
                    m=m, batch=batch,
                    fprime_chunk=fp_chunk,
                    use_pallas=use_pallas,
                    fuse_pairs=fuse,
                    seg_core=plan.core,
                    xla_flags=xla_bundle,
                    source="autotune",
                    measured_voxps=best_voxps,
                    tuned_at=time.strftime("%Y-%m-%d"),
                )
    if winner is None:
        raise RuntimeError(f"no feasible autotune candidate for {net_name}")
    return winner, results


def _sweep_xla_bundles(args) -> TunedConfig:
    """Re-exec one child per applicable flag bundle; return the best child's
    winner stamped with its bundle name (XLA_FLAGS is init-time-only)."""
    import jax  # noqa: F401  (device kind for bundle filtering)

    kind = normalize_device_kind()
    best: Optional[TunedConfig] = None
    for bundle in bundles_for(kind):
        out = Path(f".autotune_{bundle}.json")
        env = dict(os.environ)
        env["XLA_FLAGS"] = xla_flags_env(bundle, base=os.environ.get("XLA_FLAGS"))
        cmd = [
            sys.executable, "-m", "repro.tuning.autotune",
            "--net", args.net, "--max-m", str(args.max_m),
            "--batches", ",".join(map(str, args.batches)),
            "--reps", str(args.reps), "--xla-bundle", bundle,
            "--dry-run", "--candidate-out", str(out),
        ]
        print(f"-- bundle {bundle}: {env['XLA_FLAGS'] or '(empty)'}")
        subprocess.run(cmd, env=env, check=True)
        payload = json.loads(out.read_text())
        out.unlink()
        cfg = TunedConfig(**payload["winner"])
        if best is None or (cfg.measured_voxps or 0) > (best.measured_voxps or 0):
            best = cfg
    assert best is not None
    return best


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--net", default="bench-net")
    ap.add_argument("--max-m", type=int, default=2)
    ap.add_argument("--batches", type=lambda s: [int(x) for x in s.split(",")],
                    default=[1, 2])
    ap.add_argument("--fprime-chunks", type=lambda s: [
        None if x == "none" else int(x) for x in s.split(",")
    ], default=[None, 4])
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--xla-bundle", default=None,
                    help="record this bundle name in the config (the flags "
                         "must already be in XLA_FLAGS — init-time-only)")
    ap.add_argument("--sweep-xla", action="store_true",
                    help="re-exec one child per applicable XLA flag bundle "
                         "and keep the best")
    ap.add_argument("--dry-run", action="store_true",
                    help="measure but do not persist the config")
    ap.add_argument("--candidate-out", default=None,
                    help="also write winner + all measurements to this JSON")
    args = ap.parse_args(argv)

    if args.sweep_xla:
        winner = _sweep_xla_bundles(args)
        results: Dict[str, float] = {}
    else:
        winner, results = autotune_net(
            args.net, max_m=args.max_m, batches=args.batches,
            fprime_chunks=args.fprime_chunks, reps=args.reps,
            seed=args.seed, xla_bundle=args.xla_bundle,
        )
    print(f"winner: {winner}")
    if args.candidate_out:
        Path(args.candidate_out).write_text(json.dumps({
            "winner": dataclasses.asdict(winner), "results": results,
        }, indent=2, sort_keys=True))
    if not args.dry_run:
        path = save_tuned_config(winner)
        print(f"persisted {path}")


if __name__ == "__main__":
    main()
