"""Per-hardware autotuner: sweep executor tunables, persist the winner.

ZNNi derives the optimal schedule per machine by measurement (§VII); this
module is that loop for the repo's runtime.  It sweeps the *execution*
tunables the planner's analytic model does not price —

* fragment size ``m`` and patch batch (together these set the layer-0
  segment-grid size: ``seg_core = m * P`` pins the overlap-save segment
  grid to the patch core, so sweeping ``m`` IS the segment-grid sweep);
* ``fprime_chunk`` — output-channel chunking of the cached-spectra MAD;
  a scalar, or a per-conv-layer schedule (``a:b:c`` on the CLI, expanded
  to an absolute-layer tuple with ``None`` at pools — schema v2);
* ``fuse_pairs`` — the fused conv+pool epilogue in the plain walks;
* ``fuse_os`` — the halo-emitting fused epilogue in the volume executor's
  capture/strip walks (swept only on top of ``fuse_pairs``);
* XLA flag bundles (``repro.tuning.xla_flags``) via subprocess re-exec,
  since ``XLA_FLAGS`` is read once at backend init —

measuring each candidate end-to-end with ``PlanExecutor`` on a small
volume (the ``experiments/hillclimb.py`` harness pattern: warmup sweep,
interleaved repetitions, best-of wall clock), and persists the winning
``TunedConfig`` under ``src/repro/tuning/configs/`` keyed by
(device kind, net) — auto-loaded by ``PlanExecutor``/``VolumeEngine``.

Cost-model pruning (``--shortlist K``): before measuring, every
candidate's (m, batch) geometry is priced by ``planner.plan_fixed``'s
analytic model over the sweep volume, and only the predicted Pareto
frontier over (throughput up, peak device bytes down) — filled to K by
predicted throughput — is measured.  Knobs the model does not price
(fprime_chunk / fuse flags) share their geometry's score, so the
shortlist keeps every knob variant of a surviving geometry until the K
cut.  ``--quick`` shrinks the sweep volume and drops to one repetition
(CI smoke).

Run:  PYTHONPATH=src python -m repro.tuning.autotune --net bench-net
      [--max-m 2] [--batches 1,2] [--shortlist 8] [--quick]
      [--reps 2] [--sweep-xla] [--dry-run]
"""

from __future__ import annotations

import argparse
import dataclasses
import itertools
import json
import os
import subprocess
import sys
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .store import TunedConfig, normalize_device_kind, save_tuned_config
from .xla_flags import bundles_for, xla_flags_env

FprimeSpec = Union[int, Tuple[Optional[int], ...], None]


@dataclass(frozen=True)
class Candidate:
    """One point of the tuner's knob grid (geometry + execution knobs)."""

    m: int
    batch: int
    fprime_chunk: FprimeSpec
    fuse_pairs: bool
    fuse_os: bool

    @property
    def key(self) -> str:
        fp = self.fprime_chunk
        if isinstance(fp, tuple):
            fp = ":".join("none" if v is None else str(v) for v in fp)
        return (
            f"m={self.m} batch={self.batch} fprime_chunk={fp} "
            f"fuse={self.fuse_pairs} fuse_os={self.fuse_os}"
        )


def build_candidate_grid(
    max_m: int,
    batches: Sequence[int],
    fprime_chunks: Sequence[FprimeSpec],
    fuse_options: Sequence[bool],
    fuse_os_options: Sequence[bool] = (False,),
) -> List[Candidate]:
    """The full knob product the tuner would measure without pruning.

    ``fuse_os`` is swept only on top of ``fuse_pairs`` — it is the same
    fused-epilogue family extended into the strip walks, and gating it
    halves the grid without losing the interesting points.
    """
    grid: List[Candidate] = []
    for m, batch in itertools.product(range(1, max_m + 1), batches):
        for fp, fuse in itertools.product(fprime_chunks, fuse_options):
            for fos in fuse_os_options:
                if fos and not fuse:
                    continue
                grid.append(Candidate(m, batch, fp, fuse, fos))
    return grid


def _sweep_shape(net, m: int, *, quick: bool) -> Tuple[int, int, int]:
    """The measurement volume for fragment size ``m``: >1 patch per axis
    with interior x-rows (the regime the strip path and sweep caches live
    in); ``--quick`` drops to the minimal interior-bearing volume."""
    core = m * net.total_pooling()
    fov = net.field_of_view()
    if quick:
        return (2 * core + fov - 1, core + fov - 1, core + fov - 1)
    return (3 * core + fov, 2 * core + fov - 1, 2 * core + fov - 1)


def expand_fprime_schedule(net, sched: FprimeSpec) -> FprimeSpec:
    """Per-CONV-layer schedule -> per-ABSOLUTE-layer tuple (schema v2).

    Scalars and ``None`` pass through; a tuple/list is read as one entry
    per conv layer in network order and expanded with ``None`` at pools
    (and past the end), the layout ``primitives.layer_fprime_chunk``
    resolves at prepare time.
    """
    if sched is None or isinstance(sched, int):
        return sched
    vals = list(sched)
    out: List[Optional[int]] = []
    j = 0
    for layer in net.layers:
        if layer.kind == "conv":
            out.append(vals[j] if j < len(vals) else None)
            j += 1
        else:
            out.append(None)
    return tuple(out)


def shortlist_candidates(
    net,
    prims: Sequence[str],
    grid: Sequence[Candidate],
    k: int,
    *,
    quick: bool = False,
) -> Tuple[List[Candidate], Dict[Tuple[int, int], object]]:
    """Analytic pre-pruning: keep only the predicted-Pareto shortlist.

    Each distinct (m, batch) geometry is priced once with
    ``planner.plan_fixed`` over the sweep volume (exact cache-simulated
    amortization).  Geometries on the Pareto frontier of (predicted
    throughput up, predicted peak device bytes down) rank first, the rest
    by predicted throughput; candidates inherit their geometry's rank and
    the first ``k`` survive.  Returns ``(shortlist, plans)`` with the
    priced Plans keyed by geometry so the measurement loop reuses them.
    """
    from ..core import planner
    from ..core.hw import TPU_V5E

    scores: Dict[Tuple[int, int], Tuple[float, float]] = {}
    plans: Dict[Tuple[int, int], object] = {}
    for cand in grid:
        geo = (cand.m, cand.batch)
        if geo in plans:
            continue
        plan = planner.plan_fixed(
            net, TPU_V5E, prims, m=cand.m, batch=cand.batch,
            strategy_name="autotune",
            volume_shape=_sweep_shape(net, cand.m, quick=quick),
        )
        plans[geo] = plan
        if plan is not None:
            mem = plan.memory.device_bytes if plan.memory else plan.peak_bytes
            scores[geo] = (plan.throughput, float(mem))
    frontier = {
        geo for geo, (thr, mem) in scores.items()
        if not any(
            (t2 >= thr and m2 <= mem and (t2 > thr or m2 < mem))
            for t2, m2 in scores.values()
        )
    }
    ranked = sorted(
        (c for c in grid if (c.m, c.batch) in scores),
        key=lambda c: (
            (c.m, c.batch) not in frontier,  # frontier geometries first
            -scores[(c.m, c.batch)][0],
        ),
    )
    return ranked[: max(1, k)], plans


def _measure_candidate(
    params, net, plan, vol, *, fuse_pairs, fprime_chunk, fuse_os, reps: int
) -> Optional[float]:
    """Best-of-``reps`` measured vox/s for one candidate, None if it fails."""
    from ..volume import PlanExecutor

    try:
        ex = PlanExecutor(
            params, net, plan, tuned=None,
            fuse_pairs=fuse_pairs, fprime_chunk=fprime_chunk, fuse_os=fuse_os,
        )
        ex.run(vol)  # warmup: compiles + first sweep
        best = 0.0
        for _ in range(max(1, reps)):
            ex.run(vol)
            best = max(best, ex.last_stats["measured_voxps"])
        return best
    except Exception as e:  # infeasible geometry, OOM — skip the point
        print(f"    candidate failed: {type(e).__name__}: {e}")
        return None


def _os_prims(net) -> list:
    """The deployed primitive mix: overlap_save at the input conv (the one
    layer with cross-patch input identity), fft_cached deeper, MPF pools."""
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    return [
        "overlap_save" if i == first_conv
        else ("fft_cached" if l.kind == "conv" else "mpf")
        for i, l in enumerate(net.layers)
    ]


def autotune_net(
    net_name: str,
    *,
    max_m: int = 2,
    batches: Sequence[int] = (1, 2),
    fprime_chunks: Sequence[FprimeSpec] = (None, 4),
    fuse_options: Sequence[bool] = (False, True),
    fuse_os_options: Sequence[bool] = (False, True),
    reps: int = 2,
    seed: int = 0,
    xla_bundle: Optional[str] = None,
    shortlist: Optional[int] = None,
    quick: bool = False,
) -> Tuple[TunedConfig, Dict[str, float], Dict[str, List[str]]]:
    """Sweep (or shortlist-then-sweep) the candidate grid for one net.

    Returns the winning ``TunedConfig`` (not yet persisted), the
    ``candidate-key -> vox/s`` measurement map, and a meta dict with the
    full ``grid`` and measured ``shortlist`` key lists (the CI smoke job
    asserts shortlist ⊆ grid).
    """
    import jax
    import numpy as np

    from ..configs.znni_nets import net_by_name
    from ..core import planner
    from ..core import convnet
    from ..core.hw import TPU_V5E
    from ..kernels import backend_supports_pallas

    net = net_by_name(net_name)
    params = convnet.init_params(jax.random.PRNGKey(seed), net)
    use_pallas = backend_supports_pallas()
    prims = _os_prims(net)
    rng = np.random.default_rng(seed)
    if quick:
        reps = 1

    grid = build_candidate_grid(
        max_m, batches,
        [expand_fprime_schedule(net, fp) for fp in fprime_chunks],
        fuse_options, fuse_os_options,
    )
    plans: Dict[Tuple[int, int], object] = {}
    if shortlist is not None:
        cands, plans = shortlist_candidates(
            net, prims, grid, shortlist, quick=quick
        )
        print(f"shortlist: measuring {len(cands)}/{len(grid)} candidates")
    else:
        cands = list(grid)

    results: Dict[str, float] = {}
    winner: Optional[TunedConfig] = None
    best_voxps = 0.0
    for cand in cands:
        geo = (cand.m, cand.batch)
        if geo not in plans:
            plans[geo] = planner.plan_fixed(
                net, TPU_V5E, prims, m=cand.m, batch=cand.batch,
                strategy_name="autotune",
                volume_shape=_sweep_shape(net, cand.m, quick=quick),
            )
        plan = plans[geo]
        if plan is None:
            continue
        shape = _sweep_shape(net, cand.m, quick=quick)
        vol = rng.normal(size=(net.in_channels,) + shape).astype(np.float32)
        voxps = _measure_candidate(
            params, net, plan, vol,
            fuse_pairs=cand.fuse_pairs, fprime_chunk=cand.fprime_chunk,
            fuse_os=cand.fuse_os, reps=reps,
        )
        if voxps is None:
            continue
        results[cand.key] = voxps
        print(f"  {cand.key:<58s} {voxps:>12,.0f} vox/s")
        if voxps > best_voxps:
            best_voxps = voxps
            winner = TunedConfig(
                device_kind=normalize_device_kind(),
                net=net.name,
                m=cand.m, batch=cand.batch,
                fprime_chunk=cand.fprime_chunk,
                use_pallas=use_pallas,
                fuse_pairs=cand.fuse_pairs,
                fuse_os=cand.fuse_os,
                seg_core=plan.core,
                xla_flags=xla_bundle,
                source="autotune",
                measured_voxps=best_voxps,
                tuned_at=time.strftime("%Y-%m-%d"),
            )
    if winner is None:
        raise RuntimeError(f"no feasible autotune candidate for {net_name}")
    meta = {
        "grid": [c.key for c in grid],
        "shortlist": [c.key for c in cands],
    }
    return winner, results, meta


def _sweep_xla_bundles(args) -> TunedConfig:
    """Re-exec one child per applicable flag bundle; return the best child's
    winner stamped with its bundle name (XLA_FLAGS is init-time-only)."""
    import jax  # noqa: F401  (device kind for bundle filtering)

    kind = normalize_device_kind()
    best: Optional[TunedConfig] = None
    for bundle in bundles_for(kind):
        out = Path(f".autotune_{bundle}.json")
        env = dict(os.environ)
        env["XLA_FLAGS"] = xla_flags_env(bundle, base=os.environ.get("XLA_FLAGS"))
        cmd = [
            sys.executable, "-m", "repro.tuning.autotune",
            "--net", args.net, "--max-m", str(args.max_m),
            "--batches", ",".join(map(str, args.batches)),
            "--reps", str(args.reps), "--xla-bundle", bundle,
            "--dry-run", "--candidate-out", str(out),
        ]
        if args.shortlist is not None:
            cmd += ["--shortlist", str(args.shortlist)]
        if args.quick:
            cmd += ["--quick"]
        print(f"-- bundle {bundle}: {env['XLA_FLAGS'] or '(empty)'}")
        subprocess.run(cmd, env=env, check=True)
        payload = json.loads(out.read_text())
        out.unlink()
        w = payload["winner"]
        if isinstance(w.get("fprime_chunk"), list):
            w["fprime_chunk"] = tuple(w["fprime_chunk"])
        cfg = TunedConfig(**w)
        if best is None or (cfg.measured_voxps or 0) > (best.measured_voxps or 0):
            best = cfg
    assert best is not None
    return best


def _parse_fprime(s: str) -> List[FprimeSpec]:
    """CLI grammar: comma-separated specs; each spec is ``none``, an int,
    or a colon-joined per-conv-layer schedule (``4:none:2``)."""
    specs: List[FprimeSpec] = []
    for item in s.split(","):
        if ":" in item:
            specs.append(tuple(
                None if x == "none" else int(x) for x in item.split(":")
            ))
        else:
            specs.append(None if item == "none" else int(item))
    return specs


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--net", default="bench-net")
    ap.add_argument("--max-m", type=int, default=2)
    ap.add_argument("--batches", type=lambda s: [int(x) for x in s.split(",")],
                    default=[1, 2])
    ap.add_argument("--fprime-chunks", type=_parse_fprime, default=[None, 4],
                    help="comma-separated: none, an int, or a per-conv-layer "
                         "schedule like 4:none:2")
    ap.add_argument("--no-fuse-os", action="store_true",
                    help="drop the fuse_os axis from the grid")
    ap.add_argument("--shortlist", type=int, default=None,
                    help="measure only the top-K cost-model-predicted "
                         "Pareto candidates instead of the full grid")
    ap.add_argument("--quick", action="store_true",
                    help="minimal sweep volume + one repetition (CI smoke)")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--xla-bundle", default=None,
                    help="record this bundle name in the config (the flags "
                         "must already be in XLA_FLAGS — init-time-only)")
    ap.add_argument("--sweep-xla", action="store_true",
                    help="re-exec one child per applicable XLA flag bundle "
                         "and keep the best")
    ap.add_argument("--dry-run", action="store_true",
                    help="measure but do not persist the config")
    ap.add_argument("--candidate-out", default=None,
                    help="also write winner + measurements + grid/shortlist "
                         "key lists to this JSON")
    args = ap.parse_args(argv)

    if args.sweep_xla:
        winner = _sweep_xla_bundles(args)
        results: Dict[str, float] = {}
        meta: Dict[str, List[str]] = {}
    else:
        winner, results, meta = autotune_net(
            args.net, max_m=args.max_m, batches=args.batches,
            fprime_chunks=args.fprime_chunks,
            fuse_os_options=(False,) if args.no_fuse_os else (False, True),
            reps=args.reps, seed=args.seed, xla_bundle=args.xla_bundle,
            shortlist=args.shortlist, quick=args.quick,
        )
    print(f"winner: {winner}")
    if args.candidate_out:
        Path(args.candidate_out).write_text(json.dumps({
            "winner": dataclasses.asdict(winner), "results": results, **meta,
        }, indent=2, sort_keys=True))
    if not args.dry_run:
        path = save_tuned_config(winner)
        print(f"persisted {path}")


if __name__ == "__main__":
    main()
