"""Named XLA-flag bundles per hardware family, applied before jax init.

The autotuner sweeps *bundles* rather than individual flags: a bundle is a
coherent set known to move 3D-FFT-conv workloads on one hardware family,
and the winning bundle's NAME is persisted in the tuned config (the flags
themselves stay here so a stale config can't pin removed flags forever).

XLA reads ``XLA_FLAGS`` once at backend initialization, so bundles must be
exported before the first jax call — the tuner CLI re-execs itself with the
environment set (the ``experiments/hillclimb.py`` pattern); in-process
callers can only *verify* what is already applied.
"""

from __future__ import annotations

import os
from typing import Dict, Optional, Tuple

# bundle name -> (family, flags).  ``family`` is a prefix-match against the
# normalized device kind ("" matches everything).
XLA_FLAG_BUNDLES: Dict[str, Tuple[str, Tuple[str, ...]]] = {
    "none": ("", ()),
    # CPU: the container's default thread pool already matches cores; turn
    # on the multi-threaded Eigen contraction path explicitly.
    "cpu-multithread": (
        "cpu",
        ("--xla_cpu_multi_thread_eigen=true",),
    ),
    # TPU: latency-hiding scheduler + async collectives help the pipelined
    # two-stage sweeps; SPMD fusion limits tuned for large fused MADs.
    "tpu-latency-hiding": (
        "tpu",
        (
            "--xla_tpu_enable_latency_hiding_scheduler=true",
            "--xla_enable_async_all_gather=true",
            "--xla_enable_async_collective_permute=true",
        ),
    ),
    # GPU: overlap compute with NCCL-style collectives; keep autotuning on.
    "gpu-overlap": (
        "gpu",
        (
            "--xla_gpu_enable_latency_hiding_scheduler=true",
            "--xla_gpu_enable_highest_priority_async_stream=true",
        ),
    ),
}


def bundles_for(device_kind: str) -> Tuple[str, ...]:
    """Bundle names applicable to a normalized device kind (always incl. none)."""
    kind = device_kind.lower()
    names = []
    for name, (family, _flags) in XLA_FLAG_BUNDLES.items():
        if not family or kind.startswith(family) or family in kind:
            names.append(name)
    return tuple(names)


def bundle_flags(name: str) -> Tuple[str, ...]:
    try:
        return XLA_FLAG_BUNDLES[name][1]
    except KeyError:
        raise ValueError(
            f"unknown XLA flag bundle {name!r}; known: {sorted(XLA_FLAG_BUNDLES)}"
        ) from None


def xla_flags_env(name: str, base: Optional[str] = None) -> str:
    """The ``XLA_FLAGS`` value for a bundle, appended to ``base`` (or the
    current environment's value)."""
    if base is None:
        base = os.environ.get("XLA_FLAGS", "")
    parts = [base.strip()] if base and base.strip() else []
    parts.extend(bundle_flags(name))
    return " ".join(parts)


def apply_bundle(name: str) -> None:
    """Export a bundle into ``os.environ`` — MUST run before jax init."""
    os.environ["XLA_FLAGS"] = xla_flags_env(name)
