"""Per-hardware autotuning: sweep executor tunables, persist the winner.

* ``store``     — tuned-config JSON schema + load/save keyed by
  (device kind, net); ``load_tuned_config`` is what the executor and
  ``VolumeEngine`` call at construction.
* ``xla_flags`` — named XLA-flag bundles per hardware family (swept by the
  tuner, applied before jax init).
* ``autotune``  — the sweep itself (CLI: ``python -m repro.tuning.autotune``).
  Imported lazily — it pulls in the volume executor, which itself loads
  tuned configs from ``store``.
"""

from .store import (  # noqa: F401
    TunedConfig,
    config_key,
    config_path,
    load_tuned_config,
    normalize_device_kind,
    save_tuned_config,
)
from .xla_flags import (  # noqa: F401
    XLA_FLAG_BUNDLES,
    apply_bundle,
    bundle_flags,
    bundles_for,
    xla_flags_env,
)
