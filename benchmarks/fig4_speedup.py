"""Fig. 4: theoretical speedup of MPF nets vs input size & batch size —
reproduces the paper's finding that S=1 wins for >=2-pool networks while
larger batches can win with a single pool layer."""

from __future__ import annotations

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import planner
from repro.core.hw import TPU_V5E

from .common import emit

ONE_POOL = ConvNetConfig(
    "one-pool", 1,
    (L("conv", 5, 80), L("pool", 2), L("conv", 5, 80), L("conv", 5, 80)),
)
TWO_POOL = ConvNetConfig(
    "two-pool", 1,
    (L("conv", 5, 80), L("pool", 2), L("conv", 5, 80), L("pool", 2), L("conv", 5, 80)),
)


def main() -> None:
    for net in (ONE_POOL, TWO_POOL):
        rows = []
        for S in (1, 2, 4, 8):
            p = planner.plan_single(net, TPU_V5E, batches=(S,))
            rows.append((S, p.throughput if p else 0.0, p.peak_bytes if p else 0))
        best = max(rows, key=lambda r: r[1])[0]
        detail = ";".join(f"S{S}={t:.3e}@{b / 2**30:.2f}GiB" for S, t, b in rows)
        emit(f"fig4.{net.name}", 0.0, f"best_S={best};{detail}")


if __name__ == "__main__":
    main()
