"""Fig. 5: throughput vs input image size (single-worker search)."""

from __future__ import annotations

from repro.configs import ZNNI_NETS
from repro.core import planner
from repro.core.hw import TPU_V5E

from .common import emit


def main() -> None:
    for name, net in ZNNI_NETS.items():
        pts = []
        for m in (1, 2, 4, 8, 16, 24, 32):
            best = None
            p = planner.plan_single(net, TPU_V5E, batches=(1,), max_m=m)
            if p:
                pts.append(f"n{p.n_in}={p.throughput:.3e}")
        emit(f"fig5.{name}", 0.0, ";".join(pts))


if __name__ == "__main__":
    main()
