"""Fig. 2 / §III: pruned vs naive FFT of zero-padded kernels.

Measured on CPU (real executions) + the analytic FLOP ratio.  The paper
reports ~5x (CPU) / ~10x (GPU) average speedup for kernel transforms."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruned_fft as pf

from .common import emit, time_call


def main() -> None:
    rng = np.random.default_rng(0)
    fft_shape = (64, 64, 64)
    for k in (2, 3, 5, 7, 9):
        x = jnp.asarray(rng.normal(size=(8, k, k, k)).astype(np.float32))
        pruned = jax.jit(lambda a: pf.pruned_rfftn(a, fft_shape))
        naive = jax.jit(lambda a: pf.naive_rfftn(a, fft_shape))
        t_p = time_call(pruned, x)
        t_n = time_call(naive, x)
        analytic = pf.pruned_speedup((k, k, k), fft_shape)
        emit(
            f"fig2.pruned_fft.k{k}", t_p,
            f"naive_us={t_n:.1f};measured_speedup={t_n / t_p:.2f};analytic={analytic:.2f}",
        )


if __name__ == "__main__":
    main()
