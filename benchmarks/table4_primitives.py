"""Table IV: optimal per-layer primitive choice + optimal input size, per
benchmark net — the planner's answer on TPU v5e (the paper's Table IV is
the same search on a Titan X)."""

from __future__ import annotations

from repro.configs import ZNNI_NETS
from repro.core import planner
from repro.core.hw import TPU_V5E

from .common import emit


def main() -> None:
    for name, net in ZNNI_NETS.items():
        p = planner.plan_single(net, TPU_V5E)
        prims = "|".join(c.prim for c in p.choices)
        emit(
            f"table4.{name}", 0.0,
            f"n_in={p.n_in};S={p.batch};layers={prims}",
        )


if __name__ == "__main__":
    main()
