"""Benchmark aggregator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline terms for the full
(arch x shape x mesh) grid come from the dry-run artifacts
(experiments/dryrun/*.json) and are summarized by `dryrun_summary`.
"""

from __future__ import annotations

import glob
import json
import os

from . import (
    fig2_pruned_fft,
    fig4_speedup,
    fig5_throughput,
    fig7_memory,
    table1_complexity,
    table2_memory,
    table4_primitives,
    table5_throughput,
    volume_throughput,
)
from .common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def dryrun_summary() -> None:
    """Roofline terms per dry-run cell (the §Roofline table source)."""
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "baseline__*.json")))
    if not files:
        emit("dryrun.summary", 0.0, "no dry-run artifacts; run repro.launch.dryrun")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        cell = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if "skipped" in rec:
            emit(f"dryrun.{cell}", 0.0, "skipped")
            continue
        if "error" in rec:
            emit(f"dryrun.{cell}", 0.0, f"ERROR={rec['error'][:80]}")
            continue
        r = rec["roofline"]
        emit(
            f"dryrun.{cell}", 0.0,
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f}",
        )


def main() -> None:
    for mod in (
        fig2_pruned_fft,
        table1_complexity,
        table2_memory,
        table4_primitives,
        table5_throughput,
        fig4_speedup,
        fig5_throughput,
        fig7_memory,
    ):
        mod.main()
    volume_throughput.main([])  # explicit argv: don't re-parse run.py's
    dryrun_summary()


if __name__ == "__main__":
    main()
