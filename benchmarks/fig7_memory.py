"""Fig. 7: throughput vs memory consumed, for the four strategies —
single-chip ("GPU-only"), streamed ("GPU + host RAM"), pipeline2
("CPU-GPU"), spatial (beyond-paper halo sharding)."""

from __future__ import annotations

from repro.configs import ZNNI_NETS
from repro.core import planner
from repro.core.hw import TPU_V5E

from .common import emit


def main() -> None:
    for name, net in ZNNI_NETS.items():
        plans = planner.plan_all_strategies(net, TPU_V5E, chips=256)
        parts = []
        for strat in ("single", "streamed", "pipeline2", "spatial"):
            p = plans[strat]
            if p:
                parts.append(f"{strat}:mem={p.peak_bytes / 2**30:.2f}GiB,thr={p.throughput:.3e}")
        emit(f"fig7.{name}", 0.0, ";".join(parts))


if __name__ == "__main__":
    main()
