"""Table I: layer computational complexity — measured time of each
primitive on a small layer vs the analytic FLOP model (the constant-free
ratios are what the paper's table encodes)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, direct_conv, fft_conv, mpf

from .common import emit, time_call


def main() -> None:
    rng = np.random.default_rng(0)
    S, f, fp, n, k = 1, 8, 8, 24, 5
    x = jnp.asarray(rng.normal(size=(S, f, n, n, n)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(fp, f, k, k, k)).astype(np.float32))

    prims = {
        "direct": lambda: direct_conv.direct_conv(x, w),
        "fft_data": lambda: fft_conv.fft_conv_data_parallel(x, w),
        "fft_task": lambda: fft_conv.fft_conv_task_parallel(x, w),
    }
    for name, fn in prims.items():
        t = time_call(fn)
        flops = cost_model.conv_cost(name, S, f, fp, (n, n, n), k).flops
        emit(f"table1.conv.{name}", t, f"analytic_flops={flops:.3e}")

    xp = jnp.asarray(rng.normal(size=(S, f, 23, 23, 23)).astype(np.float32))
    t = time_call(lambda: mpf.mpf(xp, 2))
    emit("table1.mpf.p2", t, f"analytic_flops={cost_model.mpf_cost(S, f, (23,)*3, 2).flops:.3e}")
    xq = jnp.asarray(rng.normal(size=(S, f, 24, 24, 24)).astype(np.float32))
    t = time_call(lambda: mpf.max_pool3d(xq, 2))
    emit("table1.pool.p2", t, f"analytic_flops={cost_model.pool_cost(S, f, (24,)*3, 2).flops:.3e}")


if __name__ == "__main__":
    main()
