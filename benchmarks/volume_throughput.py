"""End-to-end volume inference throughput: measured vs. planner-predicted.

Sweeps a volume strictly larger than one patch (with a non-aligned edge)
through the PlanExecutor for each strategy the planner can realize on one
host, and reports end-to-end vox/s — border waste included, i.e. dense
output voxels divided by total wall time, the paper's §VII metric.

The prediction column is the planner's analytic throughput for the target
hardware model (TPU v5e by default); on the CPU container the absolute
numbers differ but the MPF-vs-naive ordering and the waste fractions are
the reproducible part.  The ``fft_cached`` row exercises the CompiledPlan
path: kernel spectra are transformed once at plan-compile time and reused
across every patch (ISSUE 2).  The ``overlap_save`` row additionally
reuses *input* segment spectra across x-adjacent patches (ISSUE 3), and
the ``overlap_save+deep`` row extends the reuse below layer 0 (ISSUE 4):
interior patches run the strip path — tail-segment MAD at layer 0,
activation-halo assembly deeper — and the row prints the planner's
predicted sweep counters next to the measured ones (they must agree
exactly; ``tests/test_sweep_accounting.py`` pins it).

Run:  PYTHONPATH=src python benchmarks/volume_throughput.py [--m 2]
      [--quick] [--json out.json] [--ram-budget BYTES]

``--json`` writes per-row vox/s + predicted vox/s + reuse counters +
memory counters (``peak_device_bytes`` measured by the executor's ledger,
``predicted_memory`` from ``Plan.memory``) so the perf trajectory can be
tracked across PRs (CI uploads it as an artifact); ``--quick`` shrinks
the geometry and repetitions for a CI-sized run.

``--ram-budget`` (ISSUE 5) solves the overlap-save rows under the paper's
RAM constraint: their plans carry the budget, the executor runs them
host-staged (the volume never becomes device-resident in full), and the
report pins measured peak device bytes against the predicted footprint.
It also emits a planner-side **budget sweep** — throughput vs. RAM, the
paper's Fig. 5 analog — showing where a faster primitive's patch stops
fitting and a slower-but-leaner one takes over.

The ``fused_os`` row (ISSUE 9) runs the deep plan with the halo-emitting
fused strip-path epilogue (``fuse_os=True``): eligible conv+pool pairs of
the capture/strip walks collapse to one fused call each.  Its JSON row
carries ``bitwise_equal_unfused`` (the identically-knobbed unfused walk
must produce a bit-identical dense output) and the fused counters next to
their exact sweep predictions — ``scripts/check_bench_json.py`` gates
both, plus a throughput trend gate against the previous committed
``BENCH_*.json``.

The ``hetero`` row (ISSUE 6) plans over the paper's CPU+GPU device set
(``hw.PAPER_MACHINES``) and executes the split as a two-backend pipeline
(host CPU backend + default accelerator, host-RAM hand-off at θ); its
JSON row carries the measured per-stage / hand-off counters next to the
plan's predictions — the hand-off *bytes* must match exactly
(``scripts/check_bench_json.py`` enforces it).
"""

import argparse
import json
import math
import time

import jax
import numpy as np

from repro.configs.znni_nets import BENCH_NET, ZNNI_NETS, net_by_name
from repro.core import convnet, planner
from repro.core.hw import PAPER_MACHINES, TPU_V5E
from repro.volume import PlanExecutor

# default net: 8 input channels so layer-0 input transforms carry real
# work (single-channel input makes the amortized-FFT terms measurement
# noise).  ``--net n337|n537|n726|n926`` swaps in a paper Table III net.
NET = BENCH_NET

REUSE_KEYS = (
    "os_seg_fft", "os_seg_hits", "os_mad_segments",
    "deep_strip_patches", "deep_full_patches", "retraces",
    "fused_pair_calls", "os_fused_segments",
)


def bench_plans(plans: dict, params, vol, reps: int = 3, net=NET) -> dict:
    """Run all plans in interleaved rounds; report each plan's best sweep.

    ``plans`` maps row name -> (plan, executor kwargs) — e.g.
    ``{"overlap_save+deep": (plan, {"deep_reuse": True})}``; rows opt into
    the persisted per-hardware tuned config with ``{"tuned": "auto"}``
    (legacy rows pass ``tuned=None`` so the BENCH_00x trajectory stays
    apples-to-apples), and every JSON row carries ``tuned_config``
    provenance (the loaded config's key fields, or null).

    Interleaving the repetitions (rather than finishing one plan before
    starting the next) keeps a noisy shared host from systematically
    favoring whichever row happened to run during a quiet spell — the
    paired-measurement discipline any cross-primitive wall-clock claim
    needs on CPU.
    """
    out_ch = [l for l in net.layers if l.kind == "conv"][-1].out_channels
    exs, best = {}, {}
    for name, (plan, kwargs) in plans.items():
        kw = dict(kwargs)
        kw.setdefault("tuned", None)
        ex = PlanExecutor(params, net, plan, **kw)
        out = ex.run(vol)  # warmup: compiles + first sweep
        assert out.shape[0] == out_ch
        exs[name] = ex
    for _ in range(reps):
        for name, ex in exs.items():
            ex.run(vol)
            if name not in best or ex.last_stats["seconds"] < best[name]["seconds"]:
                best[name] = ex.last_stats
    rows = {}
    for name, s in best.items():
        plan, _kwargs = plans[name]
        extra = ""
        if s["os_seg_fft"]:
            total = s["os_seg_fft"] + s["os_seg_hits"]
            extra = f"  input-FFTs={s['os_seg_fft']:.0f}/{total:.0f} segs"
            if s["deep_strip_patches"]:
                extra += (
                    f"  MAD-segs={s['os_mad_segments']:.0f}"
                    f"  strip={s['deep_strip_patches']:.0f}/{s['patches']:.0f}"
                )
            if s.get("fused_pair_calls"):
                extra += f"  fused-pairs={s['fused_pair_calls']:.0f}"
            if plan.sweep is not None:
                c = plan.sweep
                ok = (
                    c.seg_fft == s["os_seg_fft"]
                    and c.mad_segments == s["os_mad_segments"]
                    and c.strip_patches == s["deep_strip_patches"]
                )
                extra += f"  planner-predicted={'match' if ok else 'MISMATCH'}"
        if plan.ram_budget is not None:
            extra += (
                f"  peak={s['peak_device_bytes']/2**20:.2f}"
                f"/{plan.ram_budget/2**20:.2f}MiB"
            )
        if plan.strategy == "hetero":
            extra += (
                f"  theta={plan.theta}"
                f"  xfer={s['xfer_bytes']/2**20:.2f}MiB"
                f" ({'exact' if s['xfer_bytes'] == s['predicted_xfer_bytes'] else 'MISMATCH'})"
            )
        print(
            f"{name:<18s} n_in={plan.n_in:>3d} S={plan.batch} "
            f"patches={s['patches']:>3.0f} waste={s['waste_fraction']:.2f}  "
            f"measured={s['measured_voxps']:>12,.0f} vox/s  "
            f"predicted={s['predicted_voxps']:>14,.0f} vox/s{extra}"
        )
        row = {
            "n_in": plan.n_in,
            "batch": plan.batch,
            "measured_voxps": s["measured_voxps"],
            "predicted_voxps": s["predicted_voxps"],
            "waste_fraction": s["waste_fraction"],
            "patches": s["patches"],
            "seconds": s["seconds"],
            # memory counters (ISSUE 5): measured executor ledger peak vs.
            # the plan's predicted footprint; None when no model applies
            "peak_device_bytes": s["peak_device_bytes"],
            "predicted_peak_device_bytes": (
                None
                if math.isnan(s["predicted_peak_device_bytes"])
                else s["predicted_peak_device_bytes"]
            ),
            "ram_budget": plan.ram_budget,
            "predicted_memory": (
                None
                if plan.memory is None
                else {
                    "input_bytes": plan.memory.input_bytes,
                    "output_bytes": plan.memory.output_bytes,
                    "spectra_bytes": plan.memory.spectra_bytes,
                    "scratch_bytes": plan.memory.scratch_bytes,
                    "sweep_cache_bytes": plan.memory.sweep_cache_bytes,
                    "device_bytes": plan.memory.device_bytes,
                }
            ),
        }
        row.update({k: s[k] for k in REUSE_KEYS})
        # tuned-config provenance (repro.tuning): which persisted
        # per-hardware config (if any) shaped this row's executor
        row["tuned_config"] = exs[name].tuned_provenance()
        if plan.strategy == "hetero":
            # two-backend split: measured per-stage / hand-off counters
            # next to the plan's predictions (xfer bytes match exactly)
            row["theta"] = plan.theta
            row["devices"] = list(plan.devices)
            for k in (
                "stage0_seconds", "stage1_seconds",
                "xfer_seconds", "xfer_bytes",
                "predicted_stage0_seconds", "predicted_stage1_seconds",
                "predicted_xfer_seconds", "predicted_xfer_bytes",
            ):
                row[k] = s[k]
        if plan.sweep is not None:
            row["planner_sweep"] = {
                "seg_fft": plan.sweep.seg_fft,
                "seg_hits": plan.sweep.seg_hits,
                "mad_segments": plan.sweep.mad_segments,
                "strip_patches": plan.sweep.strip_patches,
                "full_patches": plan.sweep.full_patches,
            }
        rows[name] = row
    return rows


def bench_sharded(params, net, os_prims, plan, vol, *, workers, m, batch,
                  reps, ram_budget=None, sweep_axis=0) -> dict:
    """The ``sharded`` row (ISSUE 8): the N-worker serving fleet.

    Each sweep's x-planes are partitioned across ``workers`` executors
    with boundary halo handoff; one request per worker is queued so the
    wavefront pipelines and every worker is busy in steady state.  The
    row pins the fleet's halo accounting: measured halo-exchange bytes
    must equal the tiler's ``predict_shard_handoff`` schedule EXACTLY
    (``scripts/check_bench_json.py`` enforces it), and it carries the
    re-dispatch counters (0 in a fault-free bench run).
    """
    from repro.serving import ShardedVolumeEngine, VolumeRequest

    if ram_budget is not None:
        # the budget is usually sized for the default x-axis working frame;
        # a non-x sweep stages a fatter slab (the frame's trailing dims are
        # the volume's other axes), so a budget below the axis frame's own
        # predicted footprint is infeasible, not a tighter pin — raise it
        # to the prediction plus headroom and report the effective budget
        probe = planner.plan_fixed(
            net, TPU_V5E, os_prims, m=m, batch=batch,
            strategy_name="sharded_axis_probe",
            volume_shape=tuple(vol.shape[1:]), sweep_axis=sweep_axis,
        )
        if probe is not None and probe.memory is not None:
            need = probe.memory.device_bytes * 1.05
            if need > ram_budget:
                print(
                    f"sharded: axis-{sweep_axis} frame needs "
                    f"{need/2**20:.2f}MiB, raising fleet budget from "
                    f"{ram_budget/2**20:.2f}MiB"
                )
                ram_budget = need
    eng = ShardedVolumeEngine(
        params, net, prims=os_prims, m=m, batch=batch, tuned="auto",
        n_workers=workers, ram_budget=ram_budget, sweep_axis=sweep_axis,
    )
    base = eng.workers[0].executor
    rid = 0

    def _round():
        nonlocal rid
        reqs = [VolumeRequest(rid + i, vol) for i in range(workers)]
        rid += workers
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        vox = sum(float(np.prod(r.out.shape[1:])) for r in reqs)
        return vox / dt if dt > 0 else float("inf"), dt

    _round()  # warmup: compiles every (worker, bucket) specialization
    best_voxps, best_dt = max(_round() for _ in range(reps))
    s = eng.last_stats
    halo_ok = s["halo_bytes_in"] == s["predicted_halo_bytes_in"]
    print(
        f"{'sharded(x' + str(workers) + ')':<18s} n_in={base.n_in:>3d} "
        f"S={base.batch} patches={s['patches']:>3.0f}  "
        f"measured={best_voxps:>12,.0f} vox/s  "
        f"predicted={plan.throughput * workers:>14,.0f} vox/s  "
        f"halo={s['halo_exchange_bytes']/2**20:.2f}MiB "
        f"({'exact' if halo_ok else 'MISMATCH'})  "
        f"axis={sweep_axis}  redispatches={s['redispatches']}"
    )
    mem = base.predict_memory(vol.shape[1:])
    return {
        "workers": workers,
        "sweep_axis": sweep_axis,
        "n_in": base.n_in,
        "batch": base.batch,
        "batch_buckets": list(eng.batch_buckets),
        "patches": s["patches"],
        "seconds": best_dt,
        "measured_voxps": best_voxps,
        # ideal linear scaling of the single-device plan: the fleet
        # pipelines whole requests across workers, so N requests in
        # flight approach N x the plan's throughput
        "predicted_voxps": plan.throughput * workers,
        # fleet peak = max worker ledger peak (each worker sweeps only
        # its shard's slab, so the per-worker peak is the budget unit)
        "peak_device_bytes": s["peak_device_bytes"],
        "predicted_peak_device_bytes": mem.device_bytes,
        "ram_budget": ram_budget,
        "predicted_memory": None,
        "tuned_config": base.tuned_provenance(),
        # the fleet's halo-handoff accounting: per-worker measured bytes
        # vs. the tiler's predicted schedule (exact match required)
        "halo_bytes_in": list(s["halo_bytes_in"]),
        "predicted_halo_bytes_in": list(s["predicted_halo_bytes_in"]),
        "halo_exchange_bytes": s["halo_exchange_bytes"],
        "predicted_halo_exchange_bytes": s["predicted_halo_exchange_bytes"],
        "redispatches": s["redispatches"],
        "rebalances": s["rebalances"],
        "duplicates_dropped": s["duplicates_dropped"],
        "retraces": s["retraces"],
    }


def bench_anisotropic(params, net, os_prims, *, core, fov, m, batch,
                      reps) -> dict:
    """The ``anisotropic`` row (ISSUE 10): sweep-axis-aware planning pays.

    A thin-slab volume — a single patch extent on x, many cores on y —
    is the geometry the axis-generic sweep targets: a forced-x sweep has
    ONE plane (zero interior strips, zero cross-patch reuse), while the
    planner's per-axis argmax picks the long axis and runs the strip
    path.  The row pairs the planner-chosen plan against the forced-x
    fallback (interleaved repetitions, same volume) and records both
    measured throughputs plus the chosen sweep's reuse counters;
    ``scripts/check_bench_json.py`` requires the chosen axis to beat
    forced-x strictly and the counters to match the sweep prediction
    exactly.
    """
    yc = 4 * m  # long axis: enough cores that strip reuse dominates
    slab = (core + fov - 1, yc * core + 3 + fov - 1, 2 * core + fov - 1)
    # both sides unbudgeted: the A/B isolates the axis choice, and a RAM
    # budget sized for the chosen axis's lean slab can make the forced-x
    # frame (a fatter streaming slab) infeasible instead of merely slower
    chosen_plan = planner.plan_fixed(
        net, TPU_V5E, os_prims, m=m, batch=batch,
        strategy_name="anisotropic", volume_shape=slab,
    )
    forced_plan = planner.plan_fixed(
        net, TPU_V5E, os_prims, m=m, batch=batch,
        strategy_name="anisotropic_forced_x", volume_shape=slab,
        sweep_axis=0,
    )
    rng = np.random.default_rng(1)
    vol = rng.normal(size=(net.in_channels,) + slab).astype(np.float32)
    ex_c = PlanExecutor(params, net, chosen_plan, tuned=None)
    ex_f = PlanExecutor(params, net, forced_plan, tuned=None)
    out_c = ex_c.run(vol)  # warmup: compiles + first sweep
    out_f = ex_f.run(vol)
    allclose = bool(np.allclose(out_c, out_f, rtol=0, atol=2e-3))
    best_c, best_f = None, None
    for _ in range(reps):
        ex_c.run(vol)
        if best_c is None or ex_c.last_stats["seconds"] < best_c["seconds"]:
            best_c = ex_c.last_stats
        ex_f.run(vol)
        if best_f is None or ex_f.last_stats["seconds"] < best_f["seconds"]:
            best_f = ex_f.last_stats
    s = best_c
    c = chosen_plan.sweep
    counters_ok = (
        c.seg_fft == s["os_seg_fft"]
        and c.mad_segments == s["os_mad_segments"]
        and c.strip_patches == s["deep_strip_patches"]
    )
    speedup = s["measured_voxps"] / best_f["measured_voxps"]
    print(
        f"{'anisotropic':<18s} slab={slab} axis={chosen_plan.sweep_axis} "
        f"measured={s['measured_voxps']:>12,.0f} vox/s  "
        f"forced_x={best_f['measured_voxps']:>12,.0f} vox/s  "
        f"({speedup:.2f}x, planner-predicted="
        f"{'match' if counters_ok else 'MISMATCH'})"
    )
    row = {
        "volume_shape": list(slab),
        "sweep_axis": chosen_plan.sweep_axis,
        "n_in": chosen_plan.n_in,
        "batch": chosen_plan.batch,
        "patches": s["patches"],
        "seconds": s["seconds"],
        "waste_fraction": s["waste_fraction"],
        "measured_voxps": s["measured_voxps"],
        "predicted_voxps": s["predicted_voxps"],
        "forced_x_voxps": best_f["measured_voxps"],
        "forced_x_predicted_voxps": best_f["predicted_voxps"],
        "allclose_forced_x": allclose,
        "peak_device_bytes": s["peak_device_bytes"],
        "predicted_peak_device_bytes": (
            None
            if math.isnan(s["predicted_peak_device_bytes"])
            else s["predicted_peak_device_bytes"]
        ),
        "ram_budget": None,
        "predicted_memory": None,
        "tuned_config": ex_c.tuned_provenance(),
        "planner_sweep": {
            "seg_fft": c.seg_fft,
            "seg_hits": c.seg_hits,
            "mad_segments": c.mad_segments,
            "strip_patches": c.strip_patches,
            "full_patches": c.full_patches,
        },
    }
    row.update({k: s[k] for k in REUSE_KEYS})
    return row


def budget_sweep(shape, batch, max_m, net=NET) -> list:
    """Planner-side throughput-vs-RAM curve (the paper's Fig. 5 analog).

    Re-runs the constrained search at a ladder of budgets below the
    unconstrained plan's working set; each row records the winning
    first-conv primitive, fragment size, and predicted throughput, plus
    how many (prim, patch) points the budget rejected — the crossover
    where a faster primitive's patch stops fitting is visible as the
    winner changing down the ladder.
    """
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    # anchor the ladder on the memory-hungriest primitive at the largest
    # patch (whole-patch FFT working set): the top rung admits everything,
    # the lower rungs progressively reject the fat primitives
    anchor = planner.plan_single(
        net, TPU_V5E, max_m=max_m, batches=(batch,),
        conv_prims=("fft_cached",), strategy_name="anchor",
        ram_budget=float("inf"),
    )
    rows = []
    for frac in (1.0, 0.5, 0.25, 0.12, 0.06):
        budget = anchor.memory.device_bytes * frac
        pts: list = []
        plan = planner.plan_single(
            net, TPU_V5E, max_m=max_m, batches=(batch,),
            volume_shape=shape, ram_budget=budget, infeasible=pts,
        )
        row = {
            "ram_budget": budget,
            "feasible": plan is not None,
            "first_conv_prim": plan.prims[first_conv] if plan else None,
            "m": plan.m_final if plan else None,
            "predicted_voxps": plan.throughput if plan else 0.0,
            "infeasible_points": len(pts),
        }
        rows.append(row)
        print(
            f"budget={budget/2**20:8.2f} MiB  "
            + (
                f"prim={row['first_conv_prim']:<12s} m={row['m']} "
                f"predicted={row['predicted_voxps']:>14,.0f} vox/s  "
                f"rejected={len(pts)}"
                if plan
                else f"infeasible ({len(pts)} rejected points)"
            )
        )
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default=BENCH_NET.name,
                    choices=[BENCH_NET.name, *sorted(ZNNI_NETS)],
                    help="net to sweep: the CI bench net (default) or a "
                         "paper Table III net")
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--json", type=str, default=None,
                    help="write machine-readable per-row results here")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: m=1, batch=1, small volume, 1 rep")
    ap.add_argument("--workers", type=int, default=2,
                    help="worker count for the sharded serving-fleet row "
                         "(0 disables the row)")
    ap.add_argument("--sweep-axis", type=int, default=0, choices=(0, 1, 2),
                    help="volume axis the sharded row's fleet sweeps "
                         "(shard windows and halo handoff follow it)")
    ap.add_argument("--ram-budget", type=float, default=None,
                    help="device RAM budget in bytes for the overlap_save "
                         "rows (plans stream host-staged and pin measured "
                         "peak_device_bytes against the prediction)")
    args = ap.parse_args(argv)
    if args.quick:
        args.m, args.batch, args.reps = 1, 1, 1

    net = net_by_name(args.net)
    params = convnet.init_params(jax.random.PRNGKey(0), net)
    probe = planner.plan_single(net, TPU_V5E, max_m=args.m, batches=(args.batch,))
    if probe is None:
        raise SystemExit(
            f"no feasible plan for --m {args.m} --batch {args.batch} "
            "(need m >= 1 and the patch to fit the memory budget)"
        )
    core, fov = probe.core, probe.fov
    rng = np.random.default_rng(0)
    # > 1 patch per axis, non-aligned remainder on x; x is long enough (4
    # cores + remainder) that the sweep has interior x-rows — the regime a
    # real volume sweep lives in and the one overlap-save reuse targets
    xc = 3 if args.quick else 4
    shape = (xc * core + 3 + fov - 1, 2 * core + fov - 1, 2 * core + fov - 1)
    vol = rng.normal(size=(net.in_channels,) + shape).astype(np.float32)
    print(f"volume {shape} -> dense {tuple(s - fov + 1 for s in shape)}  "
          f"(patch extent {probe.patch_extent}^3, core {core}^3)")

    # the overlap_save rows are the configuration the volume runtime
    # deploys: overlap_save at the input layer (the one layer whose input
    # windows have a cross-patch identity for the sweep cache to exploit),
    # fft_cached deeper — a per-layer mix plan_fixed prices directly, in
    # the sweep's PlanGeometry so predicted counters are exact.
    first_conv = next(i for i, l in enumerate(net.layers) if l.kind == "conv")
    os_prims = [
        "overlap_save" if i == first_conv
        else ("fft_cached" if l.kind == "conv" else "mpf")
        for i, l in enumerate(net.layers)
    ]
    # (plan, deep_reuse) per row: the plain overlap_save row is the PR-3
    # baseline (input-spectra reuse only) for the paired A/B measurement
    deep_plan = planner.plan_fixed(
        net, TPU_V5E, os_prims, m=args.m, batch=args.batch,
        strategy_name="overlap_save_deep", volume_shape=shape,
        ram_budget=args.ram_budget,
    )
    plans = {
        "single(mpf)": (probe, {}),
        "fft_cached": (planner.plan_single(
            net, TPU_V5E, max_m=args.m, batches=(args.batch,),
            conv_prims=("fft_cached",), strategy_name="fft_cached",
        ), {}),
        "overlap_save": (planner.plan_fixed(
            net, TPU_V5E, os_prims, m=args.m, batch=args.batch,
            strategy_name="overlap_save", volume_shape=shape,
            deep_reuse=False, ram_budget=args.ram_budget,
        ), {"deep_reuse": False}),
        "overlap_save+deep": (deep_plan, {}),
        # the deployed configuration under the persisted per-hardware
        # tuned config (repro.tuning): same plan geometry as
        # overlap_save+deep, execution knobs (fuse_pairs, fprime_chunk,
        # use_pallas) from the autotuner — the paired row that shows what
        # tuning buys on THIS machine
        "fused_tuned": (deep_plan, {"tuned": "auto"}),
        # ISSUE 9: the halo-emitting fused strip-path epilogue — same deep
        # plan, eligible conv+pool pairs of the capture/strip walks run as
        # ONE fused call each; the row carries a bitwise parity bit vs.
        # the identically-knobbed unfused walk (check_bench_json gates it)
        "fused_os": (deep_plan, {"tuned": "auto", "fuse_pairs": True,
                                 "fuse_os": True}),
        "baseline_naive": (planner.plan_single(
            net, TPU_V5E, max_m=args.m, batches=(args.batch,),
            use_mpf=False, strategy_name="baseline_naive",
        ), {}),
        "direct_only": (planner.plan_single(
            net, TPU_V5E, max_m=args.m, batches=(args.batch,),
            conv_prims=("direct",), strategy_name="direct_only",
        ), {}),
        "pipeline2": (planner.plan_pipeline2(
            net, TPU_V5E, chips_per_stage=1, max_m=args.m,
            batches=(args.batch,),
        ), {}),
        # the paper's CPU+GPU machine as a device set: stage 0 priced on
        # one profile, stage 1 on the other, executed as a two-backend
        # pipeline (host CPU + default accelerator, host-RAM hand-off)
        "hetero": (planner.plan_hetero(
            net, PAPER_MACHINES, chips_per_stage=1, max_m=args.m,
            batches=(args.batch,),
        ), {}),
    }
    feasible = {}
    for name, (plan, kwargs) in plans.items():
        if plan is None:
            print(f"{name:<18s} infeasible under budget")
        else:
            feasible[name] = (plan, kwargs)
    rows = bench_plans(feasible, params, vol, reps=args.reps, net=net)
    if "fused_os" in rows:
        # parity gate: the SAME knobs with fuse_os flipped must produce a
        # bitwise-identical dense output (the fused epilogue moves no
        # arithmetic off the Pallas path), and the fused-pair counter must
        # equal the planner's sweep prediction exactly
        ex_f = PlanExecutor(params, net, deep_plan, tuned="auto",
                            fuse_pairs=True, fuse_os=True)
        ex_u = PlanExecutor(params, net, deep_plan, tuned="auto",
                            fuse_pairs=True, fuse_os=False)
        bitwise_equal = bool(np.array_equal(ex_f.run(vol), ex_u.run(vol)))
        c = ex_f.predict_counts(vol.shape[1:])
        predicted_pairs = (
            (c.strip_patches + c.full_patches) * len(ex_f._fused_pairs)
        )
        rows["fused_os"]["bitwise_equal_unfused"] = bitwise_equal
        rows["fused_os"]["predicted_fused_pair_calls"] = predicted_pairs
        pairs_ok = rows["fused_os"]["fused_pair_calls"] == predicted_pairs
        print(
            f"fused_os parity: bitwise_equal_unfused={bitwise_equal}  "
            f"fused_pair_calls={rows['fused_os']['fused_pair_calls']:.0f} "
            f"({'exact' if pairs_ok else 'MISMATCH'})"
        )
    if args.workers > 0:
        rows["sharded"] = bench_sharded(
            params, net, os_prims, deep_plan, vol, workers=args.workers,
            m=args.m, batch=args.batch, reps=args.reps,
            ram_budget=args.ram_budget, sweep_axis=args.sweep_axis,
        )
    # ISSUE 10: the axis-argmax A/B on a thin slab (planner-chosen sweep
    # axis vs. the forced-x fallback, paired measurement)
    rows["anisotropic"] = bench_anisotropic(
        params, net, os_prims, core=core, fov=fov, m=args.m,
        batch=args.batch, reps=args.reps,
    )
    if {"overlap_save", "fft_cached"} <= rows.keys():
        r = rows["overlap_save"]["measured_voxps"] / rows["fft_cached"]["measured_voxps"]
        print(f"overlap_save / fft_cached: {r:.2f}x "
              "(cross-patch input-spectra reuse at the input layer)")
    if {"overlap_save+deep", "overlap_save"} <= rows.keys():
        r = (rows["overlap_save+deep"]["measured_voxps"]
             / rows["overlap_save"]["measured_voxps"])
        print(f"overlap_save+deep / overlap_save: {r:.2f}x "
              "(deeper-layer activation reuse across patches)")
    print("-- throughput vs. RAM budget (planner, Fig. 5 analog) --")
    sweep_rows = budget_sweep(shape, args.batch, max(args.m, 2), net=net)
    if args.json:
        payload = {
            "net": net.name,
            "volume_shape": list(shape),
            "m": args.m,
            "batch": args.batch,
            "reps": args.reps,
            "quick": args.quick,
            "ram_budget": args.ram_budget,
            "rows": rows,
            "budget_sweep": sweep_rows,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, sort_keys=True)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
