"""End-to-end volume inference throughput: measured vs. planner-predicted.

Sweeps a volume strictly larger than one patch (with a non-aligned edge)
through the PlanExecutor for each strategy the planner can realize on one
host, and reports end-to-end vox/s — border waste included, i.e. dense
output voxels divided by total wall time, the paper's §VII metric.

The prediction column is the planner's analytic throughput for the target
hardware model (TPU v5e by default); on the CPU container the absolute
numbers differ but the MPF-vs-naive ordering and the waste fractions are
the reproducible part.  The ``fft_cached`` row exercises the CompiledPlan
path: kernel spectra are transformed once at plan-compile time and reused
across every patch (ISSUE 2 acceptance — compare against an ``fft_task``
sweep of the same geometry to see the per-patch kernel FFTs disappear).

Run:  PYTHONPATH=src python benchmarks/volume_throughput.py [--m 2]
"""

import argparse

import jax
import numpy as np

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.volume import PlanExecutor

NET = ConvNetConfig(
    "bench-net", 1,
    (L("conv", 3, 8), L("pool", 2), L("conv", 3, 8), L("pool", 2), L("conv", 3, 3)),
)


def bench_plan(name: str, plan, params, vol) -> None:
    ex = PlanExecutor(params, NET, plan)
    ex.run(vol)  # warmup: compiles + first sweep
    out = ex.run(vol)
    s = ex.last_stats
    print(
        f"{name:<16s} n_in={plan.n_in:>3d} S={plan.batch} "
        f"patches={s['patches']:>3.0f} waste={s['waste_fraction']:.2f}  "
        f"measured={s['measured_voxps']:>12,.0f} vox/s  "
        f"predicted={s['predicted_voxps']:>14,.0f} vox/s"
    )
    assert out.shape[0] == 3


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)

    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    probe = planner.plan_single(NET, TPU_V5E, max_m=args.m, batches=(args.batch,))
    if probe is None:
        raise SystemExit(
            f"no feasible plan for --m {args.m} --batch {args.batch} "
            "(need m >= 1 and the patch to fit the memory budget)"
        )
    core, fov = probe.core, probe.fov
    rng = np.random.default_rng(0)
    # > 1 patch per axis, non-aligned remainder on x
    shape = (2 * core + 3 + fov - 1, 2 * core + fov - 1, 2 * core + fov - 1)
    vol = rng.normal(size=(1,) + shape).astype(np.float32)
    print(f"volume {shape} -> dense {tuple(s - fov + 1 for s in shape)}  "
          f"(patch extent {probe.patch_extent}^3, core {core}^3)")

    plans = {
        "single(mpf)": probe,
        "fft_cached": planner.plan_single(
            NET, TPU_V5E, max_m=args.m, batches=(args.batch,),
            conv_prims=("fft_cached",), strategy_name="fft_cached",
        ),
        "baseline_naive": planner.plan_single(
            NET, TPU_V5E, max_m=args.m, batches=(args.batch,),
            use_mpf=False, strategy_name="baseline_naive",
        ),
        "direct_only": planner.plan_single(
            NET, TPU_V5E, max_m=args.m, batches=(args.batch,),
            conv_prims=("direct",), strategy_name="direct_only",
        ),
        "pipeline2": planner.plan_pipeline2(
            NET, TPU_V5E, chips_per_stage=1, max_m=args.m,
            batches=(args.batch,),
        ),
    }
    for name, plan in plans.items():
        if plan is None:
            print(f"{name:<16s} infeasible under budget")
            continue
        bench_plan(name, plan, params, vol)


if __name__ == "__main__":
    main()
