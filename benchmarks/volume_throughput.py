"""End-to-end volume inference throughput: measured vs. planner-predicted.

Sweeps a volume strictly larger than one patch (with a non-aligned edge)
through the PlanExecutor for each strategy the planner can realize on one
host, and reports end-to-end vox/s — border waste included, i.e. dense
output voxels divided by total wall time, the paper's §VII metric.

The prediction column is the planner's analytic throughput for the target
hardware model (TPU v5e by default); on the CPU container the absolute
numbers differ but the MPF-vs-naive ordering and the waste fractions are
the reproducible part.  The ``fft_cached`` row exercises the CompiledPlan
path: kernel spectra are transformed once at plan-compile time and reused
across every patch (ISSUE 2 acceptance — compare against an ``fft_task``
sweep of the same geometry to see the per-patch kernel FFTs disappear).
The ``overlap_save`` row additionally reuses *input* segment spectra
across x-adjacent patches (ISSUE 3): its line reports how many input
segment FFTs actually ran vs. how many a reuse-free sweep would run
(``fft_cached`` transforms every patch's full input every time).

Run:  PYTHONPATH=src python benchmarks/volume_throughput.py [--m 2]
"""

import argparse

import jax
import numpy as np

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.volume import PlanExecutor

# 8 input channels so layer-0 input transforms carry real work: with a
# single-channel input the term every FFT row amortizes (fft_cached: kernel
# spectra; overlap_save: input segment spectra) is measurement noise.
NET = ConvNetConfig(
    "bench-net", 8,
    (L("conv", 3, 8), L("pool", 2), L("conv", 3, 8), L("pool", 2), L("conv", 3, 3)),
)


def bench_plans(plans: dict, params, vol, reps: int = 3) -> dict:
    """Run all plans in interleaved rounds; report each plan's best sweep.

    Interleaving the repetitions (rather than finishing one plan before
    starting the next) keeps a noisy shared host from systematically
    favoring whichever row happened to run during a quiet spell — the
    paired-measurement discipline any cross-primitive wall-clock claim
    needs on CPU.
    """
    exs, best = {}, {}
    for name, plan in plans.items():
        ex = PlanExecutor(params, NET, plan)
        out = ex.run(vol)  # warmup: compiles + first sweep
        assert out.shape[0] == 3
        exs[name] = ex
    for _ in range(reps):
        for name, ex in exs.items():
            ex.run(vol)
            if name not in best or ex.last_stats["seconds"] < best[name]["seconds"]:
                best[name] = ex.last_stats
    measured = {}
    for name, s in best.items():
        plan = plans[name]
        extra = ""
        if s["os_seg_fft"]:
            total = s["os_seg_fft"] + s["os_seg_hits"]
            extra = f"  input-FFTs={s['os_seg_fft']:.0f}/{total:.0f} segs"
        print(
            f"{name:<16s} n_in={plan.n_in:>3d} S={plan.batch} "
            f"patches={s['patches']:>3.0f} waste={s['waste_fraction']:.2f}  "
            f"measured={s['measured_voxps']:>12,.0f} vox/s  "
            f"predicted={s['predicted_voxps']:>14,.0f} vox/s{extra}"
        )
        measured[name] = s["measured_voxps"]
    return measured


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    args = ap.parse_args(argv)

    params = convnet.init_params(jax.random.PRNGKey(0), NET)
    probe = planner.plan_single(NET, TPU_V5E, max_m=args.m, batches=(args.batch,))
    if probe is None:
        raise SystemExit(
            f"no feasible plan for --m {args.m} --batch {args.batch} "
            "(need m >= 1 and the patch to fit the memory budget)"
        )
    core, fov = probe.core, probe.fov
    rng = np.random.default_rng(0)
    # > 1 patch per axis, non-aligned remainder on x; x is long enough (4
    # cores + remainder) that the sweep has interior x-rows — the regime a
    # real volume sweep lives in and the one overlap-save reuse targets
    shape = (4 * core + 3 + fov - 1, 2 * core + fov - 1, 2 * core + fov - 1)
    vol = rng.normal(size=(NET.in_channels,) + shape).astype(np.float32)
    print(f"volume {shape} -> dense {tuple(s - fov + 1 for s in shape)}  "
          f"(patch extent {probe.patch_extent}^3, core {core}^3)")

    # the overlap_save row is the configuration the volume runtime deploys:
    # overlap_save at the input layer (the one layer whose input windows
    # have a cross-patch identity for the sweep cache to exploit),
    # fft_cached deeper — a per-layer mix plan_fixed prices directly.
    first_conv = next(i for i, l in enumerate(NET.layers) if l.kind == "conv")
    os_prims = [
        "overlap_save" if i == first_conv
        else ("fft_cached" if l.kind == "conv" else "mpf")
        for i, l in enumerate(NET.layers)
    ]
    plans = {
        "single(mpf)": probe,
        "fft_cached": planner.plan_single(
            NET, TPU_V5E, max_m=args.m, batches=(args.batch,),
            conv_prims=("fft_cached",), strategy_name="fft_cached",
        ),
        "overlap_save": planner.plan_fixed(
            NET, TPU_V5E, os_prims, m=args.m, batch=args.batch,
            strategy_name="overlap_save",
        ),
        "baseline_naive": planner.plan_single(
            NET, TPU_V5E, max_m=args.m, batches=(args.batch,),
            use_mpf=False, strategy_name="baseline_naive",
        ),
        "direct_only": planner.plan_single(
            NET, TPU_V5E, max_m=args.m, batches=(args.batch,),
            conv_prims=("direct",), strategy_name="direct_only",
        ),
        "pipeline2": planner.plan_pipeline2(
            NET, TPU_V5E, chips_per_stage=1, max_m=args.m,
            batches=(args.batch,),
        ),
    }
    feasible = {}
    for name, plan in plans.items():
        if plan is None:
            print(f"{name:<16s} infeasible under budget")
        else:
            feasible[name] = plan
    measured = bench_plans(feasible, params, vol)
    if {"overlap_save", "fft_cached"} <= measured.keys():
        r = measured["overlap_save"] / measured["fft_cached"]
        print(f"overlap_save / fft_cached: {r:.2f}x "
              "(cross-patch input-spectra reuse at the input layer)")


if __name__ == "__main__":
    main()
