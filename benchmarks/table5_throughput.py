"""Table V: throughput of the four execution strategies vs the naive
baseline, per net.

Two layers of evidence:
  * analytic (TPU v5e model): voxels/s of single / streamed / pipeline2 /
    spatial / baseline_naive — the Table V columns.
  * measured (this CPU): a reduced-channel n337 run with MPF vs the naive
    all-subsamplings execution, confirming the MPF win on real wall-clock.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ZNNI_NETS
from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E

from .common import emit, time_call


def analytic() -> None:
    for name, net in ZNNI_NETS.items():
        plans = planner.plan_all_strategies(net, TPU_V5E, chips=256)
        parts = []
        for strat in ("baseline_naive", "single", "streamed", "pipeline2", "spatial"):
            p = plans[strat]
            parts.append(f"{strat}={p.throughput:.3e}" if p else f"{strat}=inf")
        emit(f"table5.analytic.{name}", 0.0, ";".join(parts))


def measured() -> None:
    net = ConvNetConfig(
        "n337-small", 1,
        (L("conv", 2, 4), L("pool", 2), L("conv", 3, 4), L("pool", 2),
         L("conv", 3, 4), L("pool", 2), L("conv", 3, 2)),
    )
    rng = np.random.default_rng(0)
    params = convnet.init_params(jax.random.PRNGKey(0), net)
    m = 2
    n_mpf = net.valid_input_size(m)
    x = jnp.asarray(rng.normal(size=(1, 1, n_mpf, n_mpf, n_mpf)).astype(np.float32))
    prims_mpf = ["fft_task" if l.kind == "conv" else "mpf" for l in net.layers]
    run_mpf = jax.jit(lambda a: convnet.apply_plan(params, net, a, prims_mpf))
    t_mpf = time_call(run_mpf, x)
    vox_mpf = (m * net.total_pooling()) ** 3

    # naive: one subsampling per run; dense output needs P^3 runs
    n_pl = m
    for layer in reversed(net.layers):
        n_pl = n_pl + layer.size - 1 if layer.kind == "conv" else n_pl * layer.size
    xp = jnp.asarray(rng.normal(size=(1, 1, n_pl, n_pl, n_pl)).astype(np.float32))
    prims_pool = ["fft_task" if l.kind == "conv" else "pool" for l in net.layers]
    run_naive = jax.jit(lambda a: convnet.apply_plan(params, net, a, prims_pool))
    t_naive = time_call(run_naive, xp)
    vox_naive = float(m**3)  # per run

    thr_mpf = vox_mpf / (t_mpf * 1e-6)
    thr_naive = vox_naive / (t_naive * 1e-6)
    emit(
        "table5.measured.n337_small", t_mpf,
        f"mpf_vox_s={thr_mpf:.3e};naive_vox_s={thr_naive:.3e};speedup={thr_mpf / thr_naive:.1f}",
    )


def main() -> None:
    analytic()
    measured()


if __name__ == "__main__":
    main()
