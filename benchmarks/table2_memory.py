"""Table II: memory required by each conv primitive (peak live bytes of our
implementations' stages, the TPU translation of the paper's formulas)."""

from __future__ import annotations

from repro.core import cost_model

from .common import emit


def main() -> None:
    S, f, fp, n, k = 1, 80, 80, 128, 5
    for prim in cost_model.CONV_PRIMS:
        c = cost_model.conv_cost(prim, S, f, fp, (n, n, n), k)
        emit(
            f"table2.mem.{prim}", 0.0,
            f"peak_GiB={c.peak_bytes / 2**30:.3f};hbm_GiB={c.hbm_bytes / 2**30:.3f}",
        )
    # the paper's qualitative orderings
    d = cost_model.conv_cost("direct", S, f, fp, (n,) * 3, k)
    a1 = cost_model.conv_cost("fft_data", S, f, fp, (n,) * 3, k)
    a2 = cost_model.conv_cost("fft_task", S, f, fp, (n,) * 3, k)
    assert d.peak_bytes < a1.peak_bytes < a2.peak_bytes, "Table II ordering"
    emit("table2.ordering", 0.0, "direct<fft_data<fft_task=OK")


if __name__ == "__main__":
    main()
