"""Train a 3D boundary-segmentation ConvNet (the paper's workload family)
for a few hundred steps on synthetic EM-like volumes.

The target is a synthetic "membrane" indicator (thresholded smoothed
noise); loss is voxelwise sigmoid BCE on the dense sliding-window output.
Loss decreasing over ~200 steps demonstrates the training substrate
(optimizer, data pipeline, checkpointing) end-to-end.

Run:  PYTHONPATH=src python examples/train_segmentation.py --steps 200
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet
from repro.data import SyntheticVolumePipeline, VolumePipelineConfig
from repro.optim import AdamWConfig, apply_updates, init_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    net = ConvNetConfig(
        "seg-net", 1, (L("conv", 3, 8), L("conv", 3, 8), L("conv", 3, 1))
    )
    fov = net.field_of_view()
    n_in = 16
    n_out = n_in - fov + 1
    params = convnet.init_params(jax.random.PRNGKey(0), net)
    ocfg = AdamWConfig(lr=args.lr)
    opt = init_state(params, ocfg)
    pipe = SyntheticVolumePipeline(VolumePipelineConfig(patch=n_in, batch=2))

    def labels_of(x):
        # membrane-ish target: |smoothed voxel| above threshold
        core = x[:, :, fov // 2 : fov // 2 + n_out,
                 fov // 2 : fov // 2 + n_out, fov // 2 : fov // 2 + n_out]
        return (jnp.abs(core) > 0.4).astype(jnp.float32)

    def loss_fn(p, x, y):
        logits = convnet.apply_plan(p, net, x, ["direct"] * 3)
        z = logits.astype(jnp.float32)
        return jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss_fn)(p, x, y)
        p, o = apply_updates(p, g, o, ocfg)
        return p, o, l

    losses = []
    for s in range(args.steps):
        x = jnp.asarray(pipe.batch_at(s))
        y = labels_of(x)
        params, opt, l = step(params, opt, x, y)
        losses.append(float(l))
        if s % 25 == 0:
            print(f"step {s:4d}  bce {losses[-1]:.4f}")
    print(f"first-10 mean {np.mean(losses[:10]):.4f} -> last-10 mean {np.mean(losses[-10:]):.4f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
