"""ZNNi's CPU-GPU pipeline (Fig. 8) as a two-stage pod pipeline.

Shows (1) the planner's θ split and the queue-depth-1 timeline, and
(2) the actual pipelined executor running on a 2-pod mesh (this script
re-execs itself with 2 fake host devices).

Run:  PYTHONPATH=src python examples/pipeline_inference.py
"""

import os
import subprocess
import sys

if __name__ == "__main__" and os.environ.get("_PIPE_CHILD") != "1":
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["_PIPE_CHILD"] = "1"
    raise SystemExit(subprocess.call([sys.executable] + sys.argv, env=env))

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs import N337
from repro.core import planner
from repro.core.hw import TPU_V5E
from repro.core.pipeline import pipeline_schedule, pipelined_apply

# --- 1. the planner's θ split and timeline for the paper's n337
plan = planner.plan_pipeline2(N337, TPU_V5E, chips_per_stage=128)
t = [c.time_s for c in plan.choices]
t0, t1 = sum(t[: plan.theta]), sum(t[plan.theta :])
print(f"[plan] n337 pipeline: theta={plan.theta} stage0={t0*1e3:.2f}ms "
      f"stage1={t1*1e3:.2f}ms throughput={plan.throughput:,.0f} vox/s")
mk, events = pipeline_schedule(6, t0, t1)
for st, patch, s, e in events[:8]:
    bar = " " * int(s * 2e3) + "#" * max(int((e - s) * 2e3), 1)
    print(f"  {st} p{patch}: {bar}")

# --- 2. a real two-stage pipelined run on a 2-pod mesh
mesh = jax.make_mesh((2,), ("pod",))
stage0 = lambda x: jnp.tanh(x) * 2.0
stage1 = lambda x: x.sum(axis=-1, keepdims=True)

T = 8
xs = jax.random.normal(jax.random.PRNGKey(0), (T, 16), jnp.float32)
f = shard_map(
    lambda s: pipelined_apply(stage0, stage1, s, axis_name="pod"),
    mesh=mesh, in_specs=P(None, None), out_specs=P(None, None),
    check_rep=False,
)
ys = f(xs)
want = stage1(stage0(xs))
np.testing.assert_allclose(np.asarray(ys), np.asarray(want), rtol=1e-5)
print(f"\n[exec] pipelined 2-pod run over {T} patches matches the functional "
      f"composition (max err {float(jnp.abs(ys - want).max()):.2e})")
print("OK")
