"""Quickstart: the ZNNi pipeline in ~40 lines.

1. Build a sliding-window 3D ConvNet (paper Table III family).
2. Ask the planner for the throughput-optimal execution plan.
3. Run dense sliding-window inference with MPF + pruned-FFT convolution.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E

# a small CPCPC net (reduced channels so the example runs in seconds on CPU)
net = ConvNetConfig(
    "quickstart", 1,
    (L("conv", 3, 8), L("pool", 2), L("conv", 3, 8), L("pool", 2), L("conv", 3, 3)),
)

# --- 1. plan: the ZNNi search (primitive per layer x patch size x batch)
plan = planner.plan_single(net, TPU_V5E, max_m=16)
print(plan.summary())

# --- 2. run it (small patch so the CPU demo is fast)
m = 2
n_in = net.valid_input_size(m)
params = convnet.init_params(jax.random.PRNGKey(0), net)
x = jax.random.normal(jax.random.PRNGKey(1), (1, 1, n_in, n_in, n_in), jnp.float32)

prims = [c.prim for c in plan.choices]
out = convnet.apply_plan(params, net, x, prims)
print(f"\ninput {x.shape} -> dense sliding-window output {out.shape}")

# --- 3. verify against the dense oracle (dilated convolution semantics)
ref = convnet.apply_dense_reference(params, net, x)
err = float(jnp.abs(out - ref).max())
print(f"max abs err vs dense reference: {err:.2e}")
assert err < 1e-3
print("OK")
