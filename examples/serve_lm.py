"""Batched LM serving with continuous batching — the ZNNi throughput logic
(largest batch that fits the memory budget) applied to KV-cache slots.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-2.7b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serving import EngineConfig, Request, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, EngineConfig(slots=args.slots, max_seq=64))

    rng = np.random.default_rng(0)
    reqs = [
        Request(i, rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32), args.max_new)
        for i in range(args.requests)
    ]
    for r in reqs:
        eng.submit(r)

    t0 = time.perf_counter()
    ticks = 0
    while any(not r.done for r in reqs) and ticks < 500:
        eng.step()
        ticks += 1
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in reqs)
    print(f"[serve-lm] {args.arch} (reduced): {len(reqs)} requests, "
          f"{toks} tokens, {ticks} ticks, {toks / dt:.1f} tok/s")
    for r in reqs[:2]:
        print(f"  req {r.rid}: {r.out}")
    print("OK")


if __name__ == "__main__":
    main()
