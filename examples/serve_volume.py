"""End-to-end driver (the paper's kind: INFERENCE): a sliding-window
segmentation service over a large 3D volume.

The service plans once (planner), caches kernel spectra once (the
beyond-paper fft_cached primitive), then streams overlapping patches
through the net and stitches dense output — measuring voxels/second, the
paper's throughput metric.

Run:  PYTHONPATH=src python examples/serve_volume.py [--patches 4]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.distributed_inference import extract_patches, patch_grid
from repro.core.hw import TPU_V5E
from repro.data import SyntheticVolumePipeline, VolumePipelineConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--patches", type=int, default=4)
    ap.add_argument("--m", type=int, default=2, help="fragment size per patch")
    args = ap.parse_args()

    net = ConvNetConfig(
        "serve-net", 1,
        (L("conv", 3, 8), L("pool", 2), L("conv", 3, 8), L("pool", 2), L("conv", 3, 3)),
    )
    plan = planner.plan_single(net, TPU_V5E, max_m=16)
    prims = [c.prim for c in plan.choices]
    print(f"[plan] primitives: {prims}; paper-style patch n={plan.n_in}^3 (demo uses m={args.m})")

    m = args.m
    n_in = net.valid_input_size(m)
    core = net.output_size(n_in) * net.total_pooling()
    params = convnet.init_params(jax.random.PRNGKey(0), net)

    # the volume: W overlapping patches along x (overlap-save, §II)
    W = args.patches
    X = W * core + (net.field_of_view() - 1)
    vol = jnp.asarray(
        SyntheticVolumePipeline(VolumePipelineConfig(patch=1)).batch_at(0)[0, 0, :1, :1, :1]
    )  # placeholder init; real volume below
    rng = np.random.default_rng(0)
    vol = jnp.asarray(rng.normal(size=(1, X, n_in, n_in)).astype(np.float32))

    run = jax.jit(lambda p: convnet.apply_plan(params, net, p[None], prims))

    # warmup + serve
    grid = patch_grid((X, n_in, n_in), net, m, W)
    patches = extract_patches(vol, grid)
    _ = jax.block_until_ready(run(patches[0]))
    t0 = time.perf_counter()
    outs = [jax.block_until_ready(run(p)) for p in patches]
    dt = time.perf_counter() - t0
    dense = jnp.concatenate([o[0] for o in outs], axis=1)
    vox = int(np.prod(dense.shape[1:]))
    print(f"[serve] {W} patches -> dense output {dense.shape}; "
          f"{vox} voxels in {dt:.2f}s = {vox / dt:,.0f} vox/s")


if __name__ == "__main__":
    main()
