"""End-to-end driver (the paper's kind: INFERENCE): a sliding-window
segmentation service over large 3D volumes.

Plans once (planner), then serves queued volume requests through the
volume runtime: the tiler decomposes each volume into overlapping valid
patches, and the VolumeEngine continuously batches patches *across*
requests into fused executor steps — measuring voxels/second, the paper's
throughput metric, against the planner's prediction.

Run:  PYTHONPATH=src python examples/serve_volume.py [--volumes 2] [--m 2]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ConvLayerSpec as L, ConvNetConfig
from repro.core import convnet, planner
from repro.core.hw import TPU_V5E
from repro.serving import VolumeEngine, VolumeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--volumes", type=int, default=2, help="queued requests")
    ap.add_argument("--m", type=int, default=2, help="fragment size per patch")
    ap.add_argument("--batch", type=int, default=2, help="patches per step")
    args = ap.parse_args()

    net = ConvNetConfig(
        "serve-net", 1,
        (L("conv", 3, 8), L("pool", 2), L("conv", 3, 8), L("pool", 2), L("conv", 3, 3)),
    )
    plan = planner.plan_single(net, TPU_V5E, max_m=args.m, batches=(args.batch,))
    if plan is None:
        raise SystemExit(
            f"no feasible plan for --m {args.m} --batch {args.batch} "
            "(need m >= 1 and the patch to fit the memory budget)"
        )
    print(f"[plan] {plan.summary()}")
    print(f"[plan] patch extent {plan.patch_extent}^3, core {plan.core}^3, "
          f"overlap {plan.overlap}, predicted {plan.throughput:,.0f} vox/s")

    params = convnet.init_params(jax.random.PRNGKey(0), net)
    engine = VolumeEngine(params, net, plan)

    rng = np.random.default_rng(0)
    core, fov = plan.core, plan.fov

    # warmup compile through the engine's own path (a throwaway request
    # producing one full batch of patches), so the fixed-patch-shape
    # compiles land before the real requests are queued and timed.  Note:
    # overlap-save plans additionally re-trace their fused step per
    # distinct padded-volume shape, so differently-sized requests still
    # pay some compilation in the timed window (ROADMAP: bucket shapes).
    warm_x = engine.batch * core + fov - 1
    engine.submit(VolumeRequest(-1, np.zeros((1, warm_x, fov, fov), np.float32)))
    engine.run_until_drained()
    engine.finished.clear()
    engine.ticks = 0

    reqs = []
    for rid in range(args.volumes):
        # different sizes per request, incl. a non-core-aligned remainder
        x = (2 + rid) * core + rid + fov - 1
        y = 2 * core + fov - 1
        z = core + 3 + fov - 1
        vol = rng.normal(size=(1, x, y, z)).astype(np.float32)
        req = VolumeRequest(rid, vol)
        engine.submit(req)
        reqs.append(req)
    n_patches = len(engine.queue)
    t0 = time.perf_counter()
    engine.run_until_drained()
    dt = time.perf_counter() - t0

    vox = sum(int(np.prod(r.out.shape[1:])) for r in reqs)
    print(f"[serve] {len(reqs)} volumes, {n_patches} patches, "
          f"{engine.ticks} fused steps (batch={engine.batch})")
    print(f"[serve] {vox} dense voxels in {dt:.2f}s = {vox/dt:,.0f} vox/s "
          f"(planner predicted {plan.throughput:,.0f} on {TPU_V5E.name})")
    for r in reqs:
        print(f"  request {r.rid}: out {r.out.shape} done={r.done}")


if __name__ == "__main__":
    main()
